"""§4 / extended paper — sketch accuracy vs. memory: the paper found
sketches either inaccurate or memory-hungry and used a counter heuristic."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timer
from repro.core import CounterSketch, CountMinSketch, Histogram, LossyCounting, SpaceSaving
from repro.data.generators import zipf_keys


def _recall(est: Histogram, exact: Histogram, k: int) -> float:
    a = set(est.top(k).keys.tolist())
    b = set(exact.top(k).keys.tolist())
    return len(a & b) / max(len(b), 1)


SMOKE = dict(n=20_000, num_keys=5_000)  # CI bench-smoke profile


def run(n: int = 200_000, num_keys: int = 50_000, k: int = 40):
    rows = []
    stream = zipf_keys(n, num_keys=num_keys, exponent=1.1, seed=0)
    exact = Histogram.exact(stream)
    sketches = {
        "counter_heuristic": CounterSketch(capacity=256),
        "spacesaving": SpaceSaving(capacity=256),
        "lossy_counting": LossyCounting(epsilon=1 / 256),
        "cms_small": CountMinSketch(depth=4, width=256),
        "cms_big": CountMinSketch(depth=4, width=8192),
    }
    for name, sk in sketches.items():
        if name == "spacesaving" or name == "lossy_counting":
            sk.update(stream)  # sequential reference implementations
        else:
            for i in range(0, n, 10_000):
                sk.update(stream[i : i + 10_000])
        rows.append((f"sketch/recall@{k}/{name}", _recall(sk.histogram(), exact, k), ""))
        rows.append((f"sketch/memory_items/{name}", float(sk.memory_items), ""))
    # batch update throughput of the DRW heuristic (the paper's hot path)
    cs = CounterSketch(capacity=256)
    us = timer(lambda: cs.update(stream[:10_000]))
    rows.append(("sketch/update_10k_records", us, "us (counter heuristic)"))
    return rows
