"""Baseline partitioning strategies the paper compares against.

* ``Readj`` / ``Redist`` / ``Scan`` — Gedik, *Partitioning functions for
  stateful data parallelism in stream processing*, VLDBJ 2014.  Run with
  linear resource functions, balance constraint ``theta = 0.2`` and utility
  ``U = rho + gamma`` (the paper's stated configuration).
* ``Mixed`` — Fang et al., arXiv:1610.05121: explicit placement for tracked
  heavy keys + uniform hash for the tail, under a load bound ``theta_max``.

These are best-effort reconstructions from the cited papers' descriptions
(the DR paper itself partly reconstructs its Storm/S4 baselines the same
way).  All of them share KIP's table representation so balance, migration
and runtime measurements are apples-to-apples; none of them re-bins the
weighted-hash tail — that is KIP's distinguishing mechanism.
"""
from __future__ import annotations

import numpy as np

from repro.core.hashing import DEFAULT_NUM_HOSTS
from repro.core.histogram import Histogram
from repro.core.partitioner import Partitioner, _pad_heavy, uniform_partitioner

__all__ = ["readj_update", "redist_update", "scan_update", "mixed_update"]


def _tail_loads(prev: Partitioner, hist: Histogram, n: int) -> np.ndarray:
    hosts_per_part = np.bincount(prev.host_to_part, minlength=n).astype(np.float64)
    return hist.tail_mass / prev.num_hosts * hosts_per_part


def _build(prev: Partitioner, hist: Histogram, parts: np.ndarray, n: int) -> Partitioner:
    cap = max(len(hist), prev.heavy_keys.shape[0])
    hk, hp, _ = _pad_heavy(hist.keys.astype(np.int32), parts.astype(np.int32), cap)
    return Partitioner(n, hk, hp, prev.host_to_part.copy(), prev.seed)


def readj_update(
    prev: Partitioner, hist: Histogram, num_partitions: int | None = None, theta: float = 0.2
) -> Partitioner:
    """READJ: keep previous placement; move heavy keys off partitions only
    while the balance constraint ``max <= (1 + theta) * ideal`` is violated.
    Moves the smallest item of the most loaded partition each step (cheapest
    correction first), bounded by O(B^2) steps.
    """
    n = int(num_partitions or prev.num_partitions)
    b = len(hist)
    parts = prev.lookup_np(hist.keys.astype(np.int32)).astype(np.int64)
    freqs = hist.freqs
    load = _tail_loads(prev, hist, n)
    np.add.at(load, parts, freqs)
    ideal = 1.0 / n
    bound = (1.0 + theta) * ideal
    for _ in range(4 * b + 4):
        src = int(np.argmax(load))
        if load[src] <= bound:
            break
        members = np.where(parts == src)[0]
        if len(members) == 0:
            break
        # LPT-style readjust: relocate the *largest* improving item of the
        # overloaded partition (fast convergence, heavy migration — the
        # trade the paper measures against KIP's keep-in-place probes)
        dst = int(np.argmin(load))
        if dst == src:
            break
        order = members[np.argsort(-freqs[members])]
        move = next((m for m in order if load[dst] + freqs[m] < load[src]), None)
        if move is None:
            break
        parts[move] = dst
        load[src] -= freqs[move]
        load[dst] += freqs[move]
    return _build(prev, hist, parts, n)


def redist_update(
    prev: Partitioner, hist: Histogram, num_partitions: int | None = None, theta: float = 0.2
) -> Partitioner:
    """REDIST: rebuild from scratch by LPT greedy — best balance over the
    tracked keys, completely migration-oblivious (previous placement is
    ignored, so placements flap with histogram noise — the heavy-migration
    end of Gedik's spectrum)."""
    n = int(num_partitions or prev.num_partitions)
    load = _tail_loads(prev, hist, n)
    parts = np.zeros(len(hist), np.int64)
    for i in range(len(hist)):  # hist is frequency-descending (LPT order)
        p = int(np.argmin(load))
        parts[i] = p
        load[p] += hist.freqs[i]
    return _build(prev, hist, parts, n)


def scan_update(
    prev: Partitioner, hist: Histogram, num_partitions: int | None = None, theta: float = 0.2
) -> Partitioner:
    """SCAN: per-item utility minimization U = rho + gamma — stay at the
    current location unless that violates the balance constraint (gamma
    dominates ties), making it the most migration-frugal strategy.
    """
    n = int(num_partitions or prev.num_partitions)
    parts = prev.lookup_np(hist.keys.astype(np.int32)).astype(np.int64)
    freqs = hist.freqs
    load = _tail_loads(prev, hist, n)
    ideal = 1.0 / n
    out = np.zeros(len(hist), np.int64)
    for i in range(len(hist)):
        f = freqs[i]
        stay = int(parts[i])
        best = int(np.argmin(load))
        # U = rho + gamma: moving must beat staying by more than the slack
        # (gamma penalizes any migration) — maximally sticky placement
        if load[stay] <= load[best] + theta * ideal:
            p = stay
        else:
            p = best
        out[i] = p
        load[p] += f
    return _build(prev, hist, out, n)


def mixed_update(
    prev: Partitioner,
    hist: Histogram,
    num_partitions: int | None = None,
    theta_max: float = 0.1,
    a_max: int | None = None,
) -> Partitioner:
    """MIXED (Fang et al.): explicit top-``a_max`` keys + hash tail, rebuilt
    each epoch under load bound ``(1 + theta_max)/N``.  Unlike KIP it has no
    migration-aware probe order and never re-bins the hash tail.
    """
    n = int(num_partitions or prev.num_partitions)
    if a_max is not None:
        hist = hist.top(a_max)
    load = _tail_loads(prev, hist, n)
    bound = (1.0 + theta_max) / n
    parts = np.zeros(len(hist), np.int64)
    for i in range(len(hist)):
        f = hist.freqs[i]
        # hash location if admissible (cheap routing), else least loaded
        hp = int(prev.lookup_np(hist.keys[i : i + 1].astype(np.int32))[0])
        p = hp if load[hp] + f <= bound else int(np.argmin(load))
        parts[i] = p
        load[p] += f
    return _build(prev, hist, parts, n)


def make_baseline(name: str, num_partitions: int, num_hosts: int = DEFAULT_NUM_HOSTS, seed: int = 0):
    """(update_fn, initial_partitioner) pair for a named strategy."""
    updates = {
        "hash": lambda prev, hist, n=None, **kw: prev,
        "readj": readj_update,
        "redist": redist_update,
        "scan": scan_update,
        "mixed": mixed_update,
    }
    if name not in updates:
        raise KeyError(f"unknown baseline {name!r}; have {sorted(updates)}")
    return updates[name], uniform_partitioner(num_partitions, num_hosts, seed)
