"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (value column is the figure's
metric: imbalance ratio / speedup / us, per the row name)."""
from __future__ import annotations

import sys
import time


MODULES = [
    "bench_partitioners",   # Fig 2
    "bench_migration",      # Fig 3
    "bench_spark_like",     # Fig 4
    "bench_overpartition",  # Fig 5
    "bench_streaming",      # Fig 6
    "bench_webcrawl",       # Fig 7/8
    "bench_sketches",       # §4 + extended paper
    "bench_moe",            # beyond paper: KIP expert placement
    "bench_kernels",        # Pallas hot paths
]


def main() -> None:
    import importlib

    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if only and only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name}/FAILED,0,{type(e).__name__}: {e}")
            continue
        for row_name, value, derived in rows:
            print(f"{row_name},{value:.6g},{derived}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark module(s) failed: {failures}")


if __name__ == "__main__":
    main()
