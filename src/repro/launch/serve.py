"""Serving driver: batched requests through the engine + DR session routing.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model
from repro.models.modules import Policy
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import DRScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    pol = Policy(attn_q_chunk=64, attn_kv_chunk=64)
    params = model.init_params(cfg, jax.random.PRNGKey(0), pol)

    rng = np.random.default_rng(0)
    # heavy-tailed session keys: a hot tenant drives 30% of traffic
    sessions = np.where(rng.random(args.requests) < 0.3, 7,
                        rng.integers(0, 1000, args.requests))
    sched = DRScheduler(args.replicas)
    engines = [ServeEngine(cfg, params, pol, slots=args.slots, max_len=64)
               for _ in range(args.replicas)]
    queues: list[list[Request]] = [[] for _ in range(args.replicas)]
    for i in range(args.requests):
        req = Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                      max_new_tokens=args.max_new, session_key=int(sessions[i]))
        r = sched.route(req.session_key, cost_tokens=args.max_new)
        queues[r].append(req)

    t0 = time.time()
    for r, (eng, q) in enumerate(zip(engines, queues)):
        eng.run(q, max_ticks=200)
        print(f"replica {r}: {len(q)} requests, {eng.tokens_out} tokens, "
              f"{eng.steps} ticks")
    print(f"routed={sched.routed} imbalance={sched.imbalance():.2f} "
          f"total {time.time()-t0:.1f}s")
    info = sched.checkpoint(sessions)
    print(f"DR checkpoint: {info}")


if __name__ == "__main__":
    main()
