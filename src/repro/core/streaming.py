"""Micro-batch streaming runtime with on-the-fly Dynamic Repartitioning.

The job graph is the paper's canonical stateful pipeline::

    source -> map -> [shuffle by key] -> stateful reduce (keyed state)

Per micro-batch the runtime executes the jitted shuffle step (which also
emits the DRW histograms and global loads), folds received records into the
keyed state, then gives the DRM a safe point.  If the DRM repartitions, the
jitted migrate step moves the keyed state before the next batch — the
Spark-style integration; setting ``checkpoint_interval > 1`` gates decisions
on checkpoint ticks, the Flink-style integration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.drm import DRConfig, DRMaster
from repro.core.hashing import DEFAULT_NUM_HOSTS, KEY_SENTINEL
from repro.core.partitioner import Partitioner, uniform_partitioner
from repro.core.shuffle import make_migrate_step, make_shuffle_step
from repro.core.state import empty_state, merge_into

__all__ = ["StreamingJob", "BatchMetrics"]


@dataclasses.dataclass
class BatchMetrics:
    batch: int
    imbalance: float            # measured per-partition record imbalance
    worker_imbalance: float     # per-worker (straggler view)
    repartitioned: bool
    relative_migration: float
    overflow: int
    state_rows: int
    wall_time_s: float
    reason: str


def _default_mesh(axis: str = "data") -> Mesh:
    n = len(jax.devices())
    return jax.make_mesh((n,), (axis,))


class StreamingJob:
    """Long-running stateful streaming job with DR.

    ``payload_dim`` is the record payload width (the reduce below is a
    per-key vector sum — the word-count family of stateful operators).
    """

    def __init__(
        self,
        *,
        num_partitions: int | None = None,
        mesh: Mesh | None = None,
        capacity_factor: float = 2.0,
        state_capacity: int = 4096,
        payload_dim: int = 1,
        dr: DRConfig | None = None,
        dr_enabled: bool = True,
        checkpoint_interval: int = 1,
        initial: Partitioner | None = None,
        hist_k: int = 64,
        seed: int = 0,
    ):
        self.mesh = mesh or _default_mesh()
        self.num_workers = self.mesh.shape["data"]
        self.num_partitions = num_partitions or self.num_workers
        assert self.num_partitions >= self.num_workers
        self.capacity_factor = capacity_factor
        self.state_capacity = state_capacity
        self.payload_dim = payload_dim
        self.dr_enabled = dr_enabled
        self.checkpoint_interval = checkpoint_interval
        self.seed = seed
        cfg = dr or DRConfig()
        heavy_cap = int(np.ceil(max(1.0, cfg.lam * self.num_partitions) / 128.0) * 128)
        part = initial or uniform_partitioner(
            self.num_partitions, DEFAULT_NUM_HOSTS, seed, heavy_capacity=heavy_cap
        )
        self.drm = DRMaster(part, cfg)
        self._shuffle = None
        self._migrate = None
        self._capacity = None
        # per-worker keyed state, stacked [W, S] / [W, S, D]
        sk, sv = empty_state(state_capacity, payload_dim)
        self.state_keys = jnp.tile(sk[None], (self.num_workers, 1))
        self.state_vals = jnp.tile(sv[None], (self.num_workers, 1, 1))
        self.metrics: list[BatchMetrics] = []
        self._merge = jax.jit(jax.vmap(lambda sk, sv, bk, bv, bva: merge_into(sk, sv, bk, bv, bva)))

    # ------------------------------------------------------------------
    def _build(self, local_n: int):
        cap = int(np.ceil(self.capacity_factor * local_n / self.num_workers / 8.0) * 8)
        if self._shuffle is not None and cap == self._capacity:
            return
        self._capacity = cap
        self._shuffle = make_shuffle_step(
            self.mesh,
            num_partitions=self.num_partitions,
            capacity=cap,
            num_hosts=self.drm.partitioner.num_hosts,
            seed=self.seed,
        )
        self._migrate = make_migrate_step(
            self.mesh,
            state_capacity=self.state_capacity,
            num_hosts=self.drm.partitioner.num_hosts,
            seed=self.seed,
        )

    # ------------------------------------------------------------------
    def process_batch(self, keys: np.ndarray, values: np.ndarray | None = None) -> BatchMetrics:
        """Run one micro-batch through shuffle + stateful reduce + DR."""
        t0 = time.perf_counter()
        n = len(keys)
        w = self.num_workers
        local_n = int(np.ceil(n / w))
        pad = local_n * w - n
        keys = np.concatenate([keys, np.full(pad, KEY_SENTINEL, np.int64)]).astype(np.int32)
        if values is None:
            values = np.ones((len(keys), self.payload_dim), np.float32)
        else:
            values = np.concatenate([values, np.zeros((pad,) + values.shape[1:], np.float32)])
        valid = keys != KEY_SENTINEL
        self._build(local_n * w)

        tables = self.drm.partitioner.tables()
        res = self._shuffle(tables, jnp.asarray(keys), jnp.asarray(values, jnp.float32), jnp.asarray(valid))

        # stateful reduce: fold received records into per-worker keyed state
        self.state_keys, self.state_vals, st_overflow = self._merge(
            self.state_keys, self.state_vals, res.keys, res.values, res.valid
        )

        # DRM: ingest DRW histograms + decide at the safe point
        loads = np.asarray(res.loads)
        self.drm.observe(np.asarray(res.hist_keys), np.asarray(res.hist_counts),
                         total_records=float(loads.sum()))
        worker_loads = loads.reshape(-1, self.num_workers).sum(axis=0) if self.num_partitions % self.num_workers == 0 else np.bincount(
            np.arange(self.num_partitions) % self.num_workers, weights=loads, minlength=self.num_workers
        )
        rel_mig = 0.0
        decision = None
        at_checkpoint = (len(self.metrics) + 1) % self.checkpoint_interval == 0
        if self.dr_enabled and at_checkpoint:
            decision = self.drm.decide(loads)
            if decision.repartition:
                out = self._migrate(self.drm.partitioner.tables(), self.state_keys, self.state_vals)
                kk, vv, kv_valid, rk, rv, rva, moved, total, mig_ov = out
                kept_keys = jnp.where(kv_valid, kk, KEY_SENTINEL)
                self.state_keys, self.state_vals, _ = self._merge(
                    kept_keys, vv, rk, rv, rva
                )
                rel_mig = float(moved) / max(float(total), 1e-9)

        m = BatchMetrics(
            batch=len(self.metrics),
            imbalance=float(loads.max() / max(loads.mean(), 1e-12)),
            worker_imbalance=float(worker_loads.max() / max(worker_loads.mean(), 1e-12)),
            repartitioned=bool(decision.repartition) if decision else False,
            relative_migration=rel_mig,
            overflow=int(res.overflow),
            state_rows=int(np.asarray(jax.vmap(lambda k: jnp.sum(k != KEY_SENTINEL))(self.state_keys)).sum()),
            wall_time_s=time.perf_counter() - t0,
            reason=decision.reason if decision else "dr-disabled",
        )
        self.metrics.append(m)
        return m

    # ------------------------------------------------------------------
    def run(self, batches: Iterable[np.ndarray]) -> list[BatchMetrics]:
        return [self.process_batch(b) for b in batches]

    # -- state inspection ----------------------------------------------
    def state_count(self, key: int) -> float:
        """Total aggregated value for one key across all workers (test hook)."""
        sk = np.asarray(self.state_keys)
        sv = np.asarray(self.state_vals)
        hit = sk == key
        return float(sv[hit].sum())

    # -- checkpoint / restore --------------------------------------------
    def snapshot(self) -> dict:
        return {
            "state_keys": np.asarray(self.state_keys),
            "state_vals": np.asarray(self.state_vals),
            **{f"drm_{k}": v for k, v in self.drm.snapshot().items()},
        }

    def restore(self, snap: dict) -> None:
        self.state_keys = jnp.asarray(snap["state_keys"])
        self.state_vals = jnp.asarray(snap["state_vals"])
        drm_snap = {k[4:]: v for k, v in snap.items() if k.startswith("drm_")}
        self.drm = DRMaster.restore(drm_snap, self.drm.config)
