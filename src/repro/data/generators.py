"""Synthetic workload generators mirroring the paper's datasets.

* ``zipf_keys``      — the ZIPF dataset: parametrized Zipfian key streams
  (100K distinct items, exponent 1..3 in the paper).
* ``drifting_zipf``  — LFM-like stream: Zipfian with the identity of the
  heavy keys re-drawn over time (concept drift), matching the Fig. 3
  protocol ("replacing keys with randomly generated strings in each round").
* ``host_skew_keys`` — web-crawl-like: few giant hosts, heavy-tailed rest
  (the §6 fetch-list workload).
* ``hotspot_flip``   — nonstationary: the whole heavy set goes cold at one
  batch boundary and a disjoint set goes hot (sharpest drift the EWMA
  sketch must survive).
* ``sawtooth_skew``  — nonstationary: imbalance flips across the elastic
  grow/shrink triggers every half-period (the oscillation-guard stress
  workload).
* ``lm_token_stream``— token batches for the LM data pipeline.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "zipf_keys",
    "drifting_zipf",
    "host_skew_keys",
    "hotspot_flip",
    "sawtooth_skew",
    "lm_token_stream",
]


def _zipf_probs(num_keys: int, exponent: float) -> np.ndarray:
    ranks = np.arange(1, num_keys + 1, dtype=np.float64)
    p = ranks ** (-exponent)
    return p / p.sum()


def zipf_keys(
    n: int,
    num_keys: int = 100_000,
    exponent: float = 1.0,
    seed: int = 0,
    key_space: int = 2**30,
) -> np.ndarray:
    """Sample ``n`` keys from a Zipf(num_keys, exponent) distribution.

    Key identities are scattered over ``key_space`` via a random permutation
    so rank order is uncorrelated with key value (as with hashed word
    tokens in the paper's MurmurHash3 setup).
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(num_keys, exponent)
    ranks = rng.choice(num_keys, size=n, p=probs)
    ids = rng.choice(key_space, size=num_keys, replace=False)
    return ids[ranks].astype(np.int64)


def drifting_zipf(
    num_batches: int,
    batch_size: int,
    num_keys: int = 10_000,
    exponent: float = 1.0,
    drift_every: int = 5,
    drift_fraction: float = 0.3,
    seed: int = 0,
):
    """Yield ``num_batches`` key batches; every ``drift_every`` batches a
    ``drift_fraction`` of the heaviest ranks get brand-new key identities.
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(num_keys, exponent)
    ids = rng.choice(2**30, size=num_keys, replace=False).astype(np.int64)
    for b in range(num_batches):
        if b > 0 and b % drift_every == 0:
            k = max(1, int(drift_fraction * num_keys))
            swap = rng.choice(num_keys, size=k, replace=False)
            ids[swap] = rng.choice(2**30, size=k, replace=False)
        ranks = rng.choice(num_keys, size=batch_size, p=probs)
        yield ids[ranks].copy()


def host_skew_keys(
    n: int,
    num_hosts: int = 64,
    giants: int = 4,
    giant_mass: float = 0.6,
    seed: int = 0,
) -> np.ndarray:
    """Web-crawl fetch-list keys: ``giants`` hosts own ``giant_mass`` of all
    pages; the rest follow Zipf(1.2) — the §6 distribution shape.
    """
    rng = np.random.default_rng(seed)
    tail = _zipf_probs(num_hosts - giants, 1.2) * (1.0 - giant_mass)
    head = np.full(giants, giant_mass / giants)
    probs = np.concatenate([head, tail])
    ids = rng.choice(2**30, size=num_hosts, replace=False)
    return ids[rng.choice(num_hosts, size=n, p=probs)].astype(np.int64)


def hotspot_flip(
    num_batches: int,
    batch_size: int,
    num_keys: int = 10_000,
    exponent: float = 1.5,
    flip_at: int | None = None,
    seed: int = 0,
):
    """Yield Zipf batches whose rank -> key-identity mapping is re-drawn
    *once*, at batch ``flip_at`` (default: the midpoint).

    Unlike ``drifting_zipf``'s gradual churn, this is the sharpest
    nonstationarity a controller faces: every isolated heavy key goes cold
    in a single batch boundary while a disjoint set goes hot, so the stale
    heavy table actively misroutes until the sketch decays and the policy
    re-triggers.
    """
    flip_at = num_batches // 2 if flip_at is None else flip_at
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(num_keys, exponent)
    ids = rng.choice(2**30, size=num_keys, replace=False).astype(np.int64)
    for b in range(num_batches):
        if b == flip_at:
            ids = rng.choice(2**30, size=num_keys, replace=False).astype(np.int64)
        ranks = rng.choice(num_keys, size=batch_size, p=probs)
        yield ids[ranks].copy()


def sawtooth_skew(
    num_batches: int,
    batch_size: int,
    num_keys: int = 10_000,
    exponent: float = 1.8,
    period: int = 2,
    seed: int = 0,
):
    """Yield batches alternating ``period`` hard-Zipf batches with
    ``period`` near-uniform batches.

    The measured imbalance flips across the elastic grow/shrink triggers
    every half-period, so a controller without hysteresis ping-pongs the
    partition count — the stress workload for the control plane's cooldown
    guard.  Key identities stay fixed across phases (the *load* is
    nonstationary, not the key population).
    """
    rng = np.random.default_rng(seed)
    ids = rng.choice(2**30, size=num_keys, replace=False).astype(np.int64)
    hot = _zipf_probs(num_keys, exponent)
    flat = np.full(num_keys, 1.0 / num_keys)
    for b in range(num_batches):
        probs = hot if (b // period) % 2 == 0 else flat
        ranks = rng.choice(num_keys, size=batch_size, p=probs)
        yield ids[ranks].copy()


def lm_token_stream(
    n_batches: int, batch: int, seq: int, vocab: int, seed: int = 0, exponent: float = 1.1
):
    """Zipfian token-id batches for LM training examples/smoke tests."""
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(min(vocab, 50_000), exponent)
    for _ in range(n_batches):
        toks = rng.choice(len(probs), size=(batch, seq), p=probs)
        yield toks.astype(np.int32)
