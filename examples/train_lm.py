"""End-to-end LM training with in-model DR (KIP expert placement).

Trains a reduced llama4-scout (MoE, top-1 routing — maximally skew-prone)
for a few hundred steps on CPU; the PlacementController rebalances experts
across EP shards at step boundaries whenever router traffic drifts.

For the full-size run on a TPU slice, drop --smoke:

    PYTHONPATH=src python examples/train_lm.py          # CPU smoke (default)
    PYTHONPATH=src python -m repro.launch.train \
        --arch llama4-scout-17b-a16e --steps 500        # full driver
"""
import subprocess
import sys

if __name__ == "__main__":
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "llama4-scout-17b-a16e",
        "--smoke",
        "--steps", "200",
        "--batch", "8",
        "--seq", "64",
        "--ckpt-dir", "/tmp/repro_train_ckpt",
        "--ckpt-every", "100",
        "--log-every", "20",
    ] + sys.argv[1:]
    raise SystemExit(subprocess.call(args))
