"""Exchange vocabulary: the *what* of a routed exchange, backend-free.

``ExchangeSpec`` describes the static shape of one exchange (lanes x
capacity over an optional mesh axis); ``Payload``/``SendInfo``/
``ExchangeResult`` describe what travels through it.  The *how* — which
transport moves the buffers — lives in :mod:`repro.exchange.backends`;
nothing in this module touches a collective.

Vocabulary:

* **lane** — one destination of the exchange: a worker shard for an
  all-to-all, or a local bucket (e.g. an expert) for a pure dispatch.
* **slot** — a record's stable rank within its lane (``dispatch_count``),
  which makes the scatter into the ``[L, capacity]`` send buffer
  collision-free.
* **capacity** — static rows per lane.  XLA collectives need static shapes,
  so lanes are padded to ``capacity`` and anything beyond it is *counted*
  (never silently lost) in ``SendInfo.overflow`` — per lane in
  ``SendInfo.lane_overflow``, summed in ``SendInfo.overflow``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ExchangeSpec",
    "ExchangeStats",
    "Payload",
    "SendInfo",
    "ExchangeResult",
    "take_from",
]


@dataclasses.dataclass(frozen=True)
class ExchangeStats:
    """Everything the control plane learns from one exchange, in one record.

    Constructed *by the plane* (:meth:`ExchangeResult.stats`, the shuffle's
    ``shuffle_stats`` / ``migrate_stats`` helpers) and handed whole to
    ``Telemetry.record_exchange(stats)`` — consumers never assemble the
    fields themselves, so a new measurement (``replica_rows`` here) does not
    ripple through every call site.

    * ``rows`` — rows the active transport measured moving (shipped).
    * ``padded_rows`` — rows the exchange *provisioned* (``spec.rows``);
      ``None`` means unpadded (= ``rows``).
    * ``occupied_rows`` — rows actually live in the shipped lanes; ``None``
      means fully occupied (= ``rows``).
    * ``lane_overflow`` — per-lane capacity drops (int array) or ``None``.
    * ``count_wall_s`` / ``ship_wall_s`` / ``hidden_wall_s`` — split-phase
      wall breakdown (blocking count, blocking ship, ship wall hidden
      behind host work).
    * ``backend`` — transport name the measurements belong to.
    * ``replica_rows`` — rows landed per partition from *split* hot keys
      (int array) or ``None`` when no key is split.
    """

    rows: int
    wall_s: float = 0.0
    padded_rows: int | None = None
    occupied_rows: int | None = None
    lane_overflow: np.ndarray | None = None
    count_wall_s: float | None = None
    ship_wall_s: float | None = None
    hidden_wall_s: float | None = None
    backend: str | None = None
    replica_rows: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """Static shape of one exchange: ``num_lanes`` destinations of
    ``capacity`` rows each, optionally crossed over mesh ``axis``.

    ``axis=None`` is a *local* exchange: records are bucketized into
    ``[num_lanes, capacity]`` buffers with no collective (MoE's second
    dispatch hop — per-expert batching on the receiving shard).
    """

    num_lanes: int
    capacity: int
    axis: str | None = None

    @property
    def rows(self) -> int:
        """Rows one exchange call *provisions* per worker
        (``num_lanes * capacity``) — the static accounting unit the control
        plane's telemetry records per call as the padded side of
        ``Telemetry.record_exchange``; the active backend's measured
        ``shipped_rows`` is the other side."""
        return self.num_lanes * self.capacity

    def resized(
        self, *, num_lanes: int | None = None, capacity: int | None = None
    ) -> "ExchangeSpec":
        """Re-derive the spec for a resized topology.

        Elastic resize (changing the lane count after a worker grow/shrink)
        and re-capacitating (a migration whose planned peak transfer differs
        from the last one) are both one-spec changes: everything downstream —
        bucketize buffers, the collective, unpack — follows from the spec.
        """
        return dataclasses.replace(
            self,
            num_lanes=self.num_lanes if num_lanes is None else int(num_lanes),
            capacity=self.capacity if capacity is None else int(capacity),
        )


class Payload(NamedTuple):
    """One array travelling through the exchange; ``fill`` pads empty slots."""

    data: jax.Array  # [n, ...] one row per record
    fill: int | float = 0


class SendInfo(NamedTuple):
    """Send-side bookkeeping — enough to reverse the exchange.

    ``take_from(buffers, send)`` gathers each record's row back out of
    lane-major buffers (the MoE combine / any request-response pattern).
    ``lane_overflow`` localizes capacity drops to the lane that filled up;
    records whose lane fell outside ``[0, num_lanes)`` have no lane to
    charge, so they appear in the summed ``overflow`` only.
    """

    lane: jax.Array           # int32[n] destination lane per record
    slot: jax.Array           # int32[n] rank within lane, -1 for invalid
    ok: jax.Array             # bool[n]  accepted into the send buffer
    overflow: jax.Array       # int32[]  local records dropped (all causes)
    lane_overflow: jax.Array = None  # int32[L] capacity drops per lane


class ExchangeResult(NamedTuple):
    valid: jax.Array     # bool[L, capacity] occupancy of the (received) buffer
    payloads: tuple      # each [L, capacity, ...], same order as the inputs
    send: SendInfo
    # rows the transport actually moved for this worker: the dense backend
    # ships the whole padded buffer (L * capacity), the ragged backend its
    # measured occupancy, a local exchange nothing.  0 until the collective
    # has run (a bare bucketize ships nothing).
    shipped_rows: jax.Array = None  # int32[]
    # count bookkeeping a request-response pattern reuses: ``lane_counts``
    # is the buffer occupancy this worker *sent* per lane (min(count, cap)),
    # ``recv_counts`` what each peer sent it — the ragged transport's
    # phase-1 exchange.  A response hop riding the same lanes backward
    # (``backhaul``) needs no second count phase: its send occupancy is
    # ``recv_counts`` and its receive sizes are ``lane_counts``.
    lane_counts: jax.Array = None  # int32[L] rows sent per lane
    recv_counts: jax.Array = None  # int32[L] rows received per peer
    # static per-payload pad values (the Payload.fill each buffer was built
    # with) so a ragged transport can initialize its receive buffers
    # bit-identically to what the dense collective would have shipped
    fills: tuple = ()

    def unpack(self):
        """Flatten lane-major buffers to record-major ``[L*capacity, ...]``."""
        l, c = self.valid.shape
        flat = tuple(p.reshape((l * c,) + p.shape[2:]) for p in self.payloads)
        return self.valid.reshape(-1), flat

    def stats(
        self,
        spec: ExchangeSpec | None = None,
        *,
        wall_s: float = 0.0,
        count_wall_s: float | None = None,
        ship_wall_s: float | None = None,
        hidden_wall_s: float | None = None,
        backend: str | None = None,
        replica_rows: np.ndarray | None = None,
    ) -> ExchangeStats:
        """The plane-constructed telemetry record for this exchange.

        Pulls every measurement the result already carries — shipped rows,
        lane occupancy, per-lane overflow — so the consumer only supplies
        what the plane cannot know: wall clocks, the backend name, and the
        host-side split accounting.  Blocks on the device scalars.
        """
        rows = int(self.shipped_rows) if self.shipped_rows is not None else 0
        if self.lane_counts is not None:
            occupied = int(np.sum(np.asarray(self.lane_counts)))
        else:
            occupied = int(np.sum(np.asarray(self.valid)))
        padded = spec.rows if spec is not None else int(self.valid.size)
        lane_ov = self.send.lane_overflow
        if lane_ov is not None:
            lane_ov = np.asarray(lane_ov)
        return ExchangeStats(
            rows=rows,
            wall_s=wall_s,
            padded_rows=padded,
            occupied_rows=occupied,
            lane_overflow=lane_ov,
            count_wall_s=count_wall_s,
            ship_wall_s=ship_wall_s,
            hidden_wall_s=hidden_wall_s,
            backend=backend,
            replica_rows=replica_rows,
        )


def take_from(buffers: jax.Array, send: SendInfo) -> jax.Array:
    """Gather each record's row from ``[L, capacity, ...]`` buffers, zeroing
    records that never made it into a slot (the reverse of ``bucketize``)."""
    rows = buffers[send.lane, jnp.where(send.ok, send.slot, 0)]
    mask = send.ok.reshape(send.ok.shape + (1,) * (rows.ndim - 1))
    return jnp.where(mask, rows, 0)
