"""llama4-scout-17b-a16e [moe]: 48L, d=5120, 40H (kv=8), vocab=202048,
MoE 16 experts top-1 every layer (d_ff_expert=8192, shared expert).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import ArchConfig, Block, MoESpec

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(Block("attn", "moe"),),
    moe=MoESpec(num_experts=16, top_k=1, d_ff_expert=8192, shared_expert=True),
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=False,
    subquadratic=False,
    notes="DR/KIP expert placement applies; long_500k skipped (full attention)",
)
