"""TP head-padding exactness: padded/replicated layouts must compute the
same function as the unpadded model (the DESIGN.md §6 argument, verified)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import head_layout
from repro.models.modules import Policy
from repro.models.xlstm import init_mlstm, mlstm_forward


@pytest.mark.parametrize("hq,hkv,tp", [
    (8, 8, 16), (56, 8, 16), (28, 4, 16), (40, 8, 16), (8, 1, 16),
    (32, 16, 16), (32, 32, 16), (64, 8, 16), (4, 4, 16), (8, 2, 4),
])
def test_head_layout_invariants(hq, hkv, tp):
    lay = head_layout(hq, hkv, tp)
    assert lay.hq_p % tp == 0 and lay.hkv_p % tp == 0
    assert lay.hq_p == lay.hkv_p * lay.qps
    # every real q head appears exactly once
    reals = [q for q in lay.q_map if q >= 0]
    assert sorted(reals) == list(range(hq))
    # each physical q position's kv slot maps to that q's real kv head
    for pos, rq in enumerate(lay.q_map):
        if rq < 0:
            continue
        phys_kv = pos // lay.qps
        assert lay.kv_map[phys_kv] == rq // (hq // hkv)
    # every real kv head is present
    assert set(lay.kv_map) == set(range(hkv))


def test_mlstm_padded_heads_match_unpadded():
    """mLSTM with dead-head padding == real-head model on shared weights."""
    d, heads = 32, 4
    key = jax.random.PRNGKey(0)
    pol = Policy()
    p_real = init_mlstm(key, d, heads, heads, dtype=jnp.float32)
    p_pad = init_mlstm(key, d, heads, 16, dtype=jnp.float32)
    # copy the real-head weights into the padded layout
    hd = (2 * d) // heads
    for name in ["wq", "wk", "wv"]:
        p_pad[name] = p_pad[name].at[:, :heads].set(p_real[name])
        p_pad[name] = p_pad[name].at[:, heads:].set(0.0)
    p_pad["w_if"] = p_pad["w_if"].at[:, :, :heads].set(p_real["w_if"])
    p_pad["b_if"] = p_pad["b_if"].at[:, :heads].set(p_real["b_if"])
    p_pad["down"] = jnp.zeros_like(p_pad["down"]).at[:heads].set(p_real["down"])
    for name in ["up", "conv_w", "conv_b"]:
        p_pad[name] = p_real[name]

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    y_real, _ = mlstm_forward(p_real, x, pol, chunk=8)
    y_pad, _ = mlstm_forward(p_pad, x, pol, chunk=8)
    np.testing.assert_allclose(np.asarray(y_real), np.asarray(y_pad), rtol=1e-5, atol=1e-5)
