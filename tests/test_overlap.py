"""Split-phase overlap: the pipelined StreamingJob is bit-identical to the
serial one.

The overlapped driver enqueues batch N's start phase, then batch N-1's
in-flight row ship + merge behind it, and blocks only on start outputs; the
serial driver runs the fused step.  Because the fused step is literally the
two phases traced back to back and every decision input comes out of the
start phase, the two drivers must produce identical trajectories — same
actions, same reasons, same overflow/shipped accounting, same final keyed
state — differing only in wall-clock attribution (``exchange_wall_s``,
``state_rows`` freshness).
"""
import numpy as np
import pytest

from repro.exchange import ExchangeStats
from repro.control import Telemetry
from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob


def _skewed_batches(num_batches=10, n=384, seed=0):
    """Zipf-ish stream: keeps the imbalance trigger firing."""
    rng = np.random.default_rng(seed)
    return [(rng.zipf(1.5, n) % 200).astype(np.int64) for _ in range(num_batches)]


def _run_job(overlap: bool, batches, **cfg_kw):
    cfg = DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1,
                   overlap_exchange=overlap, **cfg_kw)
    job = StreamingJob(num_partitions=8, state_capacity=2048, payload_dim=2,
                       dr=cfg, seed=0)
    ms = job.run(batches)
    return job, ms


def _trajectory(ms):
    return [(m.action, m.reason, m.repartitioned, m.resized, m.overflow,
             m.shipped_rows, m.padded_rows, m.backend, round(m.imbalance, 9),
             m.num_partitions) for m in ms]


def test_overlap_matches_serial_trajectory():
    batches = _skewed_batches()
    job_s, ms_s = _run_job(False, batches)
    job_o, ms_o = _run_job(True, batches)
    assert not any(m.overlapped for m in ms_s)
    assert all(m.overlapped for m in ms_o)
    assert _trajectory(ms_s) == _trajectory(ms_o)
    # the stream is skewed enough that state actually moved (the split
    # migrate path ran under overlap)
    assert any(m.repartitioned for m in ms_o)
    # identical final keyed state (state_count drains the in-flight merge)
    for key in range(0, 200, 13):
        assert job_o.state_count(key) == job_s.state_count(key)


def test_overlap_matches_serial_through_resize():
    """An explicit elastic resize at a safe point: the drain-before-action
    protocol keeps the cross-size migration identical to serial."""
    batches = _skewed_batches(num_batches=6)
    out = {}
    for overlap in (False, True):
        cfg = DRConfig(imbalance_trigger=10.0, overlap_exchange=overlap)
        job = StreamingJob(num_partitions=8, state_capacity=2048,
                           dr=cfg, seed=0)
        ms = [job.process_batch(batches[0]), job.process_batch(batches[1])]
        job.resize(16)
        ms += [job.process_batch(b) for b in batches[2:]]
        out[overlap] = (job, ms)
    ms_s, ms_o = out[False][1], out[True][1]
    assert _trajectory(ms_s) == _trajectory(ms_o)
    assert any(m.resized for m in ms_o)
    assert ms_o[-1].num_partitions == 16
    for key in range(0, 200, 13):
        assert out[True][0].state_count(key) == out[False][0].state_count(key)


def test_env_escape_hatch_forces_serial(monkeypatch):
    monkeypatch.setenv("REPRO_DISABLE_OVERLAP", "1")
    job, ms = _run_job(True, _skewed_batches(num_batches=3))
    assert not any(m.overlapped for m in ms)


def test_snapshot_mid_stream_drains_inflight():
    """A snapshot between batches must capture the in-flight merge: restore
    into a fresh job and the state matches the serial run exactly."""
    batches = _skewed_batches(num_batches=5)
    job_s, _ = _run_job(False, batches)
    job_o, _ = _run_job(True, batches)
    snap = job_o.snapshot()  # drains the pending finish
    job2 = StreamingJob(num_partitions=8, state_capacity=2048, payload_dim=2,
                        dr=DRConfig(overlap_exchange=True), seed=0)
    job2.restore(snap)
    for key in range(0, 200, 13):
        assert job2.state_count(key) == job_s.state_count(key)


def test_overlapped_batches_report_phase_walls():
    """Overlapped batches attribute wall to phases: the count wall is the
    batch's blocking exchange wall, and once a drain happens (an action
    fires) the window that follows carries hidden + ship walls, surfacing
    a nonzero overlap_fraction."""
    job, ms = _run_job(True, _skewed_batches())
    assert any(m.repartitioned for m in ms)  # at least one drain happened
    t = job.telemetry
    # window accumulators since the last safe point + the long-lived EWMA
    assert t.wall_ewma.get("dense", 0.0) > 0.0
    sig = t.snapshot(loads=np.ones(8), num_workers=1)
    assert sig.exchange_count_wall_s >= 0.0


def test_overlap_fraction_signal():
    """Unit-level: hidden / (hidden + ship), 0.0 when nothing was recorded
    (serial windows) and when only the fused wall was recorded."""
    t = Telemetry("test")
    sig = t.snapshot(loads=np.ones(2))
    assert sig.overlap_fraction == 0.0
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.5))  # fused serial record: no phases
    sig = t.snapshot(loads=np.ones(2))
    assert sig.overlap_fraction == 0.0
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.2, count_wall_s=0.2))
    t.record_exchange(ExchangeStats(rows=0, ship_wall_s=0.1, hidden_wall_s=0.3))
    sig = t.snapshot(loads=np.ones(2))
    assert sig.exchange_count_wall_s == pytest.approx(0.2)
    assert sig.exchange_ship_wall_s == pytest.approx(0.1)
    assert sig.exchange_hidden_wall_s == pytest.approx(0.3)
    assert sig.overlap_fraction == pytest.approx(0.75)


def test_backend_wall_ewma_accumulates_across_windows():
    t = Telemetry("test")
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.4, backend="dense"))
    t.snapshot(loads=np.ones(2))  # window reset must not clear the EWMA
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.2, backend="dense"))
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.1, backend="ragged"))
    sig = t.snapshot(loads=np.ones(2))
    assert sig.backend_wall_ewma["dense"] == pytest.approx(0.7 * 0.4 + 0.3 * 0.2)
    assert sig.backend_wall_ewma["ragged"] == pytest.approx(0.1)
