"""whisper-base [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings).  6L encoder + 6L decoder, d=512, 8H (kv=8), d_ff=2048,
vocab=51865.  [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, Block

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    pattern=(Block("attn", "dense"),),
    ffn_kind="gelu",
    norm_kind="layernorm",
    rope_kind="learned",
    encdec=True,
    enc_layers=6,
    enc_len=1500,
    tie_embeddings=True,
    subquadratic=False,
    notes="audio frontend is a stub: input_specs() provides [B, 1500, d] frame embeddings",
)
