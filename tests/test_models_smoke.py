"""Per-architecture smoke tests: reduced same-family config, one forward /
train-grad / prefill+decode step on CPU; asserts shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import model
from repro.models.modules import Policy

POL = Policy(attn_q_chunk=64, attn_kv_chunk=64)
B, S = 2, 64


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.float32)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, jax.random.PRNGKey(0), POL)
    batch = _batch(cfg, rng)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, cfg, POL), has_aux=True
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # a sane LM init sits near ln(vocab)
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch, rng):
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, jax.random.PRNGKey(1), POL)
    batch = _batch(cfg, rng)
    batch.pop("labels"), batch.pop("mask")

    logits, cache = model.prefill(params, batch, cfg, POL, max_len=S + 8)
    vp = logits.shape[-1]
    assert logits.shape == (B, 1, vp) and vp >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))

    tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, tok, cfg, POL)
        assert logits.shape == (B, 1, vp)
        assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
        tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "xlstm-125m", "gemma3-27b", "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode equals the parallel forward (cache correctness)."""
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, jax.random.PRNGKey(2), POL)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)

    # parallel logits at final position via prefill of the full sequence
    full, _ = model.prefill(params, {"tokens": toks}, cfg, POL, max_len=32)
    # incremental: prefill the first 15, then decode token 15
    pre, cache = model.prefill(params, {"tokens": toks[:, :15]}, cfg, POL, max_len=32)
    step, _ = model.decode_step(params, cache, toks[:, 15:16], cfg, POL)
    np.testing.assert_allclose(
        np.asarray(full[0, 0, : cfg.vocab_size]),
        np.asarray(step[0, 0, : cfg.vocab_size]),
        rtol=2e-3, atol=2e-3,
    )
