"""Shared benchmark utilities + the stage-time cost model.

The cost model mirrors the paper's Spark evaluation: a stage completes when
its slowest worker finishes, workers process partitions one after another
(over-partitioning => scheduling overhead per partition), and each record
costs per-record work (the NLP/NER tasks make this heavy and key-dependent).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Partitioner


def stage_time(
    partitioner: Partitioner,
    keys: np.ndarray,
    *,
    workers: int,
    per_record_us: float = 1.0,
    per_partition_overhead_us: float = 5_000.0,
    record_cost: np.ndarray | None = None,
    pinned: bool = False,
) -> float:
    """Simulated stage completion time (us) under the straggler model.

    ``pinned=False``: batch semantics — partitions are tasks, scheduled
    greedily (longest first) onto free workers (Spark dynamic scheduling).
    ``pinned=True``: streaming semantics — long-running operator instances,
    partition p is pinned to worker ``p % workers`` (the paper: "Flink
    deploys long-running tasks that cannot be scheduled one after another").
    """
    parts = partitioner.lookup_np(keys.astype(np.int32))
    n = partitioner.num_partitions
    if record_cost is None:
        loads = np.bincount(parts, minlength=n).astype(np.float64) * per_record_us
    else:
        loads = np.zeros(n)
        np.add.at(loads, parts, record_cost * per_record_us)
    loads += per_partition_overhead_us
    w = np.zeros(workers)
    if pinned:
        for p in range(n):
            w[p % workers] += loads[p]
    else:
        order = np.argsort(-loads)
        for p in order:
            w[w.argmin()] += loads[p]
    return float(w.max())


def timer(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us
