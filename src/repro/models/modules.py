"""Minimal pure-JAX module substrate: param init, norms, embeddings, acts.

Params are nested dicts of arrays.  ``init_*`` functions build real arrays
(smoke tests); the dry-run wraps them in ``jax.eval_shape`` so full-scale
models never allocate.  Sharding is injected from outside via a ``Shard``
policy callback (the model code stays mesh-agnostic).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
Shard = Callable[[Array, str], Array]  # (x, logical_name) -> constrained x


def no_shard(x: Array, name: str) -> Array:
    return x


@dataclasses.dataclass(frozen=True)
class Policy:
    """dtype + sharding policy threaded through the model."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    shard: Shard = no_shard
    tp: int = 1                      # model-axis size (head padding target)
    mesh: object = None              # jax Mesh (None = single-device ref paths)
    dp_axes: tuple = ("data",)       # batch axes ("pod","data") multi-pod
    tp_axis: str = "model"
    remat: bool = False
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 2048
    attn_block_skip: bool = True     # skip fully-masked kv blocks (static)
    attn_p_bf16: bool = False        # softmax weights in bf16 for the PV dot
    recurrent_bf16: bool = False     # bf16 gate/qkv precompute (ssm/xlstm)
    slstm_unroll: int = 1            # steps per sLSTM scan tick (§Perf)
    remat_policy: str = "nothing"    # "nothing" | "save_moe"
    moe_capacity_factor: float = 0.0  # 0 = use config value
    exchange_backend: object = None  # MoE dispatch transport: "dense" |
                                     # "ragged" | ExchangeBackend | None=auto

    def cast(self, x: Array) -> Array:
        return x.astype(self.compute_dtype)


def normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}  # gemma-style (1 + w)
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def apply_norm(p: dict, x: Array, kind: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        nx = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (nx * (1.0 + p["w"].astype(jnp.float32))).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    nx = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (nx * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def pad_vocab(v: int, mult: int = 256) -> int:
    return int(np.ceil(v / mult) * mult)


def init_embed(key, vocab: int, d: int, dtype) -> dict:
    vp = pad_vocab(vocab)
    return {"tok": normal(key, (vp, d), d**-0.5, dtype)}


def embed(p: dict, tokens: Array, *, scale: bool, d: int, pol: Policy) -> Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(pol.compute_dtype)
    if scale:
        x = x * jnp.asarray(np.sqrt(d), pol.compute_dtype)
    return x


def unembed_logits(x: Array, w: Array, pol: Policy) -> Array:
    """[..., d] @ [V, d]^T -> [..., V] (vocab sharded over model)."""
    out = jnp.einsum("...d,vd->...v", x, w.astype(pol.compute_dtype))
    return pol.shard(out, "logits")


# ---------------------------------------------------------------------------
# activations / ffn
# ---------------------------------------------------------------------------


def act_fn(kind: str):
    if kind in ("swiglu",):
        return jax.nn.silu
    if kind in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def init_ffn(key, d: int, f: int, kind: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    gate = 2 if kind in ("swiglu", "geglu") else 1
    return {
        "wi": normal(k1, (d, gate, f), d**-0.5, dtype),
        "wo": normal(k2, (f, d), f**-0.5, dtype),
    }


def apply_ffn(p: dict, x: Array, kind: str, pol: Policy) -> Array:
    wi = p["wi"].astype(pol.compute_dtype)
    h = jnp.einsum("bsd,dgf->bsgf", x, wi)
    h = pol.shard(h, "ffn_hidden4")
    a = act_fn(kind)
    if wi.shape[1] == 2:  # gated
        h = a(h[:, :, 0]) * h[:, :, 1]
    else:
        h = a(h[:, :, 0])
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(pol.compute_dtype))
    return out


# ---------------------------------------------------------------------------
# chunked cross-entropy (huge-vocab safe: never materializes [B, S, V])
# ---------------------------------------------------------------------------


def chunked_softmax_xent(
    x: Array,            # [B, S, d] final hidden states
    w_unembed: Array,    # [Vp, d]
    labels: Array,       # int32[B, S]
    mask: Array,         # bool/float [B, S]
    pol: Policy,
    vocab: int,
    chunk: int = 512,
    softcap: float = 0.0,
) -> Array:
    b, s, d = x.shape
    vp = w_unembed.shape[0]
    nchunk = max(1, s // chunk)
    assert s % nchunk == 0
    xc = x.reshape(b, nchunk, s // nchunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nchunk, s // nchunk).swapaxes(0, 1)
    mc = mask.reshape(b, nchunk, s // nchunk).swapaxes(0, 1)
    neg_inf = jnp.asarray(-1e30, jnp.float32)

    def body(carry, inp):
        xcb, lcb, mcb = inp
        logits = unembed_logits(xcb, w_unembed, pol).astype(jnp.float32)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where(jnp.arange(vp) < vocab, logits, neg_inf)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lcb[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * mcb
        return carry + jnp.sum(loss), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, mc))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
