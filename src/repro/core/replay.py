"""Batch-mode replay: repartition while data is still in the mapper buffers.

In a batch job the paper intervenes early: mapper output is buffered, a
histogram is taken over the first fraction of the input, KIPUPDATE builds a
better partitioner, and the *buffered* records are re-assigned (replayed)
before the shuffle — so the cost is one extra partition-assignment pass over
the buffer, not a re-execution of the mappers.

``replay_partition`` is that pass; :class:`BatchJob` drives measure -> update
-> replay -> shuffle for a static dataset.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.drm import DRConfig, DRMaster
from repro.core.histogram import Histogram
from repro.core.partitioner import Partitioner, kip_update, load_imbalance, uniform_partitioner

__all__ = ["replay_partition", "BatchJob", "BatchResult"]


def replay_partition(partitioner: Partitioner, buffered_keys: np.ndarray) -> np.ndarray:
    """Re-assign buffered mapper output under a new partitioner (the replay)."""
    return partitioner.lookup_np(np.asarray(buffered_keys, np.int32))


@dataclasses.dataclass(frozen=True)
class BatchResult:
    partitioner: Partitioner
    assignments: np.ndarray
    imbalance_before: float
    imbalance_after: float
    replayed_records: int
    sample_fraction: float


class BatchJob:
    """Static-dataset job: measure a small prefix, repartition once, replay.

    ``sample_fraction`` mirrors "a batch job is repartitioned only in an
    early stage of the execution so that the cost of replay does not exceed
    the expected gains".
    """

    def __init__(self, num_partitions: int, sample_fraction: float = 0.1, dr: DRConfig | None = None, seed: int = 0):
        self.num_partitions = num_partitions
        self.sample_fraction = sample_fraction
        self.cfg = dr or DRConfig(mode="batch")
        self.seed = seed

    def run(self, keys: np.ndarray) -> BatchResult:
        keys = np.asarray(keys)
        n = len(keys)
        uhp = uniform_partitioner(self.num_partitions, seed=self.seed)
        cut = max(1, int(self.sample_fraction * n))
        hist = Histogram.exact(keys[:cut]).top(int(self.cfg.lam * self.num_partitions))
        kip = kip_update(uhp, hist, eps=self.cfg.eps)
        before = load_imbalance(uhp, keys)
        after = load_imbalance(kip, keys)
        if after >= before:  # repartitioning must pay for the replay
            return BatchResult(uhp, replay_partition(uhp, keys), before, before, 0, self.sample_fraction)
        return BatchResult(kip, replay_partition(kip, keys), before, after, cut, self.sample_fraction)
