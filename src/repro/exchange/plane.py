"""The unified exchange plane: ``route -> bucketize -> all_to_all -> unpack``.

The paper's DR module works because repartitioning "reuses normal DDPS
communication".  This module is that communication, implemented once and
split **spec + backend**: an :class:`~repro.exchange.spec.ExchangeSpec`
names the static shape of one exchange (lanes x capacity over an optional
mesh axis), an :class:`~repro.exchange.backends.ExchangeBackend` moves the
buffers (dense capacity-padded, ragged count-first, or local no-collective),
and :class:`Exchange` binds the two for the consumers — the micro-batch
shuffle (``repro.core.shuffle``), operator-state migration
(``make_migrate_step``) and MoE expert dispatch (``repro.moe.layer``).
Following Partial Key Grouping / AutoFlow, the routing+exchange primitive is
the pluggable unit; the balancing policy (KIP, KIP placement, migration
planning) layers on top and never touches collectives directly — and the
backend's measured ``shipped_rows`` / ``cost`` feed the control plane, so
policy decisions price what the active transport would actually move.

The collective is **split-phase**: :meth:`Exchange.start` runs route +
bucketize + the transport's control phase (the ragged count all-to-all) and
returns an in-flight :class:`PendingExchange`; :meth:`Exchange.finish`
ships the payload rows and yields the final :class:`ExchangeResult`.
``Exchange.__call__`` is literally ``finish(start(...))`` — bit-identical
by construction — and everything the control plane reads (loads, overflow,
``shipped_rows``) is final at ``start``, so a driver can hold the pending
exchange and overlap the row ship with the next batch's routing and with
host-side policy decisions (see ``repro.core.streaming``).

All functions are pure jnp and run inside ``jit`` / ``shard_map``.  The
routing hot path has a fused Pallas kernel
(``repro.kernels.lookup_dispatch``, extended through bucketize by
``repro.kernels.route_bucketize``) with a bit-identical jnp twin; the twin
is the default off-TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax

import jax.numpy as jnp

from repro.core.hashing import KEY_SENTINEL
from repro.core.partitioner import PartitionerTables
from repro.exchange.backends import ExchangeBackend, resolve_backend
from repro.exchange.spec import (
    ExchangeResult,
    ExchangeSpec,
    ExchangeStats,
    ExchangeTopology,
    Payload,
    SendInfo,
    take_from,
)
from repro.kernels import ref as kref

__all__ = [
    "ExchangeSpec",
    "ExchangeStats",
    "ExchangeTopology",
    "Payload",
    "SendInfo",
    "ExchangeResult",
    "Exchange",
    "PendingExchange",
    "make_exchange",
    "route_dispatch",
    "route_bucketize",
    "take_from",
]


class PendingExchange(NamedTuple):
    """An exchange whose control phase ran but whose rows have not shipped.

    ``buffers`` is the bucketized :class:`ExchangeResult` with every
    control-plane field stamped by the backend's ``a2a_start`` —
    ``shipped_rows``, ``lane_counts``, ``recv_counts``, and the full
    ``send`` accounting are final and safe to consume; ``valid`` /
    ``payloads`` still hold the *send*-side buffers until
    :meth:`Exchange.finish` moves them.
    """

    buffers: ExchangeResult

    def stats(self, spec: ExchangeSpec | None = None, **kw) -> ExchangeStats:
        """Telemetry record from the control phase (all control-plane fields
        are final at ``start``; see :meth:`ExchangeResult.stats`)."""
        return self.buffers.stats(spec, **kw)


def route_dispatch(
    tables: PartitionerTables,
    keys: jax.Array,
    valid: jax.Array,
    *,
    num_hosts: int,
    seed: int,
    num_lanes: int,
    num_partitions: int = 0,
    use_pallas: bool | None = None,
    part_loads: jax.Array | None = None,
):
    """Fused key -> partition lookup + lane slot assignment.

    Returns ``(part[n], slot[n], counts[num_lanes])`` where ``slot`` ranks
    each valid record within its ``part % num_lanes`` lane and ``counts``
    is the per-lane occupancy the same pass already tallied — hand both to
    ``bucketize`` so it derives neither again (the ragged backend's count
    phase and the per-lane overflow both reuse them).  On TPU this is one
    fused Pallas kernel (``repro.kernels.lookup_dispatch``); elsewhere the
    bit-identical jnp twin.

    ``num_partitions > 0`` activates hot-key splitting: heavy keys with
    ``tables.heavy_repl > 1`` fan out over their replica partitions.  Leave
    it 0 (the default) to route every key to its home — the state-migration
    path *must*, since homes are where split partials converge and merge.

    ``part_loads`` (a ``[num_partitions]`` load vector, jnp path only)
    switches the split-replica pick from the stateless hash offset to the
    two-choice least-load tiebreak — see
    :func:`repro.kernels.ref.split_choice_ref`.  The Pallas kernel keeps
    the hash, so callers must gate ``use_pallas=False`` statically when
    they feed loads (asserted here).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" and part_loads is None
    if use_pallas:
        assert part_loads is None, (
            "the Pallas route kernel keeps the stateless hash replica pick; "
            "pass use_pallas=False to use the least-load tiebreak"
        )
        from repro.kernels import ops

        part, slot, counts = ops.route_slots(
            keys, valid, tables, num_hosts=num_hosts, seed=seed,
            num_lanes=num_lanes, num_partitions=num_partitions,
        )
    else:
        part, slot, counts = kref.lookup_dispatch_ref(
            keys, valid, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
            seed=seed, num_hosts=num_hosts, num_lanes=num_lanes,
            heavy_repl=tables.heavy_repl if num_partitions > 0 else None,
            num_partitions=num_partitions,
            part_loads=part_loads if num_partitions > 0 else None,
        )
    return part, slot, counts


def route_bucketize(
    exchange: "Exchange",
    tables: PartitionerTables,
    keys: jax.Array,
    valid: jax.Array,
    vals: jax.Array,
    *,
    num_hosts: int,
    seed: int,
    key_fill: int = KEY_SENTINEL,
    num_partitions: int = 0,
    use_pallas: bool | None = None,
    buffers: tuple | None = None,
    part_loads: jax.Array | None = None,
):
    """Fused route -> bucketize for the shuffle's ``(keys, vals, part)``
    payload triple.

    Returns ``(part, buffers)`` — the per-record partition ids plus a
    bucketized :class:`~repro.exchange.spec.ExchangeResult` ready for the
    collective.  On TPU the whole key -> partition -> lane -> slot ->
    send-buffer chain runs in one Pallas kernel
    (``repro.kernels.route_bucketize``) so the routed block never leaves
    VMEM between the route and the scatter; elsewhere it is
    :func:`route_dispatch` + ``bucketize`` — bit-identical by the kernel's
    ref-twin contract.

    ``buffers`` is the double-buffer reuse seam (see
    :meth:`Exchange.bucketize`): a recycled ``(valid_buf, payload_bufs)``
    set the jnp scatter resets and writes into.  The Pallas kernel writes
    its own kernel-managed outputs, so the seam is a no-op on that path —
    still bit-identical, just without the realloc saving.  ``part_loads``
    is the least-load split-replica feed (jnp path only, see
    :func:`route_dispatch`).
    """
    spec = exchange.spec
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" and part_loads is None
    if use_pallas:
        assert part_loads is None, (
            "least-load replica pick requires the jnp route path "
            "(use_pallas=False)"
        )
        from repro.kernels import ops

        part, slot, counts, buf_valid, bk, bv, bp = ops.route_bucketize(
            keys, valid, tables, vals,
            num_hosts=num_hosts, seed=seed,
            num_lanes=spec.num_lanes, capacity=spec.capacity, key_fill=key_fill,
            num_partitions=num_partitions,
        )
        lane = jnp.where(valid, part % spec.num_lanes, 0).astype(jnp.int32)
        ok = valid & (slot >= 0) & (slot < spec.capacity)
        # lanes are `part % L`, always in range: the capacity drops per lane
        # (and their sum, the scalar) fall out of the dispatch counts — the
        # same O(L) accounting the two-pass `_bucketize` counts path uses
        lane_overflow = jnp.maximum(counts - spec.capacity, 0).astype(jnp.int32)
        overflow = jnp.sum(lane_overflow).astype(jnp.int32)
        buffers = ExchangeResult(
            buf_valid, (bk, bv, bp),
            SendInfo(lane, slot, ok, overflow, lane_overflow),
            shipped_rows=jnp.zeros((), jnp.int32),
            lane_counts=jnp.minimum(counts, spec.capacity).astype(jnp.int32),
            fills=(key_fill, 0, 0),
        )
    else:
        part, slot, counts = route_dispatch(
            tables, keys, valid, num_hosts=num_hosts, seed=seed,
            num_lanes=spec.num_lanes, num_partitions=num_partitions,
            use_pallas=False, part_loads=part_loads,
        )
        dest = jnp.where(valid, part, 0)
        buffers = exchange.bucketize(
            dest % spec.num_lanes, valid,
            [Payload(keys, key_fill), Payload(vals, 0), Payload(dest, 0)],
            slot=slot, counts=counts, buffers=buffers,
        )
    return part, buffers


class Exchange:
    """One :class:`ExchangeSpec` bound to one :class:`ExchangeBackend`.

    Calling it runs the full ``bucketize -> all_to_all -> unpack`` sequence;
    ``bucketize`` alone builds the lane-major send buffers (local dispatch),
    and ``backhaul`` runs the reverse collective for request-response
    patterns (MoE combine).  The backend decides *how* buffers move and what
    ``shipped_rows`` the move costs; the call sites are identical across
    backends.
    """

    def __init__(self, spec: ExchangeSpec, backend: str | ExchangeBackend | None = None):
        self.spec = spec
        self.backend = resolve_backend(backend, spec)

    # -- step 2: capacity-padded send-buffer builder -----------------------
    def bucketize(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
        buffers: tuple | None = None,
    ) -> ExchangeResult:
        """Build the lane-major send buffers.

        ``buffers`` is the double-buffer reuse seam: a recycled
        ``(valid_buf, payload_bufs)`` set from a drained exchange that the
        scatter resets and writes into instead of allocating fresh — values
        bit-identical either way (see ``backends._bucketize``).
        """
        return self.backend.bucketize(
            self.spec, lane, valid, payloads, slot=slot, counts=counts,
            buffers=buffers,
        )

    # -- step 3: the collective (split-phase) ------------------------------
    def start(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
        buffers: tuple | None = None,
    ) -> PendingExchange:
        """Bucketize + run the transport's control phase; rows stay local.

        Every control-plane output (``send`` accounting, ``shipped_rows``,
        ``lane_counts``, ``recv_counts``) is final on the returned
        :class:`PendingExchange`; :meth:`finish` ships the payload rows.
        ``finish(start(...))`` is bit-identical to calling the exchange.
        ``buffers`` recycles a drained send-buffer set (see
        :meth:`bucketize`).
        """
        return self.start_from(self.bucketize(
            lane, valid, payloads, slot=slot, counts=counts, buffers=buffers))

    def start_from(self, buffers: ExchangeResult) -> PendingExchange:
        """Start the collective from already-bucketized buffers (the fused
        route path hands these in directly)."""
        return PendingExchange(self.backend.a2a_start(self.spec, buffers))

    def finish(self, pending: PendingExchange) -> ExchangeResult:
        """Ship the payload rows of a started exchange."""
        return self.backend.a2a_finish(self.spec, pending.buffers)

    def all_to_all(self, buffers: ExchangeResult) -> ExchangeResult:
        return self.backend.all_to_all(self.spec, buffers)

    def backhaul(
        self, buffers: jax.Array, forward: ExchangeResult | None = None
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Reverse collective for already-laned response buffers.

        ``forward`` is the exchanged result of the request hop; when it
        carries counts (the ragged transport's phase 1) the response ships
        compacted rows with no second count phase — the response occupancy
        *is* the forward ``recv_counts``, and what comes back is the forward
        ``lane_counts``.  Returns ``(rows, shipped_rows, occupied_rows)``:
        the response buffers, the rows this worker's transport measured
        moving, and the rows actually live in the shipped lanes (on the
        dense path shipped is the full pad while occupied tracks the counts
        — the honest utilization for ``Telemetry.record_exchange``).
        """
        send_counts = forward.recv_counts if forward is not None else None
        recv_counts = forward.lane_counts if forward is not None else None
        if send_counts is None and forward is not None:
            # a dense forward hop never ran a count phase, but its exchanged
            # valid mask is the same information: rows live in each received
            # lane — enough for the backhaul to report counted occupancy
            send_counts = jnp.sum(forward.valid, axis=-1).astype(jnp.int32)
        return self.backend.backhaul(
            self.spec, buffers, send_counts=send_counts, recv_counts=recv_counts
        )

    # -- the full primitive ------------------------------------------------
    def __call__(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
    ) -> ExchangeResult:
        # the fused call IS the split-phase pipeline run back to back —
        # bit-identity between the serial and overlapped drivers holds by
        # construction, not by parallel implementations
        return self.finish(self.start(lane, valid, payloads, slot=slot, counts=counts))


def make_exchange(
    spec: ExchangeSpec, backend: str | ExchangeBackend | None = None
) -> Exchange:
    """Build the exchange primitive for one static spec.

    ``backend`` selects the transport — ``"dense"`` / ``"ragged"`` /
    ``"local"``, an :class:`ExchangeBackend` instance, or ``None`` to
    auto-select (local when ``spec.axis is None``, else dense).
    """
    return Exchange(spec, backend)
