"""Lane health: the failure-domain view of the control plane.

:class:`LaneHealth` tracks, per *live* lane, an EWMA of observed straggle
wall and a consecutive-failed-windows counter — both fed from the fault
evidence the driver already records into :class:`~repro.control.signals
.Telemetry` (``record_fault`` -> ``Signals.lane_straggle_s`` /
``lane_retries``) during normal work, the DRW principle applied to health.

:class:`HealthPolicy` turns that state into typed actions at safe points,
*first* in ``DRMaster.evaluate``'s precedence (a sick lane invalidates
every load-based signal downstream):

* :class:`~repro.control.actions.Quarantine` — circuit-breaker open: a
  lane whose straggle EWMA stays past ``health_straggler_ms`` for
  ``health_patience`` consecutive safe points is folded out of the
  collective (its partitions re-land on the healthy workers via the
  modulo placement), with :class:`~repro.control.policy.CooldownGuard`
  hysteresis on ``health_cooldown`` and the fold priced through
  :func:`~repro.core.migration.exchange_lane_cost` like every other
  state-moving action.
* :class:`~repro.control.actions.Evict` — permanent loss: a lane whose
  exchanges keep *failing* (``health_failure_threshold`` consecutive
  failed windows) is removed for good.  Hard worker loss discovered by
  the recovery protocol takes this path too, recorded via
  ``DRMaster.note_lost``.
* :class:`~repro.control.actions.Recover` — half-open probe: after
  ``health_recover_after`` safe points in quarantine the oldest parked
  lane is re-admitted, priced by the fold-back migration against the
  fractional worker capacity regained.

Policies stay stateless evaluators over the host (``DRMaster``), which
carries the durable :class:`LaneHealth` record and the quarantine ledger —
both ride snapshots, so a restored job resumes the same health view.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.control.actions import Action, Evict, NoOp, Quarantine, Recover
from repro.control.policy import CooldownGuard
from repro.control.signals import Signals
from repro.core.migration import MigrationPlan, exchange_lane_cost

__all__ = ["HealthPolicy", "LaneHealth"]


@dataclasses.dataclass
class LaneHealth:
    """Per-live-lane health state (EWMA straggle + failure streaks).

    Indexed by *current* lane position: quarantine/evict drop a row
    (:meth:`drop_lane`), recover appends a fresh one (:meth:`add_lane`) —
    the same renumbering the driver's lane list undergoes, so row ``i``
    always describes live lane ``i``.
    """

    num_lanes: int
    alpha: float = 0.5
    wall_ewma: np.ndarray = None
    failures: np.ndarray = None
    sick_streak: np.ndarray = None

    def __post_init__(self):
        if self.wall_ewma is None:
            self.wall_ewma = np.zeros(self.num_lanes, np.float64)
        if self.failures is None:
            self.failures = np.zeros(self.num_lanes, np.int64)
        if self.sick_streak is None:
            self.sick_streak = np.zeros(self.num_lanes, np.int64)

    def observe(self, signals: Signals) -> None:
        """Fold one window's fault evidence.  A window with no evidence for
        a lane decays its EWMA toward zero (health is earned back) and
        resets its failure streak (failures must be *consecutive*)."""
        straggle = np.zeros(self.num_lanes, np.float64)
        if signals.lane_straggle_s is not None:
            v = np.asarray(signals.lane_straggle_s, np.float64)
            straggle[: min(len(v), self.num_lanes)] = v[: self.num_lanes]
        retries = np.zeros(self.num_lanes, np.int64)
        if signals.lane_retries is not None:
            v = np.asarray(signals.lane_retries, np.int64)
            retries[: min(len(v), self.num_lanes)] = v[: self.num_lanes]
        self.wall_ewma = (1.0 - self.alpha) * self.wall_ewma \
            + self.alpha * straggle
        self.failures = np.where(retries > 0, self.failures + 1, 0)

    def drop_lane(self, lane: int) -> None:
        keep = np.arange(self.num_lanes) != int(lane)
        self.wall_ewma = self.wall_ewma[keep]
        self.failures = self.failures[keep]
        self.sick_streak = self.sick_streak[keep]
        self.num_lanes -= 1

    def add_lane(self) -> None:
        self.wall_ewma = np.append(self.wall_ewma, 0.0)
        self.failures = np.append(self.failures, 0)
        self.sick_streak = np.append(self.sick_streak, 0)
        self.num_lanes += 1

    # -- checkpoint integration ------------------------------------------
    def snapshot(self) -> dict:
        return {
            "health_num_lanes": np.int64(self.num_lanes),
            "health_wall_ewma": np.asarray(self.wall_ewma, np.float64),
            "health_failures": np.asarray(self.failures, np.int64),
            "health_sick_streak": np.asarray(self.sick_streak, np.int64),
        }

    @classmethod
    def restore(cls, snap: dict, alpha: float = 0.5) -> "LaneHealth":
        return cls(
            num_lanes=int(snap["health_num_lanes"]),
            alpha=alpha,
            wall_ewma=np.asarray(snap["health_wall_ewma"], np.float64).copy(),
            failures=np.asarray(snap["health_failures"], np.int64).copy(),
            sick_streak=np.asarray(snap["health_sick_streak"],
                                   np.int64).copy(),
        )


def _fold_cost(host, num_workers: int, lane: int) -> float:
    """Price the quarantine fold: the sick lane's fair state share (1/W of
    the mass) spreads evenly over the W-1 survivors, costed by the active
    transport's sizing rule — the same ``exchange_lane_cost`` accounting
    every other state-moving policy prices with."""
    w = int(num_workers)
    if w <= 1:
        return 0.0
    transfer = np.zeros((w, w))
    transfer[lane, :] = (1.0 / w) / (w - 1)
    transfer[lane, lane] = 0.0
    dst = np.asarray([d for d in range(w) if d != lane], np.int32)
    plan = MigrationPlan(
        keys=np.zeros(w - 1, np.int64),
        src=np.full(w - 1, lane, np.int32),
        dst=dst,
        weights=np.full(w - 1, (1.0 / w) / (w - 1)),
        transfer=transfer,
        relative_migration=1.0 / w,
        num_src=w, num_dst=w,
    )
    return exchange_lane_cost(
        plan,
        backend=getattr(host, "exchange_backend", None),
        topology=getattr(host, "exchange_topology", None),
    )


class HealthPolicy:
    """Failure-domain policy over :class:`LaneHealth` (see module doc)."""

    def evaluate(self, host, signals: Signals) -> Action:
        cfg = host.config
        imb = signals.imbalance
        if not getattr(cfg, "health_enabled", False):
            return NoOp("health-disabled", imb, imb)
        lh = host.lane_health
        if lh is None or lh.num_lanes == 0:
            return NoOp("health-no-telemetry", imb, imb)
        w = max(int(signals.num_workers), 1)
        guard = CooldownGuard(cfg.health_cooldown)

        sick_fail = lh.failures >= cfg.health_failure_threshold
        sick_slow = lh.wall_ewma * 1e3 >= cfg.health_straggler_ms
        sick = sick_fail | sick_slow
        lh.sick_streak = np.where(sick, lh.sick_streak + 1, 0)
        if sick.any():
            # the sickest lane first: hard-failing beats merely slow
            score = (sick_fail.astype(np.float64) * 1e9
                     + lh.failures * 1e6 + lh.wall_ewma * 1e3)
            lane = int(np.argmax(np.where(sick, score, -1.0)))
            streak = int(lh.sick_streak[lane])
            if streak < cfg.health_patience:
                return NoOp(f"health-patience {streak}/{cfg.health_patience}",
                            imb, imb)
            if not guard.ready(host.batches_seen, host.last_health_action):
                return NoOp("health-cooldown", imb, imb)
            if w <= 1:
                # the last lane cannot be folded anywhere — the recovery
                # protocol (restore + replay in place) is the only move
                return NoOp("health-single-worker", imb, imb)
            failures = int(lh.failures[lane])
            if sick_fail[lane]:
                return Evict(
                    reason=(f"evict lane {lane}: {failures} consecutive "
                            f"failed windows (>= "
                            f"{cfg.health_failure_threshold})"),
                    lane=lane, failures=failures)
            straggle_ms = float(lh.wall_ewma[lane] * 1e3)
            return Quarantine(
                reason=(f"quarantine lane {lane}: straggle EWMA "
                        f"{straggle_ms:.1f}ms >= "
                        f"{cfg.health_straggler_ms:.1f}ms"),
                lane=lane, straggle_ms=straggle_ms, failures=failures,
                est_migration=_fold_cost(host, w, lane))

        # circuit breaker half-open: probe the oldest quarantined lane
        if host.quarantined and cfg.health_recover_after > 0:
            lane_label, since = host.quarantined[0]
            waited = host.batches_seen - int(since)
            if waited < cfg.health_recover_after:
                return NoOp(
                    f"health-probe-timer {waited}/{cfg.health_recover_after}",
                    imb, imb)
            if not guard.ready(host.batches_seen, host.last_health_action):
                return NoOp("health-cooldown", imb, imb)
            # priced re-admission: the fold-back ships the re-admitted
            # lane's fair share (1/(W+1) of the mass); the capacity regained
            # is one worker's fractional budget — decline when the move
            # costs more than the relief it buys
            est = (cfg.migration_cost_weight
                   * _fold_cost(host, w + 1, w))
            relief = 1.0 / (w + 1)
            if est > relief:
                return NoOp(f"health-recover-cost {est:.3f}>{relief:.3f}",
                            imb, imb)
            return Recover(
                reason=(f"recover lane {lane_label} after {waited} "
                        f"quarantined safe points"),
                lane=int(lane_label), est_migration=est)
        return NoOp("health-ok", imb, imb)
