"""TPU v5e hardware constants (the target platform of the dry-run)."""

PEAK_FLOPS_BF16 = 197e12   # FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
HBM_BYTES = 16 * 2**30     # per chip
