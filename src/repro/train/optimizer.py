"""AdamW in pure JAX with global-norm clipping and configurable moment
dtypes (bf16 moments for the >=100B archs — memory math in DESIGN.md §6)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: jnp.dtype = jnp.float32
    warmup: int = 100


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init_opt(params, cfg: OptConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
