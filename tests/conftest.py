"""Test-suite bootstrap.

If the real ``hypothesis`` package is unavailable (minimal containers), a
small deterministic stand-in is installed before the test modules import it:
``@given`` draws a fixed, seeded sample of each strategy and runs the test
once per example (no shrinking, no database).  With hypothesis installed
this file does nothing.
"""
from __future__ import annotations

import sys
import types
import zlib

try:  # pragma: no cover - prefer the real thing when present
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=2**30, **_):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def lists(elem, min_size=0, max_size=10, unique=False, **_):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 20 * (n + 1):
                tries += 1
                v = elem.draw(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

        return _Strategy(draw)

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # zero-arg wrapper (and no __wrapped__) so pytest does not
            # mistake the drawn parameters for fixtures
            def wrapper():
                n = min(getattr(wrapper, "_stub_max_examples", 10), 20)
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**drawn)
                    except Exception:
                        print(f"falsifying example: {drawn}", file=sys.stderr)
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
