"""Hash primitives shared by host (numpy) and device (jnp) code paths.

The paper's weighted hash partitioner first maps keys to one of ``H >> N``
virtual *hosts* by uniform hashing, then maps hosts to partitions via a small
routing table.  We use a murmur3-style 32-bit finalizer (``fmix32``) as the
uniform hash; it is written against a generic array namespace so the exact
same bit pattern is produced by numpy on the host (DRM planning) and by jnp
on device (shuffle hot path and Pallas kernels).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["fmix32", "hash_to_host", "DEFAULT_NUM_HOSTS", "KEY_SENTINEL"]

# Number of virtual hosts H.  H >> N for every realistic partition count and
# a power of two so the modulo lowers to a mask on TPU.
DEFAULT_NUM_HOSTS = 4096

# int32 padding sentinel for fixed-width heavy-key tables (larger than any
# real key; keys are required to be non-negative int32).
KEY_SENTINEL = np.int32(2**31 - 1)


def fmix32(x, xp=jnp):
    """murmur3 32-bit finalizer — a full-avalanche integer mixer.

    Works on uint32 arrays for either ``xp=numpy`` or ``xp=jax.numpy`` with
    identical results.
    """
    x = xp.asarray(x).astype(xp.uint32)
    x = x ^ (x >> xp.uint32(16))
    x = x * xp.uint32(0x85EBCA6B)
    x = x ^ (x >> xp.uint32(13))
    x = x * xp.uint32(0xC2B2AE35)
    x = x ^ (x >> xp.uint32(16))
    return x


def hash_to_host(keys, num_hosts: int, seed: int = 0, xp=jnp):
    """Uniformly hash ``keys`` (int) to ``[0, num_hosts)``.

    ``num_hosts`` should be a power of two (masked, not modulo, on TPU).
    """
    k = xp.asarray(keys).astype(xp.uint32) ^ xp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF)
    h = fmix32(k, xp=xp)
    if num_hosts & (num_hosts - 1) == 0:
        return (h & xp.uint32(num_hosts - 1)).astype(xp.int32)
    return (h % xp.uint32(num_hosts)).astype(xp.int32)


def hash_mod(keys, n: int, seed: int = 0, xp=jnp):
    """Plain uniform-hash-partitioner assignment: fmix32(key) mod n."""
    k = xp.asarray(keys).astype(xp.uint32) ^ xp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF)
    return (fmix32(k, xp=xp) % xp.uint32(n)).astype(xp.int32)
