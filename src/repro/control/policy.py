"""Composable policies: Signals in, typed Actions out.

Mapping onto the paper's §4 decision rules:

* :class:`RepartitionPolicy` — §4's core trigger: repartition when the
  measured imbalance exceeds the trigger *and* "the gains for repartitioning
  exceed state migration costs".  The migration cost is estimated with the
  *active exchange backend's* sizing rule
  (:func:`repro.core.migration.exchange_lane_cost` over
  ``host.exchange_backend`` — the dense transport pads every lane to the
  peak, a ragged transport averages real rows) evaluated on the candidate
  plan — real exchange-lane accounting instead of the old
  heavy-key-frequency sum.  With a ``host.exchange_topology`` the estimate
  is locality-priced: inter-host cells of the candidate transfer weigh
  ~10x intra-host ones, so equal-balance plans that keep rows inside a
  host win.
* :class:`ResizePolicy` — the same trigger one level up: sustained imbalance
  beyond what KIP can spread over the current bins grows the topology;
  sustained balance (or per-worker throughput below the capacity target —
  an idle stream that happens to be balanced) shrinks it.  Guarded by
  :class:`CooldownGuard` hysteresis on top of the patience streaks and the
  ``shrink_trigger < grow_trigger`` dead zone.
* :class:`PlacementPolicy` — §4 for experts: shard-load imbalance from
  router statistics triggers a KIP re-placement, with the same cooldown
  guard (``min_steps_between``) spacing weight migrations.
* :class:`SplitPolicy` — Partial-Key-Grouping as a control-plane action:
  when the *single hottest* key's share of the load exceeds one worker's
  fair budget (``split_trigger``), no repartition can help — isolation can
  only *move* the key, splitting *shrinks* it.  The policy replicates the
  key over ``d`` consecutive partitions (the route kernels fan records out
  by a per-record hash) and prices the move like every other action: the
  load relief ``share * (1 - 1/d)`` must pay for the merge-backhaul lane
  cost (:func:`~repro.core.migration.exchange_lane_cost` on the replica ->
  home transfer the eventual combiner-side merge ships).  A cooled-down
  key is collapsed back (``unsplit_trigger``; the gap to ``split_trigger``
  is the dead zone) through an ordinary home-routed migration whose
  ``merge_into`` sums the scattered partials.  Patience streak +
  :class:`CooldownGuard` (``split_cooldown``) give the same hysteresis as
  the resize/backend policies.
* :class:`BackendPolicy` — the transport as an actuator: when the measured
  ``exchange_padding_fraction`` (occupied / provisioned rows) stays low, a
  dense job is shipping padding the ragged count-first transport would
  skip — flip it; when a ragged job's fraction nears 1.0 the count phase
  buys nothing — flip back.  The thresholds leave a dead zone and a
  :class:`CooldownGuard` (``DRConfig.backend_cooldown``) adds hysteresis on
  top of the patience streak, so dense <-> ragged never ping-pongs on a
  workload that straddles a threshold.

Policies are stateless evaluators over a *host* (``DRMaster`` or
``PlacementController``) that carries the durable decision state (sketch,
streaks, last-action ticks) so snapshots keep working unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.control.actions import (
    Action,
    NoOp,
    Repartition,
    Replace,
    Resize,
    Split,
    SwitchBackend,
    Unsplit,
)
from repro.control.signals import Signals
from repro.core.migration import MigrationPlan, exchange_lane_cost, plan_migration
from repro.core.partitioner import expected_loads, heavy_capacity_for, kip_update

__all__ = [
    "CooldownGuard",
    "RepartitionPolicy",
    "ResizePolicy",
    "PlacementPolicy",
    "BackendPolicy",
    "SplitPolicy",
]


@dataclasses.dataclass(frozen=True)
class CooldownGuard:
    """Hysteresis shared by every state-moving policy: at least ``min_gap``
    safe points must pass since the last action before the next may fire.

    Patience streaks decide *whether* a condition is sustained; the guard
    decides whether acting on it is *allowed yet*.  A declined action keeps
    its streak, so once the cooldown expires a still-sustained condition
    fires immediately.  ``min_gap=0`` disables the guard (the pre-control-
    plane behavior)."""

    min_gap: int = 0

    def ready(self, tick: int, last_action_tick: int) -> bool:
        return self.min_gap <= 0 or (tick - last_action_tick) >= self.min_gap


class RepartitionPolicy:
    """§4 trigger + exchange-lane-costed migration gate (see module doc)."""

    def evaluate(self, host, signals: Signals) -> Action:
        """One safe-point decision.  Mirrors the DRM bookkeeping exactly:
        advances ``host.batches_seen`` whether or not anything fires, so the
        safe-point spacing rule keeps its pre-refactor meaning."""
        cfg = host.config
        host.batches_seen += 1
        measured = signals.imbalance
        n = host.partitioner.num_partitions

        hist = host.sketch.histogram(top_b=int(cfg.lam * n))
        if len(hist) == 0:
            return NoOp("no-histogram", measured, measured, 0.0)
        if host.batches_seen - host.last_repartition < cfg.min_batches_between:
            return NoOp("safe-point-spacing", measured, measured, 0.0)
        if cfg.mode == "batch" and host.last_repartition > 0:
            return NoOp("batch-replayed-once", measured, measured, 0.0)
        if measured < cfg.imbalance_trigger:
            return NoOp("balanced", measured, measured, 0.0)

        # fixed heavy-table width => stable jit signatures across swaps
        cap = heavy_capacity_for(cfg.lam, n,
                                 floor=host.partitioner.heavy_keys.shape[0])
        candidate = kip_update(host.partitioner, hist, eps=cfg.eps,
                               heavy_capacity=cap, tight=cfg.tight)
        planned = expected_loads(candidate, hist)
        planned_imb = float(planned.max() * n)
        gain = measured - planned_imb
        # migration cost from exchange-lane accounting: the peak (src, dst)
        # lane mass x slack the candidate plan would make migration_capacity
        # provision, on the frequency-weighted plan (same O(1) scale as gain).
        # Sketch keys are diffed exactly; the untracked tail rides the host
        # tables, so each re-binned host carries an equal share of tail mass
        # (the same uniform-tail model KIP's load bound uses).
        plan = plan_migration(host.partitioner, candidate, hist.keys,
                              state_weights=hist.freqs)
        transfer = plan.transfer.copy()
        old_hp = host.partitioner.host_to_part
        new_hp = candidate.host_to_part
        moved = old_hp != new_hp
        if moved.any() and hist.tail_mass > 0:
            np.add.at(transfer, (old_hp[moved], new_hp[moved]),
                      hist.tail_mass / len(old_hp))
        plan = dataclasses.replace(plan, transfer=transfer)
        est = exchange_lane_cost(plan, num_workers=signals.num_workers,
                                 backend=getattr(host, "exchange_backend", None),
                                 topology=getattr(host, "exchange_topology", None))
        cost = cfg.migration_cost_weight * est
        if gain <= cost:
            return NoOp(f"gain {gain:.3f} <= cost {cost:.3f}",
                        measured, planned_imb, est)
        return Repartition(
            reason="repartition",
            partitioner=candidate,
            prev=host.partitioner,
            planned_imbalance=planned_imb,
            measured_imbalance=measured,
            est_migration=est,
        )


class ResizePolicy:
    """Elastic grow/shrink: sustained imbalance or idle throughput (see
    module doc).  Streak state lives on the host (``grow_streak`` /
    ``shrink_streak``) so snapshots carry it."""

    def evaluate(self, host, signals: Signals) -> Action:
        cfg = host.config
        if not cfg.elastic:
            return NoOp("elastic-disabled")
        n = host.partitioner.num_partitions
        imb = signals.imbalance
        floor = max(cfg.min_partitions, signals.num_workers)
        # throughput below the capacity target: the stream is idle even if
        # balanced — over-partitioning is pure overhead (ROADMAP signal)
        low_throughput = (
            cfg.target_throughput > 0.0
            and signals.throughput > 0.0
            and signals.per_worker_throughput < cfg.target_throughput
        )
        guard = CooldownGuard(cfg.resize_cooldown)
        if imb >= cfg.grow_trigger and n < cfg.max_partitions:
            host.grow_streak += 1
            host.shrink_streak = 0
            if host.grow_streak >= cfg.resize_patience:
                if not guard.ready(host.batches_seen, host.last_resize):
                    return NoOp("resize-cooldown", imb, imb)
                host.grow_streak = 0
                target = min(n * cfg.resize_factor, cfg.max_partitions)
                return Resize(reason=f"resize {n}->{target}", target=target)
            return NoOp(f"grow-patience {host.grow_streak}/{cfg.resize_patience}",
                        imb, imb)
        elif ((imb <= cfg.shrink_trigger
               or (low_throughput and imb < cfg.grow_trigger)) and n > floor):
            # the low-throughput shrink covers the trigger dead zone only —
            # a hot-spotted stream pinned at max_partitions must never be
            # shrunk onto fewer bins just because it is also idle
            host.shrink_streak += 1
            host.grow_streak = 0
            if host.shrink_streak >= cfg.resize_patience:
                if not guard.ready(host.batches_seen, host.last_resize):
                    return NoOp("resize-cooldown", imb, imb)
                host.shrink_streak = 0
                target = max(n // cfg.resize_factor, floor)
                return Resize(reason=f"resize {n}->{target}", target=target)
            return NoOp(f"shrink-patience {host.shrink_streak}/{cfg.resize_patience}",
                        imb, imb)
        else:
            host.grow_streak = host.shrink_streak = 0
        if imb >= cfg.grow_trigger:
            return NoOp("at-max", imb, imb)
        if imb <= cfg.shrink_trigger or low_throughput:
            return NoOp("at-floor", imb, imb)
        return NoOp("dead-zone", imb, imb)


class SplitPolicy:
    """Hot-key splitting / un-splitting over the DRM sketch (see module doc).

    Streak state lives on the host (``split_streak``, ``last_split``, and
    the installed ``split_keys`` replica map) so snapshots carry it.  The
    policy only *decides*; the host stamps the replica table
    (``Partitioner.with_splits``) on a taken :class:`Split`, and the driver
    executes a taken :class:`Unsplit` as a home-routed state migration
    whose ``merge_into`` is the combiner-side merge.

    How a split key's records spread over its replicas is the *route's*
    business, not this policy's: the default is the stateless fmix32 pick
    (kernel and jnp twin, bit-identical), and ``DRConfig.split_least_load``
    upgrades the twin to Partial-Key-Grouping's two-choice least-load
    tiebreak fed with the previous batch's measured loads at safe points
    (``kernels.ref.split_choice_ref``).  The policy's decision inputs —
    sketch shares, fair budget, streaks — are identical either way, so a
    split fires at the same safe point under both picks.
    """

    def evaluate(self, host, signals: Signals) -> Action:
        cfg = host.config
        imb = signals.imbalance
        if not cfg.split_keys_enabled:
            return NoOp("split-disabled", imb, imb)
        n = host.partitioner.num_partitions
        hist = host.sketch.histogram(top_b=int(cfg.lam * n))
        if len(hist) == 0:
            return NoOp("split-no-histogram", imb, imb)
        splits = host.split_keys
        guard = CooldownGuard(cfg.split_cooldown)
        # share = a key's load in fair-worker-budget units: freq * N is 1.0
        # when the key fills exactly one partition's even share
        share = {int(k): float(f) * n for k, f in zip(hist.keys, hist.freqs)}

        # unsplit first: a cooled-down key collapses (freeing its replicas
        # and merging its partials) before any new split may fire
        for k in sorted(splits):
            if share.get(k, 0.0) < cfg.unsplit_trigger:
                host.split_streak += 1
                if host.split_streak < cfg.split_patience:
                    return NoOp(
                        f"split-patience {host.split_streak}/{cfg.split_patience}",
                        imb, imb)
                if not guard.ready(host.batches_seen, host.last_split):
                    return NoOp("split-cooldown", imb, imb)
                return Unsplit(
                    reason=(f"unsplit key {k} (share {share.get(k, 0.0):.2f} < "
                            f"{cfg.unsplit_trigger})"),
                    key=k, prev=host.partitioner)

        # split: the hottest not-yet-split key whose load alone exceeds one
        # worker's budget — beyond this point moving the key cannot balance
        top_key, top_share = None, 0.0
        for k, f in zip(hist.keys, hist.freqs):
            if int(k) not in splits:
                top_key, top_share = int(k), float(f) * n
                break
        if top_key is None or top_share <= cfg.split_trigger or n < 2:
            host.split_streak = 0
            return NoOp(f"split-dead-zone {top_share:.2f}", imb, imb)
        host.split_streak += 1
        if host.split_streak < cfg.split_patience:
            return NoOp(f"split-patience {host.split_streak}/{cfg.split_patience}",
                        imb, imb)
        if not guard.ready(host.batches_seen, host.last_split):
            return NoOp("split-cooldown", imb, imb)
        # enough replicas to bring the key's per-replica share under budget
        d = int(min(max(2, int(np.ceil(top_share))), cfg.split_max_replicas, n))
        home = int(host.partitioner.lookup_np(
            np.asarray([top_key], np.int32))[0])
        # price the move like every other action: the relief (load shed off
        # the home worker) must pay for the merge backhaul the split commits
        # to — each replica eventually ships its partial aggregate home, a
        # replica -> home transfer of f/d mass, costed by the active
        # transport's sizing rule exactly like a repartition plan
        f = top_share / n
        transfer = np.zeros((n, n))
        repls = (home + np.arange(1, d)) % n
        np.add.at(transfer, (repls, np.full(d - 1, home)), f / d)
        plan = MigrationPlan(
            keys=np.full(d - 1, top_key, np.int64),
            src=repls.astype(np.int32),
            dst=np.full(d - 1, home, np.int32),
            weights=np.full(d - 1, f / d),
            transfer=transfer,
            relative_migration=0.0,
            num_src=n, num_dst=n,
        )
        est = exchange_lane_cost(plan, num_workers=signals.num_workers,
                                 backend=getattr(host, "exchange_backend", None),
                                 topology=getattr(host, "exchange_topology", None))
        relief = top_share * (1.0 - 1.0 / d)
        cost = cfg.migration_cost_weight * est
        if relief <= cost:
            return NoOp(f"split relief {relief:.3f} <= cost {cost:.3f}",
                        imb, imb, est)
        return Split(
            reason=(f"split key {top_key} x{d} (share {top_share:.2f} > "
                    f"{cfg.split_trigger})"),
            key=top_key, replicas=d, home=home,
            top_share=top_share, est_relief=relief, est_migration=est,
        )


class BackendPolicy:
    """Dense <-> ragged transport selection over the measured lane occupancy
    (see module doc).  Streak state lives on the host (``backend_streak``,
    ``last_backend_switch``) so snapshots carry it; the host installs a
    taken switch via ``note_backend_switch`` so its plan pricing
    (``exchange_lane_cost``) immediately follows the new transport."""

    def evaluate(self, host, signals: Signals) -> Action:
        cfg = host.config
        imb = signals.imbalance
        if not cfg.auto_backend:
            return NoOp("auto-backend-disabled", imb, imb)
        frac = signals.exchange_padding_fraction
        if signals.exchange_padded_rows <= 0:
            # no exchange ran this window: nothing measured, keep the streak
            return NoOp("backend-no-exchange-window", imb, imb)
        name = getattr(host.exchange_backend, "name", str(host.exchange_backend))
        if name == "dense" and frac < cfg.backend_ragged_below:
            target = "ragged"
        elif name == "ragged" and frac > cfg.backend_dense_above:
            target = "dense"
        else:
            host.backend_streak = 0
            return NoOp(f"backend-dead-zone {frac:.2f}", imb, imb)
        host.backend_streak += 1
        if host.backend_streak < cfg.backend_patience:
            return NoOp(
                f"backend-patience {host.backend_streak}/{cfg.backend_patience}",
                imb, imb,
            )
        guard = CooldownGuard(cfg.backend_cooldown)
        if not guard.ready(host.batches_seen, host.last_backend_switch):
            return NoOp("backend-cooldown", imb, imb)
        # measured-wall evidence: once both transports have a wall EWMA (the
        # target was actually run earlier in this job), don't switch onto a
        # transport measured markedly slower than the current one — the
        # occupancy model says it should win, the clock says it doesn't.
        # With no measurement for the target the guard is inert (first
        # switches are always model-driven).
        ewma = signals.backend_wall_ewma or {}
        if target in ewma and name in ewma and ewma[target] > 1.5 * ewma[name]:
            return NoOp(
                f"backend-wall-evidence {target} {ewma[target]*1e3:.1f}ms > "
                f"{name} {ewma[name]*1e3:.1f}ms",
                imb, imb,
            )
        return SwitchBackend(
            reason=f"backend {name}->{target} (padding fraction {frac:.2f})",
            backend=target,
            padding_fraction=frac,
        )


class PlacementPolicy:
    """Expert re-placement trigger over shard loads (see module doc).

    Without weight costing (``host.expert_weight_bytes == 0``) the policy
    only decides *whether*: the host computes the KIP placement on a bare
    :class:`Replace`.  With it, the policy also gates *which* placement
    wins, mirroring the streaming cost model: the host's candidate
    placements (``plan_candidates``) are priced by folding expert-weight
    bytes through :func:`~repro.core.migration.exchange_lane_cost` on the
    shard-to-shard weight-transfer matrix, and the candidate minimizing
    ``planned_imbalance + cost_weight * moved_bytes / total_bytes`` is
    chosen — including the zero-move "stay" candidate, so a re-placement
    whose balance gain cannot pay for its weight movement is declined."""

    def evaluate(self, host, signals: Signals) -> Action:
        imb = signals.imbalance
        if host.e <= host.n:
            return NoOp("too-few-experts", imb, imb)
        if imb < host.trigger:
            return NoOp("balanced", imb, imb)
        guard = CooldownGuard(host.min_steps_between)
        if not guard.ready(host.steps, host.last_update):
            return NoOp("cooldown", imb, imb)
        weight_bytes = float(getattr(host, "expert_weight_bytes", 0.0))
        if weight_bytes <= 0:
            return Replace(reason=f"imbalance {imb:.3f} >= trigger {host.trigger:.3f}")
        total = weight_bytes * host.e
        candidates = host.plan_candidates()
        cost_w = float(getattr(host, "cost_weight", 1.0))

        def score(c: dict) -> float:
            return c["planned_imbalance"] + cost_w * c["est_migration"] / max(total, 1e-12)

        best = min(candidates, key=score)
        if best["moved"] == 0:
            # the stay candidate won: no placement's gain pays for its bytes
            alt = min((c for c in candidates if c["moved"]), key=score, default=None)
            detail = (f" (best alternative {alt['choice']}: imb "
                      f"{alt['planned_imbalance']:.3f}, "
                      f"{alt['est_migration']:.0f} bytes)" if alt else "")
            return NoOp(f"placement gain <= migration cost{detail}",
                        imb, best["planned_imbalance"], 0.0)
        return Replace(
            reason=(f"placement {best['choice']}: imbalance {imb:.3f} -> "
                    f"{best['planned_imbalance']:.3f}, "
                    f"{best['est_migration']:.0f} bytes"),
            placement=best["placement"],
            perm=best["perm"],
            choice=best["choice"],
            planned_imbalance=best["planned_imbalance"],
            est_migration=best["est_migration"],
        )
