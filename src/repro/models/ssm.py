"""Mamba-1 selective SSM block (Jamba's mixer), chunked for TPU memory.

The selective scan is evaluated chunk-recurrently: an intra-chunk
associative scan (parallel, [B, chunk, d_inner, d_state] working set) with
the SSM state carried across chunks by ``lax.scan`` — the standard
TPU-friendly evaluation that keeps the working set ~(chunk/seq) of the
naive parallel scan.  Decode is the O(1) recurrent step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import Array, Policy, normal


def init_mamba(key, d: int, *, expand: int, d_state: int, d_conv: int, dtype) -> dict:
    di = expand * d
    dt_rank = -(-d // 16)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": normal(ks[0], (d, 2, di), d**-0.5, dtype),
        "conv_w": normal(ks[1], (d_conv, di), d_conv**-0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": normal(ks[2], (di, dt_rank + 2 * d_state), di**-0.5, dtype),
        "dt_proj": normal(ks[3], (dt_rank, di), dt_rank**-0.5, dtype),
        "dt_bias": jnp.full((di,), np.log(np.expm1(0.01)), dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": normal(ks[4], (di, d), di**-0.5, dtype),
    }


def _ssm_inputs(p: dict, x: Array, pol: Policy, d_state: int):
    """shared pre-scan computation: conv + projections -> (xc, dt, B, C, z)."""
    cd = pol.compute_dtype
    xz = jnp.einsum("bsd,dgi->bsgi", x, p["in_proj"].astype(cd))
    xm, z = xz[:, :, 0], xz[:, :, 1]
    return xm, z


def _conv_causal(xm: Array, w: Array, b: Array, state: Array | None):
    """depthwise causal conv; state [B, k-1, di] carries history for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xm.shape[0], k - 1, xm.shape[2]), xm.dtype)
    else:
        pad = state.astype(xm.dtype)
    xp = jnp.concatenate([pad, xm], axis=1)
    out = sum(xp[:, i : i + xm.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1) :]
    return jax.nn.silu(out + b[None, None]), new_state


def _dt_b_c(p: dict, xc: Array, d_state: int, cd):
    dbc = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"].astype(cd))
    dt_rank = p["dt_proj"].shape[0]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dbc[..., :dt_rank], p["dt_proj"].astype(cd))
        + p["dt_bias"].astype(cd)[None, None]
    )
    bmat = dbc[..., dt_rank : dt_rank + d_state]
    cmat = dbc[..., dt_rank + d_state :]
    return dt, bmat, cmat


def mamba_forward(p: dict, x: Array, pol: Policy, *, d_state: int, chunk: int = 256,
                  state: dict | None = None):
    """Train/prefill forward.  Returns (y, new_state) — state is the decode
    carry {"conv": [B, k-1, di], "ssm": [B, di, d_state]}."""
    b, s, d = x.shape
    cd = pol.compute_dtype
    xm, z = _ssm_inputs(p, x, pol, d_state)
    xc, conv_state = _conv_causal(xm, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                                  None if state is None else state["conv"])
    xc = pol.shard(xc, "ssm_inner")
    dt, bmat, cmat = _dt_b_c(p, xc, d_state, cd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [di, ds]

    c = min(chunk, s)
    nchunk = -(-s // c)
    assert s % c == 0, f"seq {s} not a multiple of mamba chunk {c}"
    # reshape to chunks [n, B, c, ...]
    def chunks(t):
        return t.reshape(b, nchunk, c, *t.shape[2:]).swapaxes(0, 1)

    xcs, dts, bs_, cs_ = map(chunks, (xc, dt, bmat, cmat))
    h0 = (jnp.zeros((b, xc.shape[-1], d_state), jnp.float32)
          if state is None else state["ssm"].astype(jnp.float32))

    def body(h, inp):
        xcb, dtb, bb, cb = inp  # [B, c, di], [B, c, di], [B, c, ds], [B, c, ds]
        da = jnp.exp(dtb.astype(jnp.float32)[..., None] * a[None, None])  # [B,c,di,ds]
        dbx = (dtb * xcb).astype(jnp.float32)[..., None] * bb.astype(jnp.float32)[:, :, None, :]
        # intra-chunk associative scan: (A_prod, Bx_cum)
        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        aprod, bxcum = jax.lax.associative_scan(op, (da, dbx), axis=1)
        hs = aprod * h[:, None] + bxcum  # [B, c, di, ds]
        y = jnp.einsum("bcis,bcs->bci", hs, cb.astype(jnp.float32))
        h_new = hs[:, -1]
        return h_new, y

    h_out, ys = jax.lax.scan(body, h0, (xcs, dts, bs_, cs_))
    y = ys.swapaxes(0, 1).reshape(b, s, -1).astype(cd)
    y = y + xc * p["d_skip"].astype(cd)[None, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cd))
    new_state = {"conv": conv_state.astype(cd), "ssm": h_out.astype(jnp.float32)}
    return out, new_state


def mamba_decode(p: dict, x: Array, pol: Policy, *, d_state: int, state: dict):
    """Single-token step: x [B, 1, d]."""
    return mamba_forward(p, x, pol, d_state=d_state, chunk=1, state=state)


def init_mamba_state(b: int, d: int, *, expand: int, d_state: int, d_conv: int, dtype=jnp.float32) -> dict:
    di = expand * d
    return {
        "conv": jnp.zeros((b, d_conv - 1, di), dtype),
        "ssm": jnp.zeros((b, di, d_state), jnp.float32),
    }
