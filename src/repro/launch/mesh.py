"""Production mesh builders (functions, never module-level constants:
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax

from repro.exchange.spec import ExchangeTopology


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) over ("data", "model").
    Multi-pod: 2 pods = 512 chips (2, 16, 16) over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    return n


def exchange_topology_of(
    mesh,
    *,
    axis: str = "data",
    lanes_per_host: int | None = None,
    class_weights: tuple[float, ...] | None = None,
) -> ExchangeTopology:
    """Derive the exchange plane's :class:`ExchangeTopology` from a mesh.

    Lanes are the shards along ``axis``; ``lanes_per_host`` is how many of
    them share one physical host, read off the mesh's device placement
    (``process_index`` along the first row of ``axis``).  Mesh device order
    is process-major on multi-host deployments, matching the topology's
    host-major lane convention (lane ``j`` on host ``j // lanes_per_host``).

    Single-process meshes (CPU tests, ``xla_force_host_platform_device_count``
    simulations) have no process boundary to read — pass ``lanes_per_host``
    explicitly to model one (the two-host bench profile does), otherwise all
    lanes land on one host and every backend degenerates to its flat
    behavior.
    """
    num_lanes = mesh.shape[axis]
    if lanes_per_host is None:
        dims = list(mesh.axis_names)
        devs = mesh.devices.transpose(
            [dims.index(axis)] + [i for i, a in enumerate(dims) if a != axis]
        )
        procs = [d.process_index for d in devs.reshape(num_lanes, -1)[:, 0]]
        # contiguous run length of the first host along the axis; a
        # single-process mesh yields one host (= the flat world)
        lanes_per_host = next(
            (i for i, p in enumerate(procs) if p != procs[0]), num_lanes
        )
        lanes_per_host = max(lanes_per_host, 1)
    kw = {} if class_weights is None else {"class_weights": tuple(class_weights)}
    return ExchangeTopology(
        num_lanes=num_lanes, lanes_per_host=int(lanes_per_host), **kw
    )
