import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract params/optimizer/batch/cache
(ShapeDtypeStructs — nothing allocates), jits the train/prefill/serve step
with the production shardings, and runs ``.lower().compile()``.  Success
proves the distribution config is coherent; ``memory_analysis()`` proves it
fits; ``cost_analysis()`` + the collective bytes parsed from the HLO feed
§Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all          # every cell
    PYTHONPATH=src python -m repro.launch.dryrun ... --multi-pod     # 512 chips

Writes one JSON per cell under reports/dryrun/.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import SHAPES, cells_for
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.launch.sharding import (
    ShardingOptions,
    batch_shardings,
    cache_shardings,
    default_options,
    make_policy,
    param_shardings,
)
from repro.models import model
from repro.models.modules import Policy
from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_parse import analyze
from repro.train.optimizer import OptConfig, OptState, init_opt
from repro.train.train_step import make_train_step

REPORT_DIR = "reports/dryrun"


def _opt_shardings(opt_abstract: OptState, pshard):
    return OptState(
        step=jax.tree.map(lambda _: jax.sharding.NamedSharding(pshard_mesh(pshard), jax.sharding.PartitionSpec()), opt_abstract.step),
        m=pshard,
        v=pshard,
    )


def pshard_mesh(pshard):
    return jax.tree.leaves(pshard)[0].mesh


def bytes_per_device(abstract_tree, shard_tree) -> int:
    """Exact per-device resident bytes of a sharded pytree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(abstract_tree), jax.tree.leaves(shard_tree)):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        div = 1
        for ax, dim in zip(tuple(sh.spec) + (None,) * leaf.ndim, leaf.shape):
            if ax is None:
                continue
            size = int(np.prod([sh.mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
            if dim % size == 0:
                div *= size
        total += n * leaf.dtype.itemsize // div
    return total


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, opts: ShardingOptions | None = None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = opts or default_options(cfg)
    pol = make_policy(cfg, mesh, shape.kind, opts)

    params_abs = model.abstract_params(cfg, pol)
    pshard = param_shardings(params_abs, mesh, opts, decode=shape.kind == "decode")

    state_bytes = bytes_per_device(params_abs, pshard)
    with set_mesh(mesh):
        batch_axes = tuple(mesh.axis_names) if opts.pure_dp else None
        if shape.kind == "train":
            batch_abs = model.input_specs(cfg, shape, pol)
            bshard = batch_shardings(batch_abs, mesh, batch_axes)
            opt_cfg = OptConfig(moment_dtype=opts.moment_dtype)
            opt_abs = jax.eval_shape(lambda p: init_opt(p, opt_cfg), params_abs)
            oshard = OptState(
                step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                m=pshard, v=pshard,
            )
            state_bytes += 2 * bytes_per_device(opt_abs.m, pshard)
            step = make_train_step(cfg, pol, opt_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
            )
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            batch_abs = model.input_specs(cfg, shape, pol)
            bshard = batch_shardings(batch_abs, mesh, batch_axes)
            fn = lambda p, b: model.prefill(p, b, cfg, pol, max_len=shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            cache_abs, tok_abs = model.decode_input_specs(cfg, shape, pol)
            cshard = cache_shardings(cache_abs, mesh, shape.global_batch)
            state_bytes += bytes_per_device(cache_abs, cshard)
            tshard = jax.sharding.NamedSharding(
                mesh,
                jax.sharding.PartitionSpec(
                    dp_axes_of(mesh) if shape.global_batch % np.prod(
                        [mesh.shape[a] for a in dp_axes_of(mesh)]) == 0 else None,
                    None,
                ),
            )
            fn = lambda p, c, t: model.decode_step(p, c, t, cfg, pol)
            jitted = jax.jit(fn, in_shardings=(pshard, cshard, tshard),
                             out_shardings=(None, cshard))
            lowered = jitted.lower(params_abs, cache_abs, tok_abs)
    return cfg, mesh, lowered, state_bytes


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, save_hlo: bool = False,
             opts: ShardingOptions | None = None, tag: str = "") -> dict:
    t0 = time.time()
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": n_chips, "status": "error", "tag": tag}
    try:
        cfg, mesh, lowered, state_bytes = lower_cell(arch, shape_name, multi_pod=multi_pod, opts=opts)
        rec["state_bytes_per_device"] = int(state_bytes)
        rec["fits_16gb_hbm"] = bool(state_bytes < 15.5 * 2**30)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        }
        rec["cost_analysis_raw"] = {  # loops counted once — reference only
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        hlo_text = compiled.as_text()
        hlo = analyze(hlo_text)  # loop-aware, per-device
        rec["hlo"] = {k: (v if not isinstance(v, dict) else v) for k, v in hlo.items()}
        rec["roofline"] = roofline_terms(
            flops_dev=hlo["flops"],
            hbm_dev=hlo["hbm_bytes"],
            hbm_dev_fused=hlo["hbm_bytes_fused"],
            coll_dev=sum(hlo["collective_bytes"].values()),
        )
        shape = SHAPES[shape_name]
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.param_count(active_only=True) * tokens
        rec["model_flops_dev"] = float(model_flops / n_chips)
        rec["useful_ratio"] = float(model_flops / n_chips / max(hlo["flops"], 1.0))
        rec["status"] = "ok"
        if save_hlo:
            os.makedirs(REPORT_DIR, exist_ok=True)
            with open(os.path.join(REPORT_DIR, f"{arch}__{shape_name}__{rec['mesh']}{tag}.hlo"), "w") as f:
                f.write(hlo_text)
    except Exception as e:  # noqa: BLE001 — report and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(REPORT_DIR, exist_ok=True)
    out = os.path.join(REPORT_DIR, f"{arch}__{shape_name}__{rec['mesh']}{tag}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        cfg = get_config(arch)
        shapes = cells_for(cfg) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp, save_hlo=args.save_hlo)
                status = rec["status"]
                extra = ("" if status == "ok" else " :: " + rec.get("error", ""))
                print(f"[{status}] {arch} x {shape} x {rec['mesh']} "
                      f"({rec['total_s']}s){extra}", flush=True)
                if status == "ok":
                    m = rec["memory"]
                    per_dev = (m["argument_bytes"] + m["temp_bytes"])
                    r = rec["roofline"]
                    print(f"    mem/device ~{per_dev/2**30:.2f} GiB  "
                          f"flops/dev {rec['hlo']['flops']:.3e}  useful {rec['useful_ratio']:.2f}  "
                          f"terms c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                          f"x={r['collective_s']:.3f}s -> {r['bottleneck']}", flush=True)


if __name__ == "__main__":
    main()
