"""Fig. 6 — relative streaming-throughput increase from DR vs. Zipf
exponent, measured on the real micro-batch runtime (StreamingJob on the
local mesh; stateful count reducer, matching the paper's Flink setup)."""
from __future__ import annotations

import numpy as np

from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf

EXPONENTS = [1.0, 1.3, 1.6, 2.0]


def _worker_time(job_metrics, per_record_us=1.0, per_batch_overhead_us=2000.0):
    """Straggler-bound completion: batches gated by the most loaded worker."""
    t = 0.0
    for m in job_metrics:
        t += m.worker_imbalance * per_record_us + per_batch_overhead_us * 1e-3
    return t


def run(batches: int = 6, batch_size: int = 16_384):
    rows = []
    state_capacity = 16_384
    for exp in EXPONENTS:
        metrics = {}
        mig_rows = 0
        reparts = 0
        for dr_on in (True, False):
            job = StreamingJob(
                num_partitions=8,
                state_capacity=state_capacity,
                dr_enabled=dr_on,
                dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2),
            )
            ms = job.run(drifting_zipf(batches, batch_size, num_keys=5_000,
                                       exponent=exp, drift_every=100, seed=int(exp * 7)))
            # throughput proxy: records / straggler-bound time
            imb = np.mean([m.imbalance for m in ms[1:]])
            metrics[dr_on] = imb
            if dr_on:
                mig_rows = sum(m.migration_rows for m in ms)
                reparts = sum(m.repartitioned for m in ms)
        gain = metrics[False] / metrics[True] - 1.0
        rows.append((f"fig6/throughput_gain/exp={exp}", gain,
                     "relative increase (paper: biggest at moderate exp)"))
        if reparts:
            # bounded exchange: rows shipped per repartition vs. the
            # full-state all-to-all (W * state_capacity rows per worker)
            full = job.num_workers * state_capacity
            rows.append((f"fig6/migration_rows_fraction/exp={exp}",
                         mig_rows / reparts / full,
                         f"{reparts} repartitions, full-state a2a = 1"))
    return rows
