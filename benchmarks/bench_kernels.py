"""Kernel micro-bench: Pallas (interpret on CPU) + jnp twins per batch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.kernels import ref as kref
from repro.kernels.ops import apply_partitioner, count_sketch, dispatch_slots
from repro.core import Histogram, kip_update, uniform_partitioner
from repro.data.generators import zipf_keys


SMOKE = dict(n=2_048)  # CI bench-smoke profile


def run(n: int = 8192):
    rows = []
    stream = zipf_keys(n, num_keys=2_000, exponent=1.2, seed=0)
    hist = Histogram.exact(stream).top(64)
    kip = kip_update(uniform_partitioner(16), hist)
    keys = jnp.asarray(stream[:n], jnp.int32)
    tables = kip.tables()

    jit_ref = jax.jit(lambda k: kref.partition_apply_ref(
        k, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
        num_hosts=kip.num_hosts))
    jit_ref(keys).block_until_ready()
    rows.append(("kernel/partition_apply_jnp", timer(
        lambda: jit_ref(keys).block_until_ready()), f"{n} keys"))
    # pallas interpret mode is NOT a performance path on CPU; correctness only
    out = apply_partitioner(keys, tables, num_hosts=kip.num_hosts)
    ok = bool(jnp.all(out == jit_ref(keys)))
    rows.append(("kernel/partition_apply_pallas_matches", float(ok), "interpret=True"))

    jit_cms = jax.jit(lambda k: kref.sketch_update_ref(k, jnp.ones(n, bool), depth=4, width=2048))
    jit_cms(keys).block_until_ready()
    rows.append(("kernel/sketch_update_jnp", timer(
        lambda: jit_cms(keys).block_until_ready()), f"{n} keys, 4x2048"))

    dest = jnp.asarray(np.random.default_rng(0).integers(0, 16, n), jnp.int32)
    jit_d = jax.jit(lambda d: kref.dispatch_count_ref(d, jnp.ones(n, bool), num_parts=16))
    jit_d(dest)[0].block_until_ready()
    rows.append(("kernel/dispatch_count_jnp", timer(
        lambda: jit_d(dest)[0].block_until_ready()), f"{n} records, 16 parts"))

    # fused exchange-plane hot path: lookup + slot in one pass
    valid = jnp.ones(n, bool)
    jit_f = jax.jit(lambda k: kref.lookup_dispatch_ref(
        k, valid, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
        num_hosts=kip.num_hosts, num_lanes=8))
    jit_f(keys)[0].block_until_ready()
    rows.append(("kernel/lookup_dispatch_jnp", timer(
        lambda: jit_f(keys)[0].block_until_ready()), f"{n} keys, 8 lanes (fused)"))
    from repro.kernels.ops import route_slots

    part_p, slot_p, _ = route_slots(keys, valid, tables, num_hosts=kip.num_hosts, num_lanes=8)
    part_r, slot_r, _ = jit_f(keys)
    ok = bool(jnp.all(part_p == part_r) & jnp.all(slot_p == slot_r))
    rows.append(("kernel/lookup_dispatch_pallas_matches", float(ok), "interpret=True"))

    # bucketize: deriving slots+counts inside vs. reusing the fused route
    # kernel's outputs (the reuse path also skips the O(n) lane_overflow
    # scatter — per-lane drops fall out of the counts)
    from repro.exchange import ExchangeSpec, Payload
    from repro.exchange.backends import _bucketize

    lanes = 16
    spec = ExchangeSpec(num_lanes=lanes, capacity=int(np.ceil(n / lanes / 8) * 8))
    bvals = jnp.ones((n, 8), jnp.float32)
    jit_slot = jax.jit(lambda d: kref.dispatch_count_ref(d, valid, num_parts=lanes))
    slot, counts = jit_slot(dest)
    slot.block_until_ready()

    jit_derive = jax.jit(
        lambda d: _bucketize(spec, d, valid, [Payload(bvals, 0)]).valid)
    jit_fused = jax.jit(
        lambda d, s, c: _bucketize(spec, d, valid, [Payload(bvals, 0)],
                                   slot=s, counts=c).valid)
    jit_derive(dest).block_until_ready()
    jit_fused(dest, slot, counts).block_until_ready()
    rows.append(("kernel/bucketize_derive_slots", timer(
        lambda: jit_derive(dest).block_until_ready()),
        f"{n} records, {lanes} lanes (dispatch_count + overflow scatter inside)"))
    rows.append(("kernel/bucketize_fused_route", timer(
        lambda: jit_fused(dest, slot, counts).block_until_ready()),
        f"{n} records, {lanes} lanes (slots+counts from the route pass)"))

    # double-buffered send sets: reset+scatter into a recycled [L, cap] set
    # (the depth-2 pipeline's ping-pong pool, donated so XLA rewrites it in
    # place) vs. materializing the set fresh every batch.  Values must be
    # bit-identical — reuse is an allocation optimization, not a semantic
    # one.
    def _fill(d, s, c, bufs):
        out = _bucketize(spec, d, valid, [Payload(bvals, 0)], slot=s, counts=c,
                         buffers=bufs)
        return out.valid, tuple(out.payloads)

    jit_realloc = jax.jit(lambda d, s, c: _fill(d, s, c, None))
    donate_bufs = () if jax.default_backend() == "cpu" else (3,)
    jit_reuse = jax.jit(
        lambda d, s, c, bufs: _fill(d, s, c, (bufs[0], tuple(bufs[1]))),
        donate_argnums=donate_bufs)
    fresh = jit_realloc(dest, slot, counts)
    reused = jit_reuse(dest, slot, counts, jit_realloc(dest, slot, counts))
    ok = bool(jnp.all(fresh[0] == reused[0])) and all(
        bool(jnp.all(f == r)) for f, r in zip(fresh[1], reused[1]))
    rows.append(("kernel/bucketize_reuse_matches", float(ok),
                 "recycled set scatters to the fresh-alloc values"))
    pool = [jit_realloc(dest, slot, counts) for _ in range(2)]

    def _ping_pong():
        bufs = pool.pop(0)
        out = jit_reuse(dest, slot, counts, bufs)
        pool.append(out)
        out[0].block_until_ready()

    _ping_pong(), _ping_pong()  # warm both sets through the jit
    rows.append(("kernel/bucketize_realloc", timer(
        lambda: jit_realloc(dest, slot, counts)[0].block_until_ready()),
        f"{n} records, {lanes} lanes (fresh [L, cap] set per batch)"))
    rows.append(("kernel/bucketize_buffer_reuse", timer(_ping_pong),
        f"{n} records, {lanes} lanes (two-set ping-pong, reset+scatter)"))

    # fused route->bucketize (the split-phase exchange's whole start path in
    # one pass) vs. the two-pass route-then-scatter chain it replaces
    from repro.kernels.ops import route_bucketize as rb_pallas

    rl = 8
    cap = int(np.ceil(n / rl / 128) * 128)
    rb_spec = ExchangeSpec(num_lanes=rl, capacity=cap)
    kf = 2**31 - 1

    def _two_pass(k):
        part, slot, counts = kref.lookup_dispatch_ref(
            k, valid, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
            seed=kip.seed, num_hosts=kip.num_hosts, num_lanes=rl)
        dest = jnp.where(valid, part, 0)
        return _bucketize(rb_spec, dest % rl, valid,
                          [Payload(k, kf), Payload(bvals, 0), Payload(dest, 0)],
                          slot=slot, counts=counts).payloads[0]

    def _fused_rb(k):
        return kref.route_bucketize_ref(
            k, valid, bvals, tables.heavy_keys, tables.heavy_parts,
            tables.host_to_part, seed=kip.seed, num_hosts=kip.num_hosts,
            num_lanes=rl, capacity=cap, key_fill=kf)[4]

    jit_two, jit_frb = jax.jit(_two_pass), jax.jit(_fused_rb)
    jit_two(keys).block_until_ready()
    jit_frb(keys).block_until_ready()
    rows.append(("kernel/route_bucketize_two_pass", timer(
        lambda: jit_two(keys).block_until_ready()),
        f"{n} keys, {rl} lanes (route, then scatter)"))
    rows.append(("kernel/route_bucketize_fused_jnp", timer(
        lambda: jit_frb(keys).block_until_ready()),
        f"{n} keys, {rl} lanes (one fused pass)"))
    got = rb_pallas(keys, valid, tables, bvals, seed=kip.seed,
                    num_hosts=kip.num_hosts, num_lanes=rl, capacity=cap, key_fill=kf)
    want = kref.route_bucketize_ref(
        keys, valid, bvals, tables.heavy_keys, tables.heavy_parts,
        tables.host_to_part, seed=kip.seed, num_hosts=kip.num_hosts,
        num_lanes=rl, capacity=cap, key_fill=kf)
    ok = bool(jnp.all(jnp.where(valid, got[0], 0) == jnp.where(valid, want[0], 0)))
    for g, w in list(zip(got, want))[1:]:
        ok = ok and bool(jnp.all(g == w))
    rows.append(("kernel/route_bucketize_pallas_matches", float(ok), "interpret=True"))
    return rows
