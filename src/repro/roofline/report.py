"""Aggregate reports/dryrun/*.json into the §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(report_dir: str = "reports/dryrun", mesh: str = "16x16", tag: str = "") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(report_dir, f"*__{mesh}{tag}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") == tag:
            cells.append(r)
    cells.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])))
    return cells


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                f"ERROR: {r.get('error', '?')[:60]} |")
    t = r["roofline"]
    state_gb = r["state_bytes_per_device"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | {t['memory_s']:.3g} "
        f"| {t['collective_s']:.3g} | **{t['bottleneck']}** | {r['useful_ratio']:.2f} "
        f"| {state_gb:.1f} | {t['roofline_fraction']:.3f} |"
    )


def table(cells: list[dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL/HLO flops | state GiB/dev | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return "\n".join([hdr] + [fmt_row(r) for r in cells])


def main() -> None:
    for mesh in ["16x16", "2x16x16"]:
        cells = load_cells(mesh=mesh)
        ok = sum(1 for c in cells if c["status"] == "ok")
        print(f"\n## mesh {mesh}: {ok}/{len(cells)} cells ok\n")
        print(table(cells))


if __name__ == "__main__":
    main()
