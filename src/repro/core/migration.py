"""State-migration planning for partitioner swaps (stream) and replay (batch).

When the DRM swaps partitioners at a safe point, every live key whose
partition changed must have its operator state moved.  The planner produces:

* the per-key move list (old partition -> new partition),
* the [N, N] transfer matrix in state-bytes (feeds the capacity-padded
  all-to-all in ``repro.core.state``),
* the *relative state migration* metric of the paper's Fig. 3
  (moved state / total state).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partitioner import Partitioner

__all__ = [
    "MigrationPlan",
    "plan_migration",
    "migration_capacity",
    "exchange_lane_cost",
    "fold_to_workers",
]


def fold_to_workers(values: np.ndarray, num_workers: int) -> np.ndarray:
    """Fold per-partition accounting to worker granularity.

    Partition ``p`` lives on worker ``p % W`` — the one placement rule the
    runtime, the migration planner, and the control-plane signals all share.
    Accepts a ``[N]`` vector (loads) or a ``[N, N]`` matrix (transfer) and
    returns the ``[W]`` / ``[W, W]`` worker-folded equivalent.
    """
    v = np.asarray(values, np.float64)
    n = v.shape[0]
    w = np.arange(n) % num_workers
    if v.ndim == 1:
        out = np.zeros(num_workers)
        np.add.at(out, w, v)
        return out
    assert v.ndim == 2 and v.shape[0] == v.shape[1], v.shape
    out = np.zeros((num_workers, num_workers))
    np.add.at(out, (w[:, None], w[None, :]), v)
    return out


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    keys: np.ndarray          # int64[M] keys that move
    src: np.ndarray           # int32[M]
    dst: np.ndarray           # int32[M]
    weights: np.ndarray       # float64[M] state size per moved key
    transfer: np.ndarray      # float64[N, N] bytes moved src->dst
    relative_migration: float # moved / total state weight
    # cross-size (elastic resize) bookkeeping: the plan's src axis spans the
    # old topology, the dst axis the new one; ``transfer`` is padded square
    # to max(num_src, num_dst) so worker folding works either way.
    num_src: int = 0          # old partition count (0 on legacy plans)
    num_dst: int = 0          # new partition count

    @property
    def num_moves(self) -> int:
        return len(self.keys)

    @property
    def is_resize(self) -> bool:
        return bool(self.num_src and self.num_dst and self.num_src != self.num_dst)


def plan_migration(
    old: Partitioner,
    new: Partitioner,
    live_keys: np.ndarray,
    state_weights: np.ndarray | None = None,
) -> MigrationPlan:
    """Diff two partitioners over the live key set.

    ``old`` and ``new`` may have different partition counts (elastic
    resize): the transfer matrix is padded square to the larger topology,
    and every key whose partition changed under the new lookup moves —
    including keys folded off removed partitions on a shrink.
    """
    live_keys = np.asarray(live_keys, np.int64)
    if state_weights is None:
        state_weights = np.ones(len(live_keys))
    state_weights = np.asarray(state_weights, np.float64)
    assert live_keys.shape == state_weights.shape

    src = old.lookup_np(live_keys.astype(np.int32))
    dst = new.lookup_np(live_keys.astype(np.int32))
    moved = src != dst
    n = max(old.num_partitions, new.num_partitions)
    transfer = np.zeros((n, n))
    np.add.at(transfer, (src[moved], dst[moved]), state_weights[moved])
    total = float(state_weights.sum())
    rel = float(state_weights[moved].sum() / total) if total > 0 else 0.0
    return MigrationPlan(
        keys=live_keys[moved],
        src=src[moved].astype(np.int32),
        dst=dst[moved].astype(np.int32),
        weights=state_weights[moved],
        transfer=transfer,
        relative_migration=rel,
        num_src=old.num_partitions,
        num_dst=new.num_partitions,
    )


def migration_capacity(
    plan: MigrationPlan,
    row_bytes: float = 1.0,
    slack: float = 1.25,
    num_workers: int | None = None,
) -> int:
    """Static per-(src,dst) lane capacity for the all-to-all state exchange.

    XLA collectives need static shapes: size each lane to the largest
    planned transfer times ``slack`` (rounded up to a multiple of 8 rows).

    With ``num_workers`` the [N, N] partition-level transfer matrix is first
    folded to worker granularity (partition p lives on worker ``p % W``) and
    same-worker moves are dropped — they never cross the exchange.  This is
    the lane size ``repro.core.shuffle.make_migrate_step`` wants: the
    exchanged buffer shrinks from ``W * state_capacity`` rows to the planned
    peak transfer x slack.
    """
    transfer = plan.transfer
    if transfer.size == 0:
        return 8
    if num_workers is not None:
        transfer = fold_to_workers(transfer, num_workers)
        np.fill_diagonal(transfer, 0.0)  # same-worker moves don't ship
    peak = float(transfer.max()) / max(row_bytes, 1e-12)
    cap = int(np.ceil(peak * slack / 8.0) * 8)
    return max(cap, 8)


def exchange_lane_cost(
    plan: MigrationPlan,
    *,
    num_workers: int | None = None,
    slack: float = 1.25,
    backend=None,
    topology=None,
) -> float:
    """Migration-cost estimate from the *active exchange backend's* sizing
    rule.

    The default (dense) rule is the quantity :func:`migration_capacity`
    quantizes into lane rows — the peak planned (src, dst) transfer times
    ``slack``, since a capacity-padded transport provisions every lane to
    the peak.  A ragged backend's rule averages real rows over the lanes
    (``backend.cost``), and a local backend is free — so the control
    plane's :class:`~repro.control.policy.RepartitionPolicy` weighs the
    balance gain against what the transport the job actually runs would
    move, not a one-size heuristic.  The estimate stays in the plan's own
    weight units so it can be evaluated on a *relative* (frequency-weighted)
    candidate plan before any state exists.

    With ``num_workers > 1`` the transfer folds to worker granularity and
    same-worker moves cost nothing (they never cross the exchange); on a
    single worker — or when the worker count is unknown — partition-level
    lanes are the accounting unit.  ``backend`` is any object with the
    :class:`~repro.exchange.backends.ExchangeBackend` ``cost`` verb (or
    ``None`` for the dense rule).

    ``topology`` (an :class:`~repro.exchange.spec.ExchangeTopology`) makes
    the estimate *locality-priced*: each (src, dst) cell of the worker-
    folded transfer is weighted by its distance class before the backend's
    sizing rule sees it, so a plan that moves the same mass within a host
    is cheaper than one that scatters it across hosts — candidate plans
    with equal balance but less inter-host traffic win, and the inter-host
    weight (10x by default) can flip a repartition/split/placement decision
    the flat estimate would have taken.
    """
    transfer = plan.transfer
    if transfer.size == 0:
        return 0.0
    if num_workers is not None and num_workers > 1:
        transfer = fold_to_workers(transfer, num_workers)
        np.fill_diagonal(transfer, 0.0)
    if topology is not None:
        transfer = transfer * topology.weight_matrix(transfer.shape[0])
    if backend is not None:
        return float(backend.cost(None, transfer, slack=slack))
    return float(transfer.max()) * slack
