"""Unit + property tests for UHP / KIP and Algorithm 1 invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Histogram,
    expected_loads,
    kip_update,
    load_imbalance,
    plan_migration,
    resize_partitioner,
    uniform_partitioner,
)
from repro.core.hashing import KEY_SENTINEL
from repro.data.generators import zipf_keys


def _hist_from_stream(stream, top_b):
    return Histogram.exact(stream).top(top_b)


class TestUHP:
    def test_range(self):
        p = uniform_partitioner(7)
        keys = np.arange(10_000, dtype=np.int32)
        parts = p.lookup_np(keys)
        assert parts.min() >= 0 and parts.max() < 7

    def test_uniform_on_uniform_keys(self):
        p = uniform_partitioner(8)
        keys = np.random.default_rng(0).integers(0, 2**30, 100_000).astype(np.int32)
        loads = np.bincount(p.lookup_np(keys), minlength=8)
        assert loads.max() / loads.mean() < 1.05

    def test_deterministic(self):
        p = uniform_partitioner(5, seed=3)
        keys = np.arange(1000, dtype=np.int32)
        assert np.array_equal(p.lookup_np(keys), p.lookup_np(keys))


class TestKIPUpdate:
    def test_isolates_heavy_keys(self):
        """A single dominant key must not share its partition with other
        heavy keys (the 'key isolator' property)."""
        n = 8
        keys = np.arange(100, dtype=np.int64)
        counts = np.ones(100)
        counts[0] = 500.0  # 83% of mass
        hist = Histogram.from_counts(keys, counts)
        eps = 0.01
        kip = kip_update(uniform_partitioner(n), hist, eps=eps)
        heavy = kip.heavy_map()
        p0 = heavy[0]
        others = [p for k, p in heavy.items() if k != 0]
        loads = expected_loads(kip, hist)
        assert loads.argmax() == p0
        # isolation property: p0 may only take tail keys up to the eps slack
        f_tail = hist.freqs[-1]
        assert sum(1 for p in others if p == p0) <= int(eps / f_tail) + 1
        maxload = max(1.0 / n, hist.freqs[0]) + eps
        assert loads[p0] <= maxload + 1e-9

    def test_respects_maxload_given_exact_hist(self):
        n, eps = 16, 0.01
        stream = zipf_keys(200_000, num_keys=5_000, exponent=1.2, seed=1)
        hist = _hist_from_stream(stream, top_b=2 * n)
        kip = kip_update(uniform_partitioner(n), hist, eps=eps)
        loads = expected_loads(kip, hist)
        maxload = max(1.0 / n, hist.freqs[0]) + eps
        hostload = hist.tail_mass / kip.num_hosts
        # greedy bin packing guarantee: within one host-load of the bound
        assert loads.max() <= maxload + hostload + 1e-9

    def test_beats_hash_on_zipf(self):
        """KIP must beat UHP and sit near the information-theoretic floor.

        With one key carrying frequency f1, max/mean imbalance cannot go
        below max(1, N*f1) for any partitioner; the paper's '<1.2' regime is
        where N*f1 < 1.2.  We assert KIP lands within 25% of the floor.
        """
        n = 32
        stream = zipf_keys(400_000, num_keys=100_000, exponent=1.0, seed=2)
        uhp = uniform_partitioner(n)
        hist = _hist_from_stream(stream, top_b=2 * n)
        kip = kip_update(uhp, hist)
        floor = max(1.0, n * hist.freqs[0])
        assert load_imbalance(kip, stream) < load_imbalance(uhp, stream)
        assert load_imbalance(kip, stream) < 1.25 * floor

    def test_below_1_2_in_paper_regime(self):
        """Where N*f1 < 1, KIP keeps measured imbalance below ~1.2 (Fig 2)."""
        n = 8
        stream = zipf_keys(400_000, num_keys=100_000, exponent=1.0, seed=7)
        hist = _hist_from_stream(stream, top_b=4 * n)
        kip = kip_update(uniform_partitioner(n), hist)
        assert load_imbalance(kip, stream) < 1.25

    def test_migration_minimal_when_balanced(self):
        """Re-running KIPUPDATE on an unchanged distribution must not move
        state (heavy keys keep their partitions — Algorithm 1 line 4-6)."""
        n = 16
        stream = zipf_keys(200_000, num_keys=10_000, exponent=1.1, seed=3)
        hist = _hist_from_stream(stream, top_b=2 * n)
        kip1 = kip_update(uniform_partitioner(n), hist)
        kip2 = kip_update(kip1, hist)
        live = np.unique(stream)
        plan = plan_migration(kip1, kip2, live)
        assert plan.relative_migration < 0.02

    def test_elastic_resize(self):
        """KIPUPDATE with a different N is the elastic-scaling primitive."""
        stream = zipf_keys(100_000, num_keys=5_000, exponent=1.0, seed=4)
        hist = _hist_from_stream(stream, top_b=64)
        kip16 = kip_update(uniform_partitioner(16), hist)
        kip24 = kip_update(kip16, hist, num_partitions=24)
        assert kip24.num_partitions == 24
        parts = kip24.lookup_np(stream.astype(np.int32))
        assert parts.max() < 24
        floor = max(1.0, 24 * hist.freqs[0])
        assert load_imbalance(kip24, stream) < 1.25 * floor

    def test_elastic_shrink_fold(self):
        """Shrink folds removed partitions (``p % n``): every lookup — heavy
        table and host hash alike — lands strictly inside the new range."""
        stream = zipf_keys(100_000, num_keys=5_000, exponent=1.2, seed=6)
        hist = _hist_from_stream(stream, top_b=32)
        kip8 = kip_update(uniform_partitioner(8), hist)
        kip3 = kip_update(kip8, hist, num_partitions=3)
        assert kip3.num_partitions == 3
        assert kip3.host_to_part.max() < 3
        parts = kip3.lookup_np(stream.astype(np.int32))
        assert parts.min() >= 0 and parts.max() < 3
        # every histogram key is still explicitly routed after the fold
        assert set(kip3.heavy_map()) == set(hist.keys.tolist())
        # and the shrink plan moves only what the fold + re-balance require
        plan = plan_migration(kip8, kip3, np.unique(stream))
        assert plan.is_resize and plan.num_src == 8 and plan.num_dst == 3
        assert plan.transfer.shape == (8, 8)  # padded square to the larger side

    def test_elastic_grow_preserves_heavy_isolation(self):
        """Growing must not cram the dominant key together with other heavy
        keys: isolation survives the resize (the 'key isolator' property)."""
        keys = np.arange(50, dtype=np.int64)
        counts = np.full(50, 10.0)
        counts[0] = 250.0  # 25% of mass: isolated at n=4 and at n=8
        # leave tail mass (a top-B summary never covers the whole stream) so
        # the resize also re-bins hosts onto the new partitions
        hist = Histogram.from_counts(keys, counts, total=1000.0)
        kip4 = kip_update(uniform_partitioner(4), hist)
        # the elastic primitive (waterfilled re-binning spreads the tail
        # onto the new partitions; plain Algorithm 1 packing only rescues
        # partitions already above MAXLOAD)
        kip8 = resize_partitioner(kip4, 8, hist)
        assert kip8.num_partitions == 8
        heavy = kip8.heavy_map()
        p0 = heavy[0]
        assert sum(1 for k, p in heavy.items() if k != 0 and p == p0) == 0
        # grow must put expected load on every partition, old and new alike
        # (heavy keys cover the old bins, re-binned tail hosts the new ones)
        assert (expected_loads(kip8, hist) > 0).all()

    def test_resize_partitioner_without_histogram(self):
        """A resize before any histogram exists re-bins hosts so every new
        partition receives hash traffic immediately."""
        grown = resize_partitioner(uniform_partitioner(4), 8)
        assert grown.num_partitions == 8
        hosts_per_part = np.bincount(grown.host_to_part, minlength=8)
        assert hosts_per_part.min() > 0
        shrunk = resize_partitioner(grown, 2)
        assert shrunk.num_partitions == 2
        assert shrunk.host_to_part.max() < 2
        with pytest.raises(ValueError):
            resize_partitioner(grown, 0)

    def test_device_lookup_matches_host(self):
        import jax.numpy as jnp

        from repro.core import lookup_device

        stream = zipf_keys(50_000, num_keys=2_000, exponent=1.3, seed=5)
        hist = _hist_from_stream(stream, top_b=32)
        kip = kip_update(uniform_partitioner(8), hist)
        got = np.asarray(
            lookup_device(kip.tables(), jnp.asarray(stream[:4096], jnp.int32), kip.num_hosts, kip.seed)
        )
        want = kip.lookup_np(stream[:4096].astype(np.int32))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# hypothesis property tests — system invariants
# ---------------------------------------------------------------------------

key_arrays = st.lists(
    st.integers(min_value=0, max_value=2**30), min_size=1, max_size=200, unique=True
)


@settings(max_examples=50, deadline=None)
@given(
    keys=key_arrays,
    n=st.integers(min_value=1, max_value=64),
    exp=st.floats(min_value=0.0, max_value=3.0),
    seed=st.integers(min_value=0, max_value=10),
)
def test_prop_kip_total_function(keys, n, exp, seed):
    """KIP is a total function onto [0, N) for any histogram/partition count."""
    keys = np.array(keys, np.int64)
    counts = (np.arange(1, len(keys) + 1, dtype=np.float64)) ** (1 + exp)
    hist = Histogram.from_counts(keys, counts)
    kip = kip_update(uniform_partitioner(n, seed=seed), hist)
    probe = np.concatenate([keys, np.arange(500, dtype=np.int64) * 7919])
    parts = kip.lookup_np(probe.astype(np.int32))
    assert parts.min() >= 0 and parts.max() < n


@settings(max_examples=50, deadline=None)
@given(keys=key_arrays, n=st.integers(min_value=2, max_value=32))
def test_prop_heavy_keys_explicitly_routed(keys, n):
    """Every histogram key ends up in the explicit table, routed where the
    planner says (lookup == heavy_parts entry)."""
    keys = np.array(keys, np.int64)
    counts = np.linspace(10.0, 1.0, len(keys))
    hist = Histogram.from_counts(keys, counts)
    kip = kip_update(uniform_partitioner(n), hist)
    hm = kip.heavy_map()
    assert set(hm) == set(keys.tolist())
    got = kip.lookup_np(keys.astype(np.int32))
    want = np.array([hm[int(k)] for k in keys])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=32),
    b=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=100),
)
def test_prop_idempotent_update_no_migration(n, b, seed):
    """KIPUPDATE on an unchanged histogram never moves heavy keys whose
    partitions are within the load bound (migration-minimality)."""
    rng = np.random.default_rng(seed)
    keys = rng.choice(2**30, size=b, replace=False)
    counts = rng.zipf(2.0, size=b).astype(np.float64)
    hist = Histogram.from_counts(keys, counts)
    k1 = kip_update(uniform_partitioner(n), hist)
    k2 = kip_update(k1, hist)
    plan = plan_migration(k1, k2, keys)
    assert plan.relative_migration <= 0.05


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_prop_histogram_merge_weighted(seed):
    """DRM merge equals exact counting over the concatenated streams."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 50, size=300)
    b = rng.integers(0, 50, size=700)
    merged = Histogram.merge([Histogram.exact(a), Histogram.exact(b)])
    exact = Histogram.exact(np.concatenate([a, b]))
    da = dict(zip(merged.keys.tolist(), merged.freqs.tolist()))
    db = dict(zip(exact.keys.tolist(), exact.freqs.tolist()))
    assert set(da) == set(db)
    for k in da:
        assert abs(da[k] - db[k]) < 1e-9


def test_sentinel_not_a_valid_key():
    p = uniform_partitioner(4)
    hist = Histogram.from_counts(np.array([KEY_SENTINEL - 1]), np.array([1.0]))
    kip = kip_update(p, hist)
    assert kip.lookup_np(np.array([KEY_SENTINEL - 1], np.int32)).shape == (1,)
