"""Dynamic Repartitioning Master — host of the control-plane policy stack.

Lives in the launcher ("Driver") process.  Per safe point it:

1. merges the DRW local histograms into the global counter sketch
   (EWMA over past histograms — drift-respecting),
2. runs the policy stack over the window's :class:`~repro.control.Signals`
   (``evaluate``): the :class:`~repro.control.policy.ResizePolicy` first
   (topology), then the :class:`~repro.control.policy.SplitPolicy`
   (hot-key replication — Partial-Key-Grouping for a key one worker cannot
   hold), then the :class:`~repro.control.policy.RepartitionPolicy`
   (contents — §4's gain-vs-migration-cost trigger, costed with real
   exchange-lane accounting), then the
   :class:`~repro.control.policy.BackendPolicy` (transport),
3. records every decision — including declined ones, with reasons — in the
   :class:`~repro.control.DecisionLog`, and hands taken actions back to the
   driver to execute at the safe point.

The runtimes (``StreamingJob``, ``DRScheduler``) are thin drivers: they
feed telemetry in and execute the returned typed actions.  ``evaluate`` is
the sole public decision API; ``decide`` and ``decide_resize`` are
*deprecated* single-policy wrappers kept for pre-control-plane callers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.control.actions import (
    Action,
    Evict,
    NoOp,
    Quarantine,
    Recover,
    Repartition,
    Resize,
    Split,
    SwitchBackend,
    Unsplit,
)
from repro.control.health import HealthPolicy, LaneHealth
from repro.control.log import DecisionLog
from repro.control.policy import (
    BackendPolicy,
    RepartitionPolicy,
    ResizePolicy,
    SplitPolicy,
)
from repro.control.signals import Signals
from repro.core.histogram import CounterSketch
from repro.core.partitioner import (
    Partitioner,
    heavy_capacity_for,
    resize_partitioner,
)
from repro.exchange.backends import resolve_backend
from repro.exchange.spec import ExchangeTopology

__all__ = ["DRConfig", "DRMaster", "DRDecision"]


@dataclasses.dataclass(frozen=True)
class DRConfig:
    """Control-plane configuration for the DR module (one frozen record).

    Most fields tune one policy each (see the inline comments); the
    exchange-pipeline knobs interact and deserve spelling out:

    * ``overlap_exchange`` (default on) — the streaming driver issues batch
      N+1's route/count phase before batch N's row ship drains (pipeline
      depth 1 of latency hiding).  Bit-identical to the serial driver by
      construction.
    * ``pipeline_depth`` — ``1`` keeps the ship-behind-host-work overlap;
      ``2`` additionally pre-routes batch N+1 (route -> bucketize -> start)
      before batch N's decision section runs, so the device pipeline holds
      two in-flight stages and the per-batch start sync costs ~nothing.
      Any taken control action first drains *both* stages and replays the
      pre-routed batch under the new partitioner, so trajectories stay
      bit-identical to serial.  Values outside ``{1, 2}`` raise
      ``ValueError`` at construction.  Depth 2 engages only in
      ``StreamingJob.run`` (the driver needs one batch of lookahead);
      direct ``process_batch`` calls degrade gracefully to depth 1.
    * ``REPRO_DISABLE_OVERLAP=1`` (environment) — forces the serial
      exchange path regardless of ``overlap_exchange`` *and*
      ``pipeline_depth``, in ``StreamingJob`` and ``DRScheduler`` both.
      The bench/debug escape hatch for A/B-ing the bit-identical paths on
      one build; ``0`` / ``false`` / unset leave the overlap on.
    * ``split_least_load`` — replica pick for split hot keys: off (default)
      every route uses the stateless fmix32 offset (TPU Pallas kernel
      eligible); on, the jnp route twin picks the lower-loaded of two
      hashed replica candidates, fed per-partition loads from ``Signals``
      at each safe point (the Pallas path is gated off statically so the
      kernel and twin can never diverge at runtime).
    """

    lam: float = 2.0                 # histogram scale factor: B = lam * N
    eps: float = 0.01                # KIP load slack
    ewma_alpha: float = 0.5          # weight of the newest histogram
    sketch_capacity: int = 512       # DRM counter sketch size
    sketch_decay: float = 0.9
    imbalance_trigger: float = 1.2   # repartition when measured imb exceeds
    migration_cost_weight: float = 1.0  # batches of gain a migration must pay for
    min_batches_between: int = 1     # safe-point spacing (1 = every boundary)
    mode: str = "stream"             # "stream" | "batch" (replay-once)
    tight: bool = True               # waterfilled host re-binning (beyond-paper;
                                     # False = faithful Algorithm 1 packing)
    # -- elastic resize: grow/shrink the partition (logical worker) count --
    elastic: bool = False            # let the DRM decide to resize
    min_partitions: int = 1          # shrink floor (also floored at num_workers)
    max_partitions: int = 256        # grow ceiling
    grow_trigger: float = 1.5        # sustained imbalance above this => grow
    shrink_trigger: float = 1.05     # sustained imbalance below this => shrink
    resize_patience: int = 2         # consecutive safe points before acting
    resize_factor: int = 2           # grow/shrink multiplies/divides by this
    # -- control-plane hysteresis + capacity-target signal -----------------
    resize_cooldown: int = 0         # min safe points between resizes (0 = off);
                                     # the oscillation guard on top of patience
    target_throughput: float = 0.0   # per-worker records/s capacity target;
                                     # sustained below => shrink even if the
                                     # imbalance sits in the trigger dead zone
    # -- exchange-transport actuator (dense <-> ragged auto-selection) -----
    auto_backend: bool = False       # let the BackendPolicy flip the transport
    backend_ragged_below: float = 0.5  # dense -> ragged when the padding
                                     # fraction stays below this
    backend_dense_above: float = 0.9 # ragged -> dense when it stays above
                                     # (the gap between the two is the dead
                                     # zone that stops threshold straddling)
    backend_patience: int = 2        # consecutive safe points before flipping
    backend_cooldown: int = 0        # min safe points between flips (0 = off)
    # -- hot-key splitting (Partial-Key-Grouping as a control action) ------
    split_keys_enabled: bool = False # let the SplitPolicy replicate hot keys
    split_max_replicas: int = 8      # fan-out ceiling per split key
    split_trigger: float = 1.3       # split when the top key's share alone
                                     # exceeds this many worker fair budgets
    unsplit_trigger: float = 0.8     # collapse a split key cooled below this
                                     # (the gap to split_trigger is the dead
                                     # zone that stops split/unsplit churn)
    split_patience: int = 2          # consecutive safe points before acting
    split_cooldown: int = 0          # min safe points between split actions
    # -- split-phase exchange overlap --------------------------------------
    overlap_exchange: bool = True    # issue batch N+1's route/count phase
                                     # before batch N's row ship drains
                                     # (bit-identical to serial; env escape
                                     # hatch: REPRO_DISABLE_OVERLAP=1)
    pipeline_depth: int = 1          # 1 = ship-behind-host-work overlap;
                                     # 2 = additionally pre-route batch N+1
                                     # before batch N's decision section
                                     # (see the class docstring)
    split_least_load: bool = False   # two-choice least-load replica pick
                                     # for split hot keys (jnp route twin;
                                     # statically gates the Pallas kernel
                                     # off — see the class docstring)
    # -- failure domains: auto-snapshots, replay, lane health --------------
    snapshot_interval: int = 0       # auto-snapshot every N batches (0 = off);
                                     # also bounds the zero-loss replay
                                     # buffer — a worker loss restores the
                                     # last snapshot and replays at most
                                     # this many batches
    health_enabled: bool = False     # let the HealthPolicy act on per-lane
                                     # straggle/failure evidence
    health_straggler_ms: float = 50.0  # quarantine when a lane's straggle
                                     # EWMA stays past this many ms
    health_failure_threshold: int = 3  # evict after this many *consecutive*
                                     # failed windows on one lane
    health_patience: int = 2         # consecutive sick safe points before
                                     # a health action may fire
    health_cooldown: int = 0         # min safe points between health
                                     # actions (0 = off)
    health_recover_after: int = 0    # probe (re-admit) a quarantined lane
                                     # after this many safe points
                                     # (0 = never re-admit)

    def __post_init__(self):
        if self.pipeline_depth not in (1, 2):
            raise ValueError(
                f"pipeline_depth must be 1 (ship-behind-host-work overlap) or "
                f"2 (batch-ahead route), got {self.pipeline_depth!r}"
            )
        # knob relationships are validated unconditionally — a config whose
        # dead zones are inverted is wrong even while its feature flag is
        # off (it used to fail silently the day the flag turned on)
        if self.grow_trigger <= self.shrink_trigger:
            raise ValueError(
                "elastic resize needs a trigger-gap dead zone: "
                f"grow_trigger {self.grow_trigger} <= shrink_trigger "
                f"{self.shrink_trigger}"
            )
        if self.backend_ragged_below >= self.backend_dense_above:
            raise ValueError(
                "backend auto-selection needs a threshold dead zone: "
                f"backend_ragged_below {self.backend_ragged_below} >= "
                f"backend_dense_above {self.backend_dense_above}"
            )
        if self.split_trigger <= self.unsplit_trigger:
            raise ValueError(
                "hot-key splitting needs a trigger-gap dead zone: "
                f"split_trigger {self.split_trigger} <= "
                f"unsplit_trigger {self.unsplit_trigger}"
            )
        for knob in ("min_batches_between", "resize_patience",
                     "resize_cooldown", "backend_patience",
                     "backend_cooldown", "split_patience", "split_cooldown",
                     "snapshot_interval", "health_patience",
                     "health_cooldown", "health_recover_after",
                     "health_straggler_ms", "target_throughput"):
            if getattr(self, knob) < 0:
                raise ValueError(
                    f"{knob} must be >= 0, got {getattr(self, knob)!r}")
        if self.health_failure_threshold < 1:
            raise ValueError(
                "health_failure_threshold must be >= 1 (0 would evict a "
                f"healthy lane), got {self.health_failure_threshold!r}")


@dataclasses.dataclass(frozen=True)
class DRDecision:
    repartition: bool
    partitioner: Partitioner
    planned_imbalance: float
    measured_imbalance: float
    est_migration: float
    reason: str


class DRMaster:
    def __init__(self, initial: Partitioner, config: DRConfig = DRConfig(),
                 *, consumer: str = "stream",
                 exchange_backend: str | object | None = None,
                 exchange_topology: "ExchangeTopology | None" = None):
        self.config = config
        self.partitioner = initial
        # the transport the hosted runtime exchanges through — its sizing
        # rule prices candidate migration plans (exchange_lane_cost), so the
        # repartition gate reflects what would actually move.  None = dense.
        self.exchange_backend = resolve_backend(exchange_backend)
        # the lanes' physical locality — with it, plan pricing weighs each
        # (src, dst) cell by distance class (exchange_lane_cost's topology
        # kwarg), so equal-balance plans that stay inside a host win.
        # None = the flat world: every lane priced alike.
        self.exchange_topology = exchange_topology
        self.sketch = CounterSketch(config.sketch_capacity, decay=config.sketch_decay)
        self.batches_seen = 0
        self.last_repartition = -(10**9)
        self.last_resize = -(10**9)
        self.last_backend_switch = -(10**9)
        self.history: list[dict] = []
        # elastic-resize policy state: how many consecutive safe points the
        # grow/shrink condition has held (the "sustained" part of the policy)
        self.grow_streak = 0
        self.shrink_streak = 0
        # backend-actuator state: how long the padding fraction has sat
        # beyond the active transport's flip threshold
        self.backend_streak = 0
        # hot-key splitting state: the installed replica map (key -> d),
        # re-stamped onto every partitioner this master installs, plus the
        # SplitPolicy's patience streak and cooldown stamp
        self.split_keys: dict[int, int] = dict(initial.split_map())
        self.split_streak = 0
        self.last_split = -(10**9)
        # failure-domain state: per-live-lane health (built lazily from the
        # first safe point's worker count), the quarantine ledger — (lane
        # label, tick quarantined), oldest first — and the health cooldown
        self.lane_health: LaneHealth | None = None
        self.quarantined: list[tuple[int, int]] = []
        self.last_health_action = -(10**9)
        # the policy stack this master hosts + its decision log
        self.repartition_policy = RepartitionPolicy()
        self.resize_policy = ResizePolicy()
        self.backend_policy = BackendPolicy()
        self.split_policy = SplitPolicy()
        self.health_policy = HealthPolicy()
        self.decisions = DecisionLog(consumer)

    # -- DRW ingestion ------------------------------------------------------
    def observe(self, hist_keys: np.ndarray, hist_counts: np.ndarray,
                total_records: float | None = None) -> None:
        """Merge stacked worker histograms [W, K] into the DRM sketch.

        ``total_records`` is the true number of records the workers saw
        (top-k summaries undercount the tail mass)."""
        k = np.asarray(hist_keys).reshape(-1)
        c = np.asarray(hist_counts).reshape(-1).astype(np.float64)
        m = (k >= 0) & (c > 0)
        if m.any():
            keys, inv = np.unique(k[m], return_inverse=True)
            counts = np.zeros(len(keys))
            np.add.at(counts, inv, c[m])
            self.sketch.update_counts(keys.astype(np.int64), counts, total=total_records)

    # -- the one safe-point entry -------------------------------------------
    def evaluate(self, signals: Signals, *, requested_resize: int | None = None,
                 policies_enabled: bool = True) -> Action:
        """Run the policy stack over one safe point's signals.

        **This is the one public decision API.**  Drivers feed a
        :class:`~repro.control.Signals` record in and execute the returned
        typed action; the single-policy wrappers :meth:`decide` and
        :meth:`decide_resize` are deprecated compatibility shims over the
        same stack and take no part in the safe-point protocol.

        Precedence mirrors the safe-point protocol: an explicit resize
        request wins (it is this safe point's decision), then the elastic
        :class:`ResizePolicy` (topology), then the :class:`SplitPolicy`
        (hot-key replication — a key one worker cannot hold must split
        before a repartition wastes a migration shuffling it around), then
        the :class:`RepartitionPolicy` (contents), then the
        :class:`BackendPolicy` (transport).  A taken repartition or
        split/unsplit is installed here (partitioner swap/re-stamp +
        bookkeeping); a taken resize is *returned* for the driver to
        execute via :meth:`replan_resize`, and a taken unsplit is likewise
        returned so the driver runs the merging migration — state only
        moves in the driver.  Every safe-point outcome lands in
        :attr:`decisions` (non-safe-point calls are peeks, not decisions,
        and are not logged).
        """
        n = self.partitioner.num_partitions
        detail: dict = {}
        if not signals.at_safe_point:
            # not a decision point: nothing to log — the decision log counts
            # safe points only, else a checkpoint_interval > 1 stream buries
            # the real decisions under per-batch "not-checkpoint-tick" noise
            return NoOp("not-checkpoint-tick", signals.imbalance)
        if requested_resize is not None and int(requested_resize) != n:
            action = Resize(reason=f"resize {n}->{int(requested_resize)}",
                            target=int(requested_resize), requested=True)
        elif not policies_enabled:
            action = NoOp("dr-disabled", signals.imbalance)
        else:
            # failure domains first: a sick lane invalidates every
            # load-based signal the policies below would key on
            action = self._evaluate_health(signals, detail)
            if action is None:
                action = self.resize_policy.evaluate(self, signals)
                if isinstance(action, NoOp):
                    if action.reason != "elastic-disabled":
                        detail["resize_declined"] = action.reason
                    action = self.split_policy.evaluate(self, signals)
            if isinstance(action, (Split, Unsplit)):
                self._install_split(action)
            elif isinstance(action, NoOp):
                if action.reason != "split-disabled":
                    detail["split_declined"] = action.reason
                action = self.repartition_policy.evaluate(self, signals)
                if isinstance(action, Repartition):
                    self._install(action)
                elif isinstance(action, NoOp):
                    # nothing structural fired: the transport actuator may
                    # still flip dense <-> ragged on the measured occupancy
                    switch = self.backend_policy.evaluate(self, signals)
                    if isinstance(switch, SwitchBackend):
                        self.note_backend_switch(switch.backend)
                        action = switch
                    elif switch.reason != "auto-backend-disabled":
                        detail["backend_declined"] = switch.reason
        self.decisions.record(action, tick=self.batches_seen,
                              imbalance=signals.imbalance, detail=detail)
        return action

    def _evaluate_health(self, signals: Signals, detail: dict) -> Action | None:
        """Run the failure-domain policy first in the evaluate precedence.

        Folds the window's fault evidence into :class:`LaneHealth` (built
        lazily at the live worker count — a restore onto a shrunk topology
        starts the health view fresh) and returns a *taken* health action,
        bookkept, or ``None`` to fall through to the load policies."""
        if self.config.health_enabled:
            w = max(int(signals.num_workers), 1)
            if self.lane_health is None or self.lane_health.num_lanes != w:
                self.lane_health = LaneHealth(w, alpha=self.config.ewma_alpha)
            self.lane_health.observe(signals)
        action = self.health_policy.evaluate(self, signals)
        if action.taken:
            self._note_health(action)
            return action
        if action.reason != "health-disabled":
            detail["health_declined"] = action.reason
        return None

    def _note_health(self, action: Action) -> None:
        """Install a taken health action (DRM bookkeeping).  Counts as this
        safe point's decision — advances ``batches_seen`` and stamps
        ``last_repartition`` like every state-moving install, plus the
        health cooldown; the *driver* reshapes the mesh and folds the
        state."""
        self.batches_seen += 1
        self.last_health_action = self.batches_seen
        self.last_repartition = self.batches_seen
        if isinstance(action, Quarantine):
            self.quarantined.append((int(action.lane), self.batches_seen))
            if (self.lane_health is not None
                    and int(action.lane) < self.lane_health.num_lanes):
                self.lane_health.drop_lane(int(action.lane))
        elif isinstance(action, Evict):
            if (self.lane_health is not None
                    and 0 <= int(action.lane) < self.lane_health.num_lanes):
                self.lane_health.drop_lane(int(action.lane))
        elif isinstance(action, Recover):
            if self.quarantined:
                self.quarantined.pop(0)
            if self.lane_health is not None:
                self.lane_health.add_lane()
        self.history.append({
            "batch": self.batches_seen,
            "health": (action.kind, int(getattr(action, "lane", -1))),
            "reason": action.reason,
        })

    def note_lost(self, lane: int, *, reason: str) -> None:
        """Record a hard worker loss the recovery protocol discovered as a
        forced :class:`Evict` — failures land in the decision log exactly
        like policy decisions, reasons and all.  ``lane`` is the lost
        lane's *original* label (the live mesh no longer contains it)."""
        action = Evict(reason=reason, lane=int(lane))
        # the label indexes the *lost* topology — drop the stale health
        # view; the next safe point rebuilds it at the surviving width
        self.lane_health = None
        self._note_health(action)
        self.decisions.record(action, tick=self.batches_seen, imbalance=1.0,
                              detail={"forced": "worker-lost"})

    def _install(self, action: Repartition) -> None:
        """Swap in a taken repartition at the safe point (DRM bookkeeping)."""
        self.partitioner = action.partitioner
        if self.split_keys:
            # kip_update plans over plain homes; installed splits survive a
            # content swap — re-stamp the replica column onto the new tables
            self.partitioner = self.partitioner.with_splits(self.split_keys)
        self.last_repartition = self.batches_seen
        d = DRDecision(True, action.partitioner, action.planned_imbalance,
                       action.measured_imbalance, action.est_migration, "repartition")
        self.history.append(dataclasses.asdict(d) | {"batch": self.batches_seen})

    def _install_split(self, action: Split | Unsplit) -> None:
        """Install a taken split/unsplit at the safe point (DRM bookkeeping).

        Counts as this safe point's decision (advances ``batches_seen`` the
        way a policy evaluation would) and re-stamps the replica table.  A
        :class:`Split` is install-only — routing fans out from the next
        batch, no state moves.  An :class:`Unsplit` removes the key here;
        the *driver* runs the home-routed migration off ``action.prev``
        that merges the scattered partials, so it stamps
        ``last_repartition`` like any other state-moving install.
        """
        self.batches_seen += 1
        if isinstance(action, Split):
            self.split_keys[int(action.key)] = int(action.replicas)
        else:
            self.split_keys.pop(int(action.key), None)
            self.last_repartition = self.batches_seen
        self.partitioner = self.partitioner.with_splits(self.split_keys)
        self.last_split = self.batches_seen
        self.split_streak = 0
        self.history.append({
            "batch": self.batches_seen,
            "split": (action.kind, int(action.key),
                      int(getattr(action, "replicas", 1))),
            "reason": action.reason,
        })

    def _as_decision(self, action: Action) -> DRDecision:
        if isinstance(action, Repartition):
            return DRDecision(True, action.partitioner, action.planned_imbalance,
                              action.measured_imbalance, action.est_migration,
                              "repartition")
        assert isinstance(action, NoOp), action
        return DRDecision(False, self.partitioner, action.planned_imbalance,
                          action.measured_imbalance, action.est_migration,
                          action.reason)

    # -- single-policy wrappers (the pre-control-plane API) ------------------
    def decide(self, loads: np.ndarray, state_rows: float = 0.0) -> DRDecision:
        """Run only the repartition policy on measured per-partition loads.

        .. deprecated:: Kept for callers predating the control plane.  Use
           :meth:`evaluate` — the one safe-point decision API — with a
           :class:`~repro.control.Signals` record; ``decide`` bypasses the
           resize/split/backend policies and the explicit-request protocol.
        """
        signals = Signals(loads=np.asarray(loads, np.float64),
                          state_rows=int(state_rows))
        action = self.repartition_policy.evaluate(self, signals)
        if isinstance(action, Repartition):
            self._install(action)
        self.decisions.record(action, tick=self.batches_seen,
                              imbalance=signals.imbalance)
        return self._as_decision(action)

    def decide_resize(self, loads: np.ndarray, *, num_workers: int = 1) -> int | None:
        """Run only the elastic resize policy; returns the new partition
        count, or ``None`` to keep the topology.

        .. deprecated:: Kept for callers predating the control plane.  Use
           :meth:`evaluate` and match on the returned
           :class:`~repro.control.Resize` — this wrapper skips decision
           logging and the rest of the policy stack.
        """
        signals = Signals(loads=np.asarray(loads, np.float64),
                          num_workers=num_workers)
        action = self.resize_policy.evaluate(self, signals)
        return action.target if isinstance(action, Resize) else None

    def replan_resize(self, num_partitions: int) -> Partitioner:
        """Re-plan the partitioner cross-size and install it at a safe point.

        The one resize re-planning path shared by ``StreamingJob`` and
        ``DRScheduler``: the sketch is re-warmed first (its ``lam * n``
        heavy-key budget changes meaning across the resize — stale
        floor-dominated tail entries must not surface as heavy keys under
        the grown budget), heavy keys come from the re-warmed sketch, the
        heavy-table width follows the new topology, and the swap is
        recorded via :meth:`note_resize`.
        """
        cfg = self.config
        n = int(num_partitions)
        self.sketch.rescale()
        hist = self.sketch.histogram(top_b=int(np.ceil(cfg.lam * n)))
        heavy_cap = heavy_capacity_for(cfg.lam, n)
        new = resize_partitioner(self.partitioner, n, hist, eps=cfg.eps,
                                 heavy_capacity=heavy_cap, tight=cfg.tight)
        if self.split_keys:
            # installed splits survive the resize; with_splits clamps each
            # fan-out to the new partition count (a shrink may fold a d all
            # the way to 1, dropping the key from the map)
            new = new.with_splits(self.split_keys)
            self.split_keys = dict(new.split_map())
        self.note_resize(new)
        return new

    def note_backend_switch(self, backend: str | object) -> None:
        """Install a taken backend switch (DRM bookkeeping).

        The DRM's own transport flips immediately — plan pricing
        (``exchange_lane_cost``) must follow the transport the job is about
        to run — and the cooldown stamp starts the hysteresis window.  The
        *driver* rebuilds its jitted steps for the new backend (same
        contract as a resize: state never moves here).
        """
        old = self.exchange_backend.name
        self.exchange_backend = resolve_backend(backend)
        self.last_backend_switch = self.batches_seen
        self.backend_streak = 0
        self.history.append({
            "batch": self.batches_seen,
            "backend": (old, self.exchange_backend.name),
            "reason": f"backend {old}->{self.exchange_backend.name}",
        })

    def note_resize(self, new: Partitioner) -> None:
        """Install a resized partitioner at a safe point (DRM bookkeeping).

        Counts as this safe point's decision: advances ``batches_seen`` and
        ``last_repartition`` so the safe-point spacing applies to resizes
        exactly as to plain repartitions, and stamps ``last_resize`` for the
        cooldown guard.
        """
        old_n = self.partitioner.num_partitions
        self.batches_seen += 1
        self.partitioner = new
        self.last_repartition = self.batches_seen
        self.last_resize = self.batches_seen
        self.grow_streak = self.shrink_streak = 0
        self.history.append({
            "batch": self.batches_seen,
            "resize": (old_n, new.num_partitions),
            "reason": f"resize {old_n}->{new.num_partitions}",
        })

    # -- checkpoint integration ----------------------------------------------
    def snapshot(self) -> dict:
        p = self.partitioner
        split_items = sorted(self.split_keys.items())
        return {
            "num_partitions": p.num_partitions,
            "heavy_keys": p.heavy_keys,
            "heavy_parts": p.heavy_parts,
            "host_to_part": p.host_to_part,
            "seed": p.seed,
            # replica table + split-policy state ride the snapshot exactly
            # like the partitioner tables they re-stamp
            "heavy_repl": (p.heavy_repl if p.heavy_repl is not None
                           else np.ones(p.heavy_keys.shape[0], np.int32)),
            "split_keys": np.asarray([k for k, _ in split_items], np.int64),
            "split_repl": np.asarray([d for _, d in split_items], np.int64),
            "last_split": np.int64(self.last_split),
            "split_streak": np.int64(self.split_streak),
            "sketch_keys": self.sketch._keys,
            "sketch_counts": self.sketch._counts,
            "sketch_floor": np.float64(self.sketch._floor),
            "sketch_total": np.float64(self.sketch.total),
            "batches_seen": np.int64(self.batches_seen),
            "last_repartition": np.int64(self.last_repartition),
            "last_resize": np.int64(self.last_resize),
            "grow_streak": np.int64(self.grow_streak),
            "shrink_streak": np.int64(self.shrink_streak),
            "last_backend_switch": np.int64(self.last_backend_switch),
            "backend_streak": np.int64(self.backend_streak),
            "exchange_backend": np.str_(self.exchange_backend.name),
            # topology rides the snapshot as its three scalars (absent on a
            # flat job so legacy snapshot round-trips stay byte-stable)
            **({
                "topology_lanes_per_host":
                    np.int64(self.exchange_topology.lanes_per_host),
                "topology_num_lanes": np.int64(self.exchange_topology.num_lanes),
                "topology_class_weights": np.asarray(
                    self.exchange_topology.class_weights, np.float64),
            } if self.exchange_topology is not None else {}),
            # failure-domain state rides only when the layer is live, so
            # legacy snapshot round-trips stay byte-stable
            **(self.lane_health.snapshot()
               if self.lane_health is not None else {}),
            **({
                "quarantined_lane": np.asarray(
                    [l for l, _ in self.quarantined], np.int64),
                "quarantined_tick": np.asarray(
                    [t for _, t in self.quarantined], np.int64),
                "last_health_action": np.int64(self.last_health_action),
            } if (self.quarantined or self.lane_health is not None) else {}),
            # decision log: a restored job keeps its decision history
            **self.decisions.to_arrays(),
        }

    @classmethod
    def restore(cls, snap: dict, config: DRConfig = DRConfig()) -> "DRMaster":
        p = Partitioner(
            int(snap["num_partitions"]),
            np.asarray(snap["heavy_keys"]),
            np.asarray(snap["heavy_parts"]),
            np.asarray(snap["host_to_part"]),
            int(snap["seed"]),
            # legacy snapshots predate the replica table: None = no splits
            heavy_repl=(np.asarray(snap["heavy_repl"], np.int32)
                        if "heavy_repl" in snap else None),
        )
        topo = None
        if "topology_lanes_per_host" in snap:
            topo = ExchangeTopology(
                num_lanes=int(snap.get("topology_num_lanes",
                                       snap["num_partitions"])),
                lanes_per_host=int(snap["topology_lanes_per_host"]),
                class_weights=tuple(
                    np.asarray(snap["topology_class_weights"], np.float64)
                ) if "topology_class_weights" in snap else (0.0, 1.0, 10.0),
            )
        drm = cls(p, config, consumer=str(snap.get("decisions_consumer", "stream")),
                  exchange_backend=str(snap["exchange_backend"])
                  if "exchange_backend" in snap else None,
                  exchange_topology=topo)
        drm.sketch._keys = np.asarray(snap["sketch_keys"])
        drm.sketch._counts = np.asarray(snap["sketch_counts"])
        drm.sketch._floor = float(snap["sketch_floor"])
        drm.sketch.total = float(snap["sketch_total"])
        drm.batches_seen = int(snap["batches_seen"])
        if "last_repartition" in snap:  # older snapshots predate this field
            drm.last_repartition = int(snap["last_repartition"])
        # control-plane fields (older snapshots predate these)
        drm.last_resize = int(snap.get("last_resize", -(10**9)))
        drm.grow_streak = int(snap.get("grow_streak", 0))
        drm.shrink_streak = int(snap.get("shrink_streak", 0))
        drm.last_backend_switch = int(snap.get("last_backend_switch", -(10**9)))
        drm.backend_streak = int(snap.get("backend_streak", 0))
        # split-policy state (the replica map itself was restored from the
        # partitioner's heavy_repl column via __init__'s split_map seed)
        if "split_keys" in snap:
            drm.split_keys = dict(zip(
                np.asarray(snap["split_keys"]).astype(int).tolist(),
                np.asarray(snap["split_repl"]).astype(int).tolist(),
            ))
        drm.last_split = int(snap.get("last_split", -(10**9)))
        drm.split_streak = int(snap.get("split_streak", 0))
        # failure-domain state (older snapshots predate the health layer)
        if "health_num_lanes" in snap:
            drm.lane_health = LaneHealth.restore(snap,
                                                 alpha=config.ewma_alpha)
        if "quarantined_lane" in snap:
            drm.quarantined = list(zip(
                np.asarray(snap["quarantined_lane"]).astype(int).tolist(),
                np.asarray(snap["quarantined_tick"]).astype(int).tolist(),
            ))
        drm.last_health_action = int(snap.get("last_health_action", -(10**9)))
        # decision history (older snapshots predate the log — empty is fine)
        if "decisions_tick" in snap:
            drm.decisions = DecisionLog.from_arrays(snap)
        return drm
