"""Unified exchange plane — one routed all-to-all subsystem for shuffle,
state migration, and MoE dispatch.  See :mod:`repro.exchange.plane`."""
from repro.exchange.plane import (
    Exchange,
    ExchangeResult,
    ExchangeSpec,
    Payload,
    SendInfo,
    make_exchange,
    route_dispatch,
    take_from,
)

__all__ = [
    "Exchange",
    "ExchangeResult",
    "ExchangeSpec",
    "Payload",
    "SendInfo",
    "make_exchange",
    "route_dispatch",
    "take_from",
]
