"""Dynamic Repartitioning (DR) — the paper's core contribution in JAX.

Public surface:

* partitioners: :func:`uniform_partitioner`, :func:`kip_update`,
  :class:`Partitioner`, :class:`PartitionerTables`
* histograms/sketches: :class:`Histogram`, :class:`CounterSketch`,
  :class:`SpaceSaving`, :class:`LossyCounting`, :class:`CountMinSketch`
* migration: :func:`plan_migration`, :class:`MigrationPlan`
* runtime: :class:`repro.core.streaming.StreamingJob` (micro-batch DR loop),
  :mod:`repro.core.shuffle` (device keyed all-to-all)
"""
from repro.core.baselines import make_baseline, mixed_update, readj_update, redist_update, scan_update
from repro.core.histogram import (
    CounterSketch,
    CountMinSketch,
    Histogram,
    LossyCounting,
    SpaceSaving,
    local_topk_histogram,
)
from repro.core.migration import MigrationPlan, migration_capacity, plan_migration
from repro.core.partitioner import (
    Partitioner,
    PartitionerTables,
    expected_loads,
    kip_update,
    load_imbalance,
    lookup_device,
    resize_partitioner,
    uniform_partitioner,
)

__all__ = [
    "CounterSketch",
    "CountMinSketch",
    "Histogram",
    "LossyCounting",
    "MigrationPlan",
    "Partitioner",
    "PartitionerTables",
    "SpaceSaving",
    "expected_loads",
    "kip_update",
    "load_imbalance",
    "local_topk_histogram",
    "lookup_device",
    "make_baseline",
    "migration_capacity",
    "mixed_update",
    "plan_migration",
    "readj_update",
    "redist_update",
    "resize_partitioner",
    "scan_update",
    "uniform_partitioner",
]
