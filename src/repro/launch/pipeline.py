"""GPipe-style pipeline parallelism over the ``pod`` axis (selectable).

Multi-pod strategy: instead of treating the second pod as extra data
parallelism, the layer stack is split into ``n_pod`` contiguous stages;
microbatches stream through the stages with activations handed across pods
by ``ppermute`` (cross-pod ICI is the scarce link — PP sends one activation
tensor per microbatch instead of gradient all-reduces over the full model).

Implementation: ``shard_map`` manual over ``pod`` only (data/model stay
GSPMD-auto inside the body), the classic M+S-1 tick loop, stage params
sliced from a [n_pod, ...] stack.  Supports uniform-pattern decoder archs
(pattern length 1, no tail).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.attention import head_layout
from repro.models.modules import Policy, chunked_softmax_xent, embed, pad_vocab, unembed_logits


def stack_stage_params(cfg: ArchConfig, params: dict, n_stages: int) -> dict:
    """Re-stack blocks [periods, ...] -> [n_stages, periods/n_stages, ...]."""
    assert len(cfg.pattern) == 1 and not cfg.tail, "PP supports uniform-pattern archs"
    per = cfg.num_periods
    assert per % n_stages == 0
    blocks = jax.tree.map(
        lambda a: a.reshape((n_stages, per // n_stages) + a.shape[1:]),
        params["blocks"],
    )
    return {**params, "blocks": blocks}


def make_pp_loss(cfg: ArchConfig, pol: Policy, mesh: Mesh, *, microbatches: int):
    """Pipelined loss over the pod axis.  batch [B, S] split into M
    microbatches; returns mean loss (identical math to the unpiped model)."""
    n_stages = mesh.shape["pod"]
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, pol.tp)

    def stage_blocks(blocks_stage, x, pos):
        def body(carry, per_params):
            y, _, _ = transformer._apply_block(
                cfg.pattern[0], per_params["b0"], carry, cfg, lay, pol, pos=pos)
            return y, None
        if pol.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, blocks_stage)
        return x

    def pp_body(stage_params, embed_tok, lm_head, final_norm, tokens, labels, mask):
        # manual over "pod": P("pod") args arrive as [1, ...] — drop the
        # stage axis to get this stage's own parameter stack
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        sid = jax.lax.axis_index("pod")
        b, s = tokens.shape
        m = microbatches
        mb = b // m
        d = cfg.d_model
        pos = transformer._positions(cfg, mb, s, 0)
        ticks = m + n_stages - 1
        buf_in = jnp.zeros((mb, s, d), pol.compute_dtype)
        # rank-1 carries: old shard_map's transpose rank-check rejects
        # rank-0 residuals
        losses = jnp.zeros((1,), jnp.float32)
        denom = jnp.zeros((1,), jnp.float32)

        def tick(t, carry):
            buf_in, losses, denom = carry
            mb_idx = jnp.clip(t - sid, 0, m - 1)
            tok_mb = jax.lax.dynamic_slice_in_dim(tokens, mb_idx * mb, mb, 0)
            lab_mb = jax.lax.dynamic_slice_in_dim(labels, mb_idx * mb, mb, 0)
            msk_mb = jax.lax.dynamic_slice_in_dim(mask, mb_idx * mb, mb, 0)
            # stage 0 embeds its microbatch; later stages consume the buffer
            x0 = embed({"tok": embed_tok}, tok_mb, scale=cfg.embed_scale, d=d, pol=pol)
            x = jnp.where(sid == 0, x0, buf_in)
            active = (t >= sid) & (t - sid < m)
            y = stage_blocks(stage_params["blocks"], x, pos)
            y = jnp.where(active, y, 0.0)
            # last stage: norm + loss for its finished microbatch
            from repro.models.modules import apply_norm

            h = apply_norm(final_norm, y, cfg.norm_kind)
            mb_loss = chunked_softmax_xent(
                h, lm_head, lab_mb, msk_mb, pol, cfg.vocab_size,
                chunk=min(512, s))
            is_last = sid == n_stages - 1
            losses = losses + jnp.where(is_last & active, mb_loss, 0.0)
            denom = denom + jnp.where(is_last & active, 1.0, 0.0)
            # hand activations to the next stage
            nxt = jax.lax.ppermute(y, "pod",
                                   [(i, i + 1) for i in range(n_stages - 1)])
            return (nxt, losses, denom)

        buf_in, losses, denom = jax.lax.fori_loop(
            0, ticks, tick, (buf_in, losses, denom))
        total = jax.lax.psum(losses, "pod")  # only last stage contributed
        cnt = jax.lax.psum(denom, "pod")
        # emit the (replicated) loss as a pod-mapped [1] output: transposing
        # an unmapped P() output through jax.grad is unsupported on older
        # shard_map, and the mean outside is identical math
        return total / jnp.maximum(cnt, 1.0)

    mapped = shard_map(
        pp_body,
        mesh=mesh,
        in_specs=(P("pod"), P(), P(), P(), P(), P(), P()),
        out_specs=P("pod"),
        axis_names=frozenset({"pod"}),
        check_vma=False,
    )

    def loss_fn(stacked_params, batch):
        per_stage = mapped(
            {"blocks": stacked_params["blocks"]},
            stacked_params["embed"]["tok"],
            stacked_params.get("lm_head", stacked_params["embed"]["tok"]),
            stacked_params["final_norm"],
            batch["tokens"], batch["labels"], batch["mask"],
        )
        return jnp.mean(per_stage)

    return loss_fn
