"""Approximate key-frequency histograms (DRW sampling + DRM merging).

The paper gathers the top ``B = lambda * N`` keys in a global histogram
``Hist`` whose entries carry *relative* frequencies (all key frequencies,
including keys not in Hist, sum to 1).  Workers build small local summaries
during normal routing work; the master merges them and keeps a record of past
histograms so partitioning decisions respect concept drift.

Host-side sketches implemented here:

* :class:`CounterSketch`   — the paper's counter-based heuristic (their
  extended-paper algorithm is reconstructed as a mergeable SpaceSaving-style
  counter table with multiplicative decay for drift).
* :class:`SpaceSaving`     — Metwally et al. (baseline in the paper).
* :class:`LossyCounting`   — Manku & Motwani (baseline in the paper).
* :class:`CountMinSketch`  — classic sketch baseline (the paper found sketches
  either inaccurate or memory-hungry; we reproduce that comparison).

Device-side: :func:`local_topk_histogram` — an exact, sort-based top-k of a
single micro-batch computed inside jit (the DRW hook); the Pallas
``sketch_update`` kernel provides the CMS hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import fmix32

__all__ = [
    "Histogram",
    "CounterSketch",
    "SpaceSaving",
    "LossyCounting",
    "CountMinSketch",
    "local_topk_histogram",
]


@dataclasses.dataclass(frozen=True)
class Histogram:
    """Top-B histogram with *relative* frequencies, sorted descending.

    ``keys[i]`` has estimated frequency ``freqs[i]`` (fraction of all input).
    ``sum(freqs) <= 1``; the remainder is the untracked tail mass.
    """

    keys: np.ndarray  # int64[B]
    freqs: np.ndarray  # float64[B], descending
    total_weight: float  # absolute number of records observed

    def __post_init__(self):
        assert self.keys.shape == self.freqs.shape
        if len(self.freqs) > 1:
            assert np.all(np.diff(self.freqs) <= 1e-12), "freqs must be descending"

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def tail_mass(self) -> float:
        return max(0.0, 1.0 - float(self.freqs.sum()))

    def top(self, b: int) -> "Histogram":
        return Histogram(self.keys[:b], self.freqs[:b], self.total_weight)

    @staticmethod
    def from_counts(keys, counts, total: float | None = None) -> "Histogram":
        keys = np.asarray(keys, dtype=np.int64)
        counts = np.asarray(counts, dtype=np.float64)
        order = np.argsort(-counts, kind="stable")
        keys, counts = keys[order], counts[order]
        total = float(counts.sum()) if total is None else float(total)
        freqs = counts / max(total, 1e-30)
        return Histogram(keys, freqs, total)

    @staticmethod
    def exact(key_stream: np.ndarray) -> "Histogram":
        keys, counts = np.unique(np.asarray(key_stream), return_counts=True)
        return Histogram.from_counts(keys, counts)

    @staticmethod
    def merge(hists: Sequence["Histogram"], top_b: int | None = None) -> "Histogram":
        """DRM merge of per-worker local histograms (weight = records seen)."""
        if not hists:
            return Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
        acc: dict[int, float] = {}
        total = 0.0
        for h in hists:
            total += h.total_weight
            w = h.total_weight
            for k, f in zip(h.keys.tolist(), h.freqs.tolist()):
                acc[k] = acc.get(k, 0.0) + f * w
        merged = Histogram.from_counts(
            np.fromiter(acc.keys(), np.int64, len(acc)),
            np.fromiter(acc.values(), np.float64, len(acc)),
            total=total,
        )
        return merged.top(top_b) if top_b is not None else merged

    def ewma(self, newer: "Histogram", alpha: float, top_b: int | None = None) -> "Histogram":
        """Drift-respecting blend: keep a record of past histograms.

        ``alpha`` is the weight of the *new* histogram; old mass decays by
        ``1 - alpha`` so heavy keys must persist to stay isolated.
        """
        acc: dict[int, float] = {}
        for k, f in zip(self.keys.tolist(), self.freqs.tolist()):
            acc[k] = acc.get(k, 0.0) + (1.0 - alpha) * f
        for k, f in zip(newer.keys.tolist(), newer.freqs.tolist()):
            acc[k] = acc.get(k, 0.0) + alpha * f
        keys = np.fromiter(acc.keys(), np.int64, len(acc))
        vals = np.fromiter(acc.values(), np.float64, len(acc))
        order = np.argsort(-vals, kind="stable")
        out = Histogram(keys[order], vals[order], newer.total_weight)
        return out.top(top_b) if top_b is not None else out


# ---------------------------------------------------------------------------
# Host-side sketches
# ---------------------------------------------------------------------------


class CounterSketch:
    """The DRW counter-based heuristic (paper §4 / extended paper).

    A fixed table of ``capacity`` (key, count) pairs.  Batches are counted
    exactly (vectorized ``np.unique``) and merged with the SpaceSaving merge
    rule: evicted keys donate their count to the minimum-count floor so the
    estimate stays an over-approximation.  A multiplicative ``decay`` applied
    per batch makes the summary drift-respecting: keys that stop being heavy
    fade out within a few micro-batches.
    """

    def __init__(self, capacity: int, decay: float = 1.0):
        assert capacity > 0 and 0.0 < decay <= 1.0
        self.capacity = capacity
        self.decay = decay
        self._keys = np.zeros(0, np.int64)
        self._counts = np.zeros(0, np.float64)
        self._floor = 0.0  # SpaceSaving-style minimum for unseen keys
        self.total = 0.0

    def update(self, key_batch: np.ndarray) -> None:
        keys, counts = np.unique(np.asarray(key_batch, np.int64), return_counts=True)
        self.update_counts(keys, counts.astype(np.float64))

    def update_counts(self, keys: np.ndarray, counts: np.ndarray,
                      total: float | None = None) -> None:
        """``total``: true number of records the counts were sampled from
        (a top-k summary undercounts the tail; without the true total the
        relative frequencies would be inflated by 1/coverage)."""
        if self.decay < 1.0:
            self._counts *= self.decay
            self._floor *= self.decay
            self.total *= self.decay
        self.total += float(counts.sum()) if total is None else float(total)
        # merge exact batch counts into the summary
        all_keys = np.concatenate([self._keys, np.asarray(keys, np.int64)])
        new_mask = np.concatenate(
            [np.zeros(len(self._keys), bool), np.ones(len(keys), bool)]
        )
        all_counts = np.concatenate([self._counts, np.asarray(counts, np.float64)])
        # keys new to the summary enter at floor + their batch count
        all_counts = all_counts + np.where(new_mask, self._floor, 0.0)
        uniq, inv = np.unique(all_keys, return_inverse=True)
        merged = np.zeros(len(uniq))
        np.add.at(merged, inv, all_counts)
        # a key present both in summary and batch was given the floor once: ok
        dup = np.zeros(len(uniq))
        np.add.at(dup, inv, new_mask & np.isin(all_keys, self._keys))
        merged -= dup * self._floor
        if len(uniq) > self.capacity:
            order = np.argsort(-merged, kind="stable")
            keep = order[: self.capacity]
            self._floor = float(merged[order[self.capacity]])
            self._keys, self._counts = uniq[keep], merged[keep]
        else:
            self._keys, self._counts = uniq, merged

    def histogram(self, top_b: int | None = None) -> Histogram:
        h = Histogram.from_counts(self._keys, self._counts, total=max(self.total, 1e-30))
        return h.top(top_b) if top_b is not None else h

    def rescale(self) -> int:
        """Re-warm the summary when its heavy-key budget changes meaning.

        The DRM reads the top ``B = lam * N`` entries; an elastic resize
        jumps ``N``, so a *grow* suddenly reads deeper into the table —
        into entries whose count is dominated by the SpaceSaving floor
        (the over-approximation every evicted key donates on entry) rather
        than by observed traffic.  Those stale-tail entries would surface
        as freshly isolated "heavy" keys purely because they entered the
        table recently.  Dropping every entry without at least a floor's
        worth of evidence beyond the inherited floor (``count < 2 * floor``)
        re-warms the summary: surviving entries are backed by real counts,
        and genuinely heavy keys sit far above the cut.  Returns the number
        of entries dropped.  A no-op while the table has never evicted
        (``floor == 0`` — every count is exact).
        """
        if self._floor <= 0.0 or len(self._keys) == 0:
            return 0
        keep = self._counts >= 2.0 * self._floor
        dropped = int((~keep).sum())
        if dropped:
            self._keys = self._keys[keep]
            self._counts = self._counts[keep]
        return dropped

    @property
    def memory_items(self) -> int:
        return len(self._keys)


class SpaceSaving:
    """Metwally et al. stream-summary (sequential reference implementation)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.counts: dict[int, float] = {}
        self.total = 0.0

    def update(self, key_batch: np.ndarray) -> None:
        for k in np.asarray(key_batch).tolist():
            self.total += 1.0
            if k in self.counts:
                self.counts[k] += 1.0
            elif len(self.counts) < self.capacity:
                self.counts[k] = 1.0
            else:
                mk = min(self.counts, key=self.counts.get)
                mv = self.counts.pop(mk)
                self.counts[k] = mv + 1.0

    def histogram(self, top_b: int | None = None) -> Histogram:
        if not self.counts:
            return Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
        h = Histogram.from_counts(
            np.fromiter(self.counts.keys(), np.int64, len(self.counts)),
            np.fromiter(self.counts.values(), np.float64, len(self.counts)),
            total=max(self.total, 1e-30),
        )
        return h.top(top_b) if top_b is not None else h

    @property
    def memory_items(self) -> int:
        return len(self.counts)


class LossyCounting:
    """Manku & Motwani lossy counting with bucket width ceil(1/eps)."""

    def __init__(self, epsilon: float):
        self.epsilon = epsilon
        self.width = int(np.ceil(1.0 / epsilon))
        self.counts: dict[int, float] = {}
        self.deltas: dict[int, float] = {}
        self.total = 0.0
        self._bucket = 1

    def update(self, key_batch: np.ndarray) -> None:
        for k in np.asarray(key_batch).tolist():
            self.total += 1.0
            if k in self.counts:
                self.counts[k] += 1.0
            else:
                self.counts[k] = 1.0
                self.deltas[k] = self._bucket - 1
            if int(self.total) % self.width == 0:
                self._prune()
                self._bucket += 1

    def _prune(self) -> None:
        dead = [k for k, c in self.counts.items() if c + self.deltas[k] <= self._bucket]
        for k in dead:
            del self.counts[k]
            del self.deltas[k]

    def histogram(self, top_b: int | None = None) -> Histogram:
        if not self.counts:
            return Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
        h = Histogram.from_counts(
            np.fromiter(self.counts.keys(), np.int64, len(self.counts)),
            np.fromiter(self.counts.values(), np.float64, len(self.counts)),
            total=max(self.total, 1e-30),
        )
        return h.top(top_b) if top_b is not None else h

    @property
    def memory_items(self) -> int:
        return len(self.counts)


class CountMinSketch:
    """Count-min sketch + candidate set, vectorized over batches.

    The device hot path for row updates is the Pallas ``sketch_update``
    kernel; this host class mirrors it bit-exactly (same fmix32-row hashing)
    and adds the top-k candidate tracking the kernel leaves to the host.
    """

    def __init__(self, depth: int, width: int, candidates: int = 256):
        self.depth, self.width = depth, width
        self.table = np.zeros((depth, width), np.float64)
        self.total = 0.0
        self.k = candidates
        self._cand: dict[int, float] = {}

    def _rows(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.int64)
        cols = np.stack(
            [fmix32((keys ^ (d * 0x9E3779B9)) & 0xFFFFFFFF, xp=np) % self.width
             for d in range(self.depth)]
        )  # [depth, n]
        return cols

    def update(self, key_batch: np.ndarray) -> None:
        keys, counts = np.unique(np.asarray(key_batch, np.int64), return_counts=True)
        self.total += float(counts.sum())
        cols = self._rows(keys)
        for d in range(self.depth):
            np.add.at(self.table[d], cols[d], counts)
        est = self.estimate(keys)
        for k, e in zip(keys.tolist(), est.tolist()):
            self._cand[k] = e
        if len(self._cand) > self.k:
            keep = sorted(self._cand.items(), key=lambda kv: -kv[1])[: self.k]
            self._cand = dict(keep)

    def estimate(self, keys: np.ndarray) -> np.ndarray:
        cols = self._rows(keys)
        ests = np.stack([self.table[d, cols[d]] for d in range(self.depth)])
        return ests.min(axis=0)

    def histogram(self, top_b: int | None = None) -> Histogram:
        if not self._cand:
            return Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
        keys = np.fromiter(self._cand.keys(), np.int64, len(self._cand))
        h = Histogram.from_counts(keys, self.estimate(keys), total=max(self.total, 1e-30))
        return h.top(top_b) if top_b is not None else h

    @property
    def memory_items(self) -> int:
        return self.depth * self.width + len(self._cand)


# ---------------------------------------------------------------------------
# Device-side (inside jit) exact top-k of one micro-batch — the DRW hook.
# ---------------------------------------------------------------------------


def local_topk_histogram(keys: jnp.ndarray, valid: jnp.ndarray, k: int):
    """Exact top-k (key, count) of one padded key batch, inside jit.

    Returns ``(topk_keys i32[k], topk_counts i32[k], total i32)``; unused
    slots carry key ``-1`` and count ``0``.  Sort-based: O(n log n) on device,
    no host round trip — this is the "measure during normal work" DRW hook.
    """
    n = keys.shape[0]
    big = jnp.int64(2**62) if keys.dtype == jnp.int64 else jnp.int32(2**31 - 1)
    masked = jnp.where(valid, keys, big)
    s = jnp.sort(masked)
    # run-length encode: position where a new key starts
    start = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    seg_id = jnp.cumsum(start) - 1  # [n] segment index per element
    counts = jnp.zeros((n,), jnp.int32).at[seg_id].add(
        jnp.where(masked != big, 1, 0).astype(jnp.int32)
    )
    seg_keys = jnp.zeros((n,), s.dtype).at[seg_id].max(jnp.where(start, s, -big))
    k = min(k, n)  # small batches: cannot have more segments than records
    top_counts, idx = jax.lax.top_k(counts, k)
    top_keys = seg_keys[idx]
    top_keys = jnp.where(top_counts > 0, top_keys, -1)
    total = jnp.sum(valid.astype(jnp.int32))
    return top_keys.astype(keys.dtype), top_counts, total
