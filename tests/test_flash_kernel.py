"""Pallas flash-attention kernel vs the jnp flash path and a naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_tpu
from repro.models.attention import flash_attention


def _naive(q, k, v, causal, window):
    # q [G, P, Sq, hd]; k/v [G, Sk, hd]
    g, p, sq, hd = q.shape
    sk = k.shape[1]
    s = jnp.einsum("gpqh,gkh->gpqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * hd**-0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("gpqk,gkh->gpqh", w, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("sq,sk,bq,bk", [(256, 256, 128, 128), (512, 512, 256, 256)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
@pytest.mark.parametrize("g,p,hd", [(2, 2, 64), (1, 4, 128)])
def test_kernel_matches_naive(sq, sk, bq, bk, causal, window, g, p, hd):
    rng = np.random.default_rng(sq + g + hd + int(causal))
    q = jnp.asarray(rng.standard_normal((g, p, sq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((g, sk, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((g, sk, hd)), jnp.float32)
    got = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=True)
    want = _naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_jnp_flash(dtype):
    """Kernel == the model's jnp flash path (the thing it replaces on TPU)."""
    rng = np.random.default_rng(0)
    b, sq, g, qps, hd = 1, 256, 2, 2, 64
    q = jnp.asarray(rng.standard_normal((b, sq, g, qps, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, sq, g, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, sq, g, hd)), dtype)
    want = flash_attention(q, k, v, causal=True, q_chunk=128, kv_chunk=128)
    qk = q[0].transpose(1, 2, 0, 3)  # [g, qps, sq, hd]
    got = flash_attention_tpu(qk, k[0].transpose(1, 0, 2), v[0].transpose(1, 0, 2),
                              causal=True, bq=128, bk=128, interpret=True)
    got = got.transpose(2, 0, 1, 3)[None]  # back to [b, sq, g, qps, hd]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
        atol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
    )
