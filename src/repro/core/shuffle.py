"""Device-side keyed shuffle: the DDPS stage boundary on a JAX mesh.

One shuffle step, executed under ``shard_map`` over the ``data`` axis:

1. every worker evaluates the partitioner on its local keys
   (Pallas ``partition_apply`` on TPU, jnp twin elsewhere — bit-identical),
2. records are bucketed into a capacity-padded ``[W, cap]`` send buffer
   (slots from ``dispatch_count``; overflow is counted, never silently lost),
3. ``jax.lax.all_to_all`` exchanges the buffers,
4. the DRW hook emits the local top-k histogram + global per-partition loads
   (a ``psum`` — reusing normal DDPS communication, as the paper requires).

Partitions may outnumber workers (over-partitioning, paper Fig. 5);
``worker = partition % W``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map

from repro.core.hashing import KEY_SENTINEL
from repro.core.histogram import local_topk_histogram
from repro.core.partitioner import PartitionerTables, lookup_device
from repro.kernels import ref as kref

__all__ = ["ShuffleResult", "make_shuffle_step", "make_migrate_step"]


class ShuffleResult(NamedTuple):
    keys: jax.Array       # int32[W, W*cap]   received keys per worker (sentinel padded)
    values: jax.Array     # f32[W, W*cap, D]  received payloads
    valid: jax.Array      # bool[W, W*cap]
    part: jax.Array       # int32[W, W*cap]   destination partition of each record
    loads: jax.Array      # int32[N]          global per-partition record counts
    hist_keys: jax.Array  # int32[W, K]       DRW local top-k keys
    hist_counts: jax.Array  # int32[W, K]
    overflow: jax.Array   # int32[]           records dropped for capacity globally


def _bucketize(keys, vals, valid, dest_part, num_workers, capacity):
    """[n] records -> [W, cap] send buffers; returns buffers + overflow."""
    w = dest_part % num_workers
    slot, _ = kref.dispatch_count_ref(w, valid, num_parts=num_workers)
    ok = valid & (slot >= 0) & (slot < capacity)
    overflow = jnp.sum(valid & (slot >= capacity))
    # out-of-range rows are dropped by scatter mode='drop'
    s = jnp.where(ok, slot, capacity)
    buf_keys = jnp.full((num_workers, capacity), KEY_SENTINEL, jnp.int32)
    buf_keys = buf_keys.at[w, s].set(keys, mode="drop")
    buf_part = jnp.zeros((num_workers, capacity), jnp.int32).at[w, s].set(dest_part, mode="drop")
    buf_vals = jnp.zeros((num_workers, capacity) + vals.shape[1:], vals.dtype)
    buf_vals = buf_vals.at[w, s].set(vals, mode="drop")
    buf_valid = jnp.zeros((num_workers, capacity), bool).at[w, s].set(ok, mode="drop")
    return buf_keys, buf_vals, buf_valid, buf_part, overflow


def make_shuffle_step(
    mesh: Mesh,
    *,
    num_partitions: int,
    capacity: int,
    hist_k: int = 64,
    num_hosts: int,
    seed: int = 0,
    axis: str = "data",
):
    """Build the jitted shuffle step for a fixed mesh/capacity."""
    num_workers = mesh.shape[axis]

    def _local(tables, keys, vals, valid):
        # keys [n] local records of this worker
        tables = PartitionerTables(*tables)
        dest = lookup_device(tables, keys, num_hosts, seed)
        dest = jnp.where(valid, dest, 0)
        bk, bv, bva, bp, overflow = _bucketize(keys, vals, valid, dest, num_workers, capacity)
        # exchange: row j of the buffer goes to worker j
        rk = jax.lax.all_to_all(bk, axis, 0, 0, tiled=True)
        rv = jax.lax.all_to_all(bv, axis, 0, 0, tiled=True)
        rva = jax.lax.all_to_all(bva, axis, 0, 0, tiled=True)
        rp = jax.lax.all_to_all(bp, axis, 0, 0, tiled=True)
        # DRW: sample local keys during normal work (no extra pass)
        hk, hc, _ = local_topk_histogram(keys, valid, hist_k)
        # global per-partition loads (normal DDPS comms: one psum)
        my_loads = jnp.zeros(num_partitions, jnp.int32).at[dest].add(valid.astype(jnp.int32))
        loads = jax.lax.psum(my_loads, axis)
        overflow = jax.lax.psum(overflow, axis)
        return (
            rk.reshape(-1)[None],
            rv.reshape(num_workers * capacity, -1)[None],
            rva.reshape(-1)[None],
            rp.reshape(-1)[None],
            loads,
            hk[None],
            hc[None],
            overflow,
        )

    mapped = shard_map(
        _local,
        mesh=mesh,
        in_specs=(
            (P(), P(), P()),  # partitioner tables replicated
            P(axis),  # keys sharded over workers
            P(axis),
            P(axis),
        ),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(axis), P(axis), P()),
        check_vma=False,
    )

    @jax.jit
    def step(tables: PartitionerTables, keys, vals, valid) -> ShuffleResult:
        rk, rv, rva, rp, loads, hk, hc, ov = mapped(tuple(tables), keys, vals, valid)
        return ShuffleResult(rk, rv, rva, rp, loads, hk, hc, ov)

    return step


def make_migrate_step(
    mesh: Mesh,
    *,
    state_capacity: int,
    num_hosts: int,
    seed: int = 0,
    axis: str = "data",
):
    """Jitted operator-state migration for a partitioner swap.

    Each worker re-evaluates old vs. new partitioner on its stored keys and
    ships rows whose worker changed through an all-to-all sized to the full
    state table (correctness-first; §Perf shrinks this with the histogram
    bound).  Returns the new state table + relative-migration metric.
    """
    num_workers = mesh.shape[axis]

    def _local(new_tables, state_keys, state_vals):
        # state tables arrive stacked [1, S] / [1, S, D] per shard
        state_keys, state_vals = state_keys[0], state_vals[0]
        new_tables = PartitionerTables(*new_tables)
        me = jax.lax.axis_index(axis)
        valid = state_keys != KEY_SENTINEL
        dest = lookup_device(new_tables, state_keys, num_hosts, seed) % num_workers
        dest = jnp.where(valid, dest, me)  # padding stays put
        moving = valid & (dest != me)
        moved_w = jnp.sum(moving)
        total_w = jax.lax.psum(jnp.sum(valid), axis)

        bk, bv, bva, _, overflow = _bucketize(
            jnp.where(moving, state_keys, KEY_SENTINEL),
            state_vals,
            moving,
            jnp.where(moving, dest, me),
            num_workers,
            state_capacity,
        )
        rk = jax.lax.all_to_all(bk, axis, 0, 0, tiled=True)
        rv = jax.lax.all_to_all(bv, axis, 0, 0, tiled=True)
        rva = jax.lax.all_to_all(bva, axis, 0, 0, tiled=True)

        kept_keys = jnp.where(moving, KEY_SENTINEL, state_keys)
        kept_valid = valid & ~moving
        moved_total = jax.lax.psum(moved_w, axis)
        overflow = jax.lax.psum(overflow, axis)
        return (
            kept_keys[None],
            state_vals[None],
            kept_valid[None],
            rk.reshape(-1)[None],
            rv.reshape(num_workers * state_capacity, -1)[None],
            rva.reshape(-1)[None],
            moved_total,
            total_w,
            overflow,
        )

    mapped = shard_map(
        _local,
        mesh=mesh,
        in_specs=((P(), P(), P()), P(axis), P(axis)),
        out_specs=(P(axis),) * 6 + (P(), P(), P()),
        check_vma=False,
    )

    @jax.jit
    def migrate(new_tables, state_keys, state_vals):
        return mapped(tuple(new_tables), state_keys, state_vals)

    return migrate
