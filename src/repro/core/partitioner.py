"""Partitioning functions: UHP and the Key Isolator Partitioner (KIP).

A partitioner is represented by three small device-friendly tables so the
per-record lookup is fully vectorized (and has a Pallas kernel twin in
``repro.kernels.partition_apply``):

* ``heavy_keys``  int32[B]  sorted ascending, padded with ``KEY_SENTINEL``
* ``heavy_parts`` int32[B]  explicit partition of each heavy key
* ``host_to_part`` int32[H] weighted-hash routing: key -> host -> partition
* ``heavy_repl``  int32[B]  replica count per heavy key (1 = no split; pad
  rows carry 0 so both route twins clamp them to a no-op choice)

A heavy key with ``heavy_repl[b] = d > 1`` is *split*: records route to one
of the d consecutive partitions ``(heavy_parts[b] + choice) % N`` where
``choice`` is a per-record hash — the Partial-Key-Grouping move for keys
too hot for any single worker.  State merges back at ``heavy_parts[b]``
(the home) through the ordinary migration path, which routes by
:meth:`Partitioner.lookup_np` and therefore ignores replicas.

``kip_update`` implements Algorithm 1 (KIPUPDATE) from the paper: heavy keys
try (1) their previous partition, (2) their plain-hash location, (3) the
least-loaded partition; hosts are then greedily re-binned so no partition
exceeds ``MAXLOAD = max(1/N, Hist[1].freq) + eps``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import DEFAULT_NUM_HOSTS, KEY_SENTINEL, hash_to_host
from repro.core.histogram import Histogram

__all__ = [
    "PartitionerTables",
    "Partitioner",
    "uniform_partitioner",
    "kip_update",
    "resize_partitioner",
    "heavy_capacity_for",
    "split_replica_rows",
]


class PartitionerTables(NamedTuple):
    """The jit-traversable device representation of a partitioner."""

    heavy_keys: jax.Array  # int32[B] sorted, padded with KEY_SENTINEL
    heavy_parts: jax.Array  # int32[B]
    host_to_part: jax.Array  # int32[H]
    heavy_repl: jax.Array  # int32[B] replicas per heavy key (pad rows: 0)


@dataclasses.dataclass(frozen=True)
class Partitioner:
    """Host-side partitioner object (numpy tables + metadata)."""

    num_partitions: int
    heavy_keys: np.ndarray  # int32[B] sorted ascending (sentinel padded)
    heavy_parts: np.ndarray  # int32[B]
    host_to_part: np.ndarray  # int32[H]
    seed: int = 0
    heavy_repl: np.ndarray | None = None  # int32[B] replicas (None = all 1)

    @property
    def num_hosts(self) -> int:
        return len(self.host_to_part)

    @property
    def num_heavy(self) -> int:
        return int((self.heavy_keys != KEY_SENTINEL).sum())

    def tables(self) -> PartitionerTables:
        live = self.heavy_keys != KEY_SENTINEL
        if self.heavy_repl is None:
            repl = live.astype(np.int32)
        else:
            # live rows clamp to >= 1; pad rows stay 0 so a sentinel match in
            # the kernel's eq-matmul sums to 0 -> choice 0 on both twins
            repl = np.where(live, np.maximum(self.heavy_repl, 1), 0).astype(np.int32)
        return PartitionerTables(
            jnp.asarray(self.heavy_keys),
            jnp.asarray(self.heavy_parts),
            jnp.asarray(self.host_to_part),
            jnp.asarray(repl),
        )

    # -- lookups ----------------------------------------------------------
    def lookup_np(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized host-side partition lookup (planning / benchmarks)."""
        keys = np.asarray(keys, np.int32)
        hosts = hash_to_host(keys, self.num_hosts, self.seed, xp=np)
        part = self.host_to_part[hosts]
        if self.num_heavy:
            idx = np.searchsorted(self.heavy_keys, keys)
            idx = np.minimum(idx, len(self.heavy_keys) - 1)
            hit = self.heavy_keys[idx] == keys
            part = np.where(hit, self.heavy_parts[idx], part)
        return part.astype(np.int32)

    def heavy_map(self) -> dict[int, int]:
        m = self.heavy_keys != KEY_SENTINEL
        return dict(zip(self.heavy_keys[m].tolist(), self.heavy_parts[m].tolist()))

    # -- hot-key splitting ------------------------------------------------
    def split_map(self) -> dict[int, int]:
        """``{key: replicas}`` for every key currently split (repl > 1)."""
        if self.heavy_repl is None:
            return {}
        m = (self.heavy_keys != KEY_SENTINEL) & (self.heavy_repl > 1)
        return dict(zip(self.heavy_keys[m].tolist(), self.heavy_repl[m].tolist()))

    def with_splits(self, split_map: dict[int, int]) -> "Partitioner":
        """Re-stamp the replica column from ``split_map``; every other key
        drops back to one replica.

        A split key missing from the heavy table is inserted at its current
        :meth:`lookup_np` home (the table only grows — to the next
        kernel-tile multiple — when the insertions overflow the current
        width, so jit signatures stay stable across re-stamps)."""
        live = self.heavy_keys != KEY_SENTINEL
        keys = self.heavy_keys[live].astype(np.int32)
        parts = self.heavy_parts[live].astype(np.int32)
        repl = np.ones(len(keys), np.int32)
        have = {int(k): i for i, k in enumerate(keys.tolist())}
        extra_keys, extra_parts, extra_repl = [], [], []
        for k, d in split_map.items():
            d = int(min(max(int(d), 1), self.num_partitions))
            if int(k) in have:
                repl[have[int(k)]] = d
            else:
                home = int(self.lookup_np(np.asarray([k], np.int32))[0])
                extra_keys.append(int(k))
                extra_parts.append(home)
                extra_repl.append(d)
        if extra_keys:
            keys = np.concatenate([keys, np.asarray(extra_keys, np.int32)])
            parts = np.concatenate([parts, np.asarray(extra_parts, np.int32)])
            repl = np.concatenate([repl, np.asarray(extra_repl, np.int32)])
        cap = self.heavy_keys.shape[0]
        if len(keys) > cap:
            cap = heavy_capacity_for(0.0, self.num_partitions, floor=len(keys))
        hk, hp, hr = _pad_heavy(keys, parts, cap, repl)
        return dataclasses.replace(
            self, heavy_keys=hk, heavy_parts=hp, heavy_repl=hr
        )


def lookup_device(tables: PartitionerTables, keys: jax.Array, num_hosts: int, seed: int = 0) -> jax.Array:
    """jnp twin of :meth:`Partitioner.lookup_np` (used inside jit)."""
    keys = keys.astype(jnp.int32)
    hosts = hash_to_host(keys, num_hosts, seed, xp=jnp)
    part = tables.host_to_part[hosts]
    if tables.heavy_keys.shape[0] == 0:  # no explicit routing table
        return part.astype(jnp.int32)
    idx = jnp.clip(jnp.searchsorted(tables.heavy_keys, keys), 0, tables.heavy_keys.shape[0] - 1)
    hit = tables.heavy_keys[idx] == keys
    return jnp.where(hit, tables.heavy_parts[idx], part).astype(jnp.int32)


def _pad_heavy(keys: np.ndarray, parts: np.ndarray, capacity: int, repl=None):
    """Sort by key and sentinel-pad heavy tables to fixed width.

    ``repl`` (replicas per key) defaults to all-ones; its pad value is 0 —
    the route twins clamp 0 to 1, and the kernel relies on pad rows summing
    to 0 in its eq-matmul so sentinel records take replica choice 0."""
    if repl is None:
        repl = np.ones(len(keys), np.int32)
    order = np.argsort(keys, kind="stable")
    keys, parts, repl = keys[order], parts[order], np.asarray(repl)[order]
    pad = capacity - len(keys)
    assert pad >= 0, f"heavy table overflow: {len(keys)} > {capacity}"
    keys = np.concatenate([keys, np.full(pad, KEY_SENTINEL, np.int32)])
    parts = np.concatenate([parts, np.zeros(pad, np.int32)])
    repl = np.concatenate([repl, np.zeros(pad, np.int32)])
    return keys.astype(np.int32), parts.astype(np.int32), repl.astype(np.int32)


def uniform_partitioner(
    num_partitions: int,
    num_hosts: int = DEFAULT_NUM_HOSTS,
    seed: int = 0,
    heavy_capacity: int = 0,
) -> Partitioner:
    """UHP — the Spark/Flink default: hash(key) mod N (host table = h mod N)."""
    host_to_part = (np.arange(num_hosts, dtype=np.int64) % num_partitions).astype(np.int32)
    hk, hp, _ = _pad_heavy(np.zeros(0, np.int32), np.zeros(0, np.int32), heavy_capacity)
    return Partitioner(num_partitions, hk, hp, host_to_part, seed)


def kip_update(
    prev: Partitioner,
    hist: Histogram,
    num_partitions: int | None = None,
    eps: float = 0.01,
    heavy_capacity: int | None = None,
    tight: bool = False,
) -> Partitioner:
    """Algorithm 1 — KIPUPDATE(KI, HASH, H, Hist, N, eps).

    ``prev`` is KI (the partitioner of the previous stage); its
    ``host_to_part`` also serves as the HASH host mapping when probing a
    heavy key's fallback location.  ``num_partitions`` may differ from
    ``prev.num_partitions`` (elastic resize uses this).
    """
    n = int(num_partitions or prev.num_partitions)
    h = prev.num_hosts
    seed = prev.seed
    b = len(hist)
    cap = heavy_capacity if heavy_capacity is not None else max(b, prev.heavy_keys.shape[0])

    keys = hist.keys.astype(np.int64)
    freqs = hist.freqs.astype(np.float64)

    # line 1: allowed load level
    top_freq = float(freqs[0]) if b else 0.0
    maxload = max(1.0 / n, top_freq) + eps
    # line 2: average load carried by one host (tail mass spread over hosts)
    hostload = max(0.0, 1.0 - float(freqs.sum())) / h

    load = np.zeros(n, np.float64)
    prev_heavy = prev.heavy_map()
    # previous assignment of each heavy key under KI
    prev_part = prev.lookup_np(keys.astype(np.int32))
    # the pure-hash (future non-heavy) location under the previous host map
    hash_host = hash_to_host(keys.astype(np.int32), h, seed, xp=np)
    hash_part = prev.host_to_part[hash_host]
    if n < prev.num_partitions:  # elastic shrink: fold removed partitions
        prev_part = prev_part % n
        hash_part = hash_part % n
        prev_heavy = {k: p % n for k, p in prev_heavy.items()}

    heavy_parts = np.zeros(b, np.int32)
    for i in range(b):  # Hist is ordered by decreasing frequency
        f = freqs[i]
        p = int(prev_heavy.get(int(keys[i]), prev_part[i]))  # line 4: KI(k)
        if load[p] < maxload - f:  # line 5
            heavy_parts[i] = p
            load[p] += f
            continue
        p = int(hash_part[i])  # line 7: HASH(k)
        if load[p] < maxload - f:  # line 8
            heavy_parts[i] = p
            load[p] += f
            continue
        p = int(np.argmin(load))  # line 10: lowest-load partition
        heavy_parts[i] = p
        load[p] += f

    # lines 11-13: add host loads under the previous host->partition mapping
    host_to_part = prev.host_to_part.copy()
    if n < prev.num_partitions:
        host_to_part = host_to_part % n
    hosts_per_part = np.bincount(host_to_part, minlength=n).astype(np.float64)
    load = load + hostload * hosts_per_part

    # lines 14-15: greedy bin packing — move hosts off overloaded partitions
    if tight and hostload > 0:
        # Beyond-paper 'tight' mode: Algorithm 1 only rebins hosts when a
        # partition exceeds MAXLOAD, which for f1 >> 1/N leaves the tail
        # spread untouched.  Waterfill instead: equalize total loads at the
        # level L solving sum_p max(0, L - heavy_load[p]) = tail_mass, and
        # move the minimal number of hosts toward per-partition quotas.
        heavy_only = load - hostload * hosts_per_part
        tail_mass = hostload * h
        lo, hi = heavy_only.min(), heavy_only.max() + tail_mass + hostload
        for _ in range(60):  # bisection on the waterline
            mid = 0.5 * (lo + hi)
            if np.maximum(0.0, mid - heavy_only).sum() > tail_mass:
                hi = mid
            else:
                lo = mid
        quota = np.maximum(0.0, hi - heavy_only) / hostload
        quota = np.floor(quota).astype(int)
        # distribute leftover host slots to lowest-load partitions
        leftover = h - quota.sum()
        order = np.argsort(heavy_only + quota * hostload)
        for i in range(leftover):
            quota[order[i % n]] += 1
        hosts_of = [list(np.where(host_to_part == p)[0]) for p in range(n)]
        surplus = []
        for p in range(n):
            while len(hosts_of[p]) > quota[p]:
                surplus.append(hosts_of[p].pop())
        for p in range(n):
            while len(hosts_of[p]) < quota[p] and surplus:
                hh = surplus.pop()
                host_to_part[hh] = p
                hosts_of[p].append(hh)
        hosts_per_part = np.bincount(host_to_part, minlength=n).astype(np.float64)
        load = heavy_only + hostload * hosts_per_part
    elif hostload > 0:
        order_src = np.argsort(-load, kind="stable")
        # hosts grouped per partition for O(H) moves
        hosts_of = [np.where(host_to_part == p)[0].tolist() for p in range(n)]
        dst_iter = 0
        dsts = np.argsort(load, kind="stable").tolist()
        for p in order_src.tolist():
            while load[p] > maxload and hosts_of[p]:
                # first partition with room for one more host
                while dst_iter < len(dsts) and (
                    dsts[dst_iter] == p or load[dsts[dst_iter]] >= maxload - hostload
                ):
                    dst_iter += 1
                if dst_iter >= len(dsts):
                    break  # nowhere below the bound: leave residual imbalance
                q = dsts[dst_iter]
                hh = hosts_of[p].pop()
                host_to_part[hh] = q
                hosts_of[q].append(hh)
                load[p] -= hostload
                load[q] += hostload

    # a fresh plan carries no replica column: the DR master re-stamps its
    # split set via ``with_splits`` after installing the new partitioner
    hk, hp, _ = _pad_heavy(keys.astype(np.int32), heavy_parts, max(cap, b))
    return Partitioner(n, hk, hp, host_to_part.astype(np.int32), seed)


def resize_partitioner(
    prev: Partitioner,
    num_partitions: int,
    hist: Histogram | None = None,
    *,
    eps: float = 0.01,
    heavy_capacity: int | None = None,
    tight: bool = True,
) -> Partitioner:
    """Elastic grow/shrink: re-plan ``prev`` for a different partition count.

    This is :func:`kip_update` with ``num_partitions != prev.num_partitions``
    — shrink folds removed partitions (``p % n``), grow relies on the host
    re-binning (waterfill under ``tight``) to populate the new partitions —
    plus the degenerate case of a resize *before any histogram exists*: an
    empty histogram still re-bins hosts, so every partition receives hash
    traffic immediately after the resize.
    """
    n = int(num_partitions)
    if n < 1:
        raise ValueError(f"num_partitions must be >= 1, got {n}")
    if hist is None:
        hist = Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
    return kip_update(
        prev, hist, num_partitions=n, eps=eps, heavy_capacity=heavy_capacity, tight=tight
    )


def heavy_capacity_for(lam: float, num_partitions: int, *, floor: int = 0) -> int:
    """Heavy-table width for tracking ``lam`` keys per partition, rounded up
    to the route kernels' tile width (``KEY_LANES``).

    The one shared rounding rule for every sizing site (streaming driver,
    serve scheduler, elastic replan, repartition policy) — previously each
    hand-inlined ``ceil(.../128)*128``.  ``floor`` lower-bounds the result
    before rounding (e.g. the current table width, to keep jit signatures
    stable)."""
    from repro.kernels.partition_apply import KEY_LANES

    want = max(int(np.ceil(lam * num_partitions)), int(floor), 1)
    return int(-(-want // KEY_LANES) * KEY_LANES)


def split_replica_rows(
    partitioner: Partitioner,
    keys: np.ndarray,
    num_workers: int = 1,
    valid: np.ndarray | None = None,
) -> np.ndarray:
    """Host twin of the fused kernels' replica pick: rows each partition
    receives from *split* keys this batch (``int64[num_partitions]``).

    Bit-identical to the device route: under ``shard_map`` worker ``i``
    owns the contiguous chunk ``keys[i*local:(i+1)*local]`` and a record's
    replica hash uses its *local* index in that chunk."""
    from repro.core.hashing import fmix32

    n = partitioner.num_partitions
    out = np.zeros(n, np.int64)
    smap = partitioner.split_map()
    if not smap:
        return out
    keys = np.asarray(keys, np.int32).reshape(num_workers, -1)
    local_n = keys.shape[1]
    idx = np.broadcast_to(np.arange(local_n, dtype=np.int64), keys.shape)
    golden = np.uint32(0x9E3779B9)
    seedmix = np.uint32((partitioner.seed * 0x9E3779B9) & 0xFFFFFFFF)
    mixed = fmix32(keys.astype(np.uint32) ^ seedmix, xp=np)
    h = fmix32(idx.astype(np.uint32) * golden ^ mixed, xp=np)
    choice31 = (h & np.uint32(0x7FFFFFFF)).astype(np.int32)
    if valid is not None:
        valid = np.asarray(valid, bool).reshape(keys.shape)
    for k, d in smap.items():
        m = keys == np.int32(k)
        if valid is not None:
            m &= valid
        if not m.any():
            continue
        home = int(partitioner.lookup_np(np.asarray([k], np.int32))[0])
        parts = (home + choice31[m] % np.int32(d)) % n
        np.add.at(out, parts, 1)
    return out


# ---------------------------------------------------------------------------
# Balance metrics (paper's evaluation currency)
# ---------------------------------------------------------------------------


def load_imbalance(partitioner: Partitioner, key_stream: np.ndarray) -> float:
    """max(load) / mean(load) over the actual key stream (paper Fig. 2/3)."""
    parts = partitioner.lookup_np(np.asarray(key_stream, np.int32))
    loads = np.bincount(parts, minlength=partitioner.num_partitions)
    return float(loads.max() / max(loads.mean(), 1e-12))


def expected_loads(partitioner: Partitioner, hist: Histogram) -> np.ndarray:
    """Planner's view of per-partition load given a histogram."""
    n = partitioner.num_partitions
    load = np.zeros(n)
    parts = partitioner.lookup_np(hist.keys.astype(np.int32))
    np.add.at(load, parts, hist.freqs)
    hosts_per_part = np.bincount(partitioner.host_to_part, minlength=n)
    load += hist.tail_mass / partitioner.num_hosts * hosts_per_part
    return load
