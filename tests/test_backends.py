"""Exchange backends: dense / ragged / local equivalence and cost rules.

The backend contract is bit-identity: on the same routed input every
transport must produce identical unpacked rows and identical overflow
accounting — they differ only in *how much* they ship (``shipped_rows``)
and what a candidate plan costs (``cost``).  Property tests cover the
bucketize layer on random inputs; the collective layer is exercised through
``shard_map`` here (single device) and on 8 real shards in
``tests/test_distributed.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.migration import exchange_lane_cost, plan_migration
from repro.core.partitioner import uniform_partitioner
from repro.exchange import (
    DenseBackend,
    ExchangeSpec,
    LocalBackend,
    Payload,
    RaggedBackend,
    backend_name,
    make_exchange,
    resolve_backend,
    take_from,
)

ALL_BACKENDS = ("dense", "ragged", "local")


def _random_input(rng, n, num_lanes, payload_dim=3):
    lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = rng.random(n) < 0.8
    vals = rng.normal(size=(n, payload_dim)).astype(np.float32)
    ints = rng.integers(0, 1000, n).astype(np.int32)
    return jnp.asarray(lane), jnp.asarray(valid), jnp.asarray(vals), jnp.asarray(ints)


# ---------------------------------------------------------------------------
# bucketize: transport-independent, bit-identical across backends
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(
    n=st.integers(min_value=1, max_value=512),
    num_lanes=st.integers(min_value=1, max_value=16),
    capacity=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_bucketize_bit_identical_across_backends(n, num_lanes, capacity, seed):
    rng = np.random.default_rng(seed)
    lane, valid, vals, ints = _random_input(rng, n, num_lanes)
    spec = ExchangeSpec(num_lanes=num_lanes, capacity=capacity)
    results = {
        be: make_exchange(spec, be).bucketize(
            lane, valid, [Payload(vals, 0), Payload(ints, -1)]
        )
        for be in ALL_BACKENDS
    }
    ref = results["dense"]
    for be, res in results.items():
        np.testing.assert_array_equal(np.asarray(res.valid), np.asarray(ref.valid), err_msg=be)
        for got, want in zip(res.payloads, ref.payloads):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want), err_msg=be)
        assert int(res.send.overflow) == int(ref.send.overflow), be
        np.testing.assert_array_equal(
            np.asarray(res.send.lane_overflow), np.asarray(ref.send.lane_overflow),
            err_msg=be,
        )
        # unpacked view identical too (the consumer-facing surface)
        va, flat = res.unpack()
        wa, wflat = ref.unpack()
        np.testing.assert_array_equal(np.asarray(va), np.asarray(wa), err_msg=be)
        for g, w in zip(flat, wflat):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=be)


@settings(max_examples=10)
@given(
    n=st.integers(min_value=8, max_value=512),
    num_lanes=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_lane_overflow_sums_to_scalar_in_range(n, num_lanes, seed):
    """With every lane in range, the per-lane vector is a refinement of the
    scalar: it sums to exactly the total overflow."""
    rng = np.random.default_rng(seed)
    lane, valid, vals, _ = _random_input(rng, n, num_lanes)
    res = make_exchange(ExchangeSpec(num_lanes=num_lanes, capacity=4)).bucketize(
        lane, valid, [Payload(vals, 0)]
    )
    assert int(np.asarray(res.send.lane_overflow).sum()) == int(res.send.overflow)


def test_lane_overflow_localizes_the_hot_lane():
    lane = jnp.asarray([1, 1, 1, 1, 1, 0], jnp.int32)  # lane 1 gets 5 > cap 2
    valid = jnp.ones(6, bool)
    res = make_exchange(ExchangeSpec(num_lanes=3, capacity=2)).bucketize(
        lane, valid, [Payload(jnp.arange(6, dtype=jnp.float32), 0)]
    )
    np.testing.assert_array_equal(np.asarray(res.send.lane_overflow), [0, 3, 0])
    assert int(res.send.overflow) == 3


def test_out_of_range_lane_counts_in_scalar_only():
    """A lane outside [0, L) has no lane to charge: the scalar sees it, the
    vector (by design) does not — the documented asymmetry."""
    lane = jnp.asarray([0, 7, -3], jnp.int32)
    valid = jnp.ones(3, bool)
    res = make_exchange(ExchangeSpec(num_lanes=2, capacity=4)).bucketize(
        lane, valid, [Payload(jnp.zeros(3), 0)]
    )
    assert int(res.send.overflow) == 2
    assert int(np.asarray(res.send.lane_overflow).sum()) == 0


# ---------------------------------------------------------------------------
# the collective: dense vs ragged through a real shard_map
# ---------------------------------------------------------------------------


def _run_collective(backend, lane, valid, vals, num_lanes, capacity):
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), backend
    )

    def body(lane, valid, vals):
        res = ex(lane, valid, [Payload(vals, -1.0)])
        va, (v,) = res.unpack()
        return va[None], v[None], res.shipped_rows, res.send.overflow

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(), P()),
        check_vma=False,
    )
    va, v, shipped, overflow = mapped(lane, valid, vals)
    return np.asarray(va), np.asarray(v), int(shipped), int(overflow)


@pytest.mark.parametrize("skew", ["uniform", "hot"])
def test_collective_backends_bit_identical(skew):
    rng = np.random.default_rng(3)
    n, num_lanes, capacity = 256, 4, 96
    if skew == "hot":
        lane = np.zeros(n, np.int32)  # everything to lane 0: max raggedness
    else:
        lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    vals = rng.normal(size=(n,)).astype(np.float32)
    out = {
        be: _run_collective(be, jnp.asarray(lane), jnp.asarray(valid),
                            jnp.asarray(vals), num_lanes, capacity)
        for be in ("dense", "ragged")
    }
    va_d, v_d, shipped_d, ov_d = out["dense"]
    va_r, v_r, shipped_r, ov_r = out["ragged"]
    np.testing.assert_array_equal(va_d, va_r)
    np.testing.assert_array_equal(v_d, v_r)
    assert ov_d == ov_r
    # dense ships the whole pad; ragged ships measured occupancy + counts
    assert shipped_d == num_lanes * capacity
    assert shipped_r <= shipped_d
    assert shipped_r == int(valid.sum() if skew == "uniform" else min(valid.sum(), capacity)) + num_lanes


def test_bucketize_with_precomputed_counts_bit_identical():
    """The fused-route fast path (slot + counts handed in) must produce the
    same buffers, overflow scalar, and per-lane overflow vector as the
    derive-everything path — the lane_overflow scatter it skips is exactly
    recomputable from the counts."""
    rng = np.random.default_rng(11)
    for n, num_lanes, capacity in [(64, 4, 4), (256, 8, 16), (33, 3, 1)]:
        lane, valid, vals, ints = _random_input(rng, n, num_lanes)
        spec = ExchangeSpec(num_lanes=num_lanes, capacity=capacity)
        from repro.kernels import ref as kref

        slot, counts = kref.dispatch_count_ref(lane, valid, num_parts=num_lanes)
        ex = make_exchange(spec)
        derived = ex.bucketize(lane, valid, [Payload(vals, 0), Payload(ints, -1)])
        fused = ex.bucketize(lane, valid, [Payload(vals, 0), Payload(ints, -1)],
                             slot=slot, counts=counts)
        np.testing.assert_array_equal(np.asarray(fused.valid), np.asarray(derived.valid))
        for g, w in zip(fused.payloads, derived.payloads):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        assert int(fused.send.overflow) == int(derived.send.overflow)
        np.testing.assert_array_equal(
            np.asarray(fused.send.lane_overflow), np.asarray(derived.send.lane_overflow)
        )
        # both paths also surface the buffer occupancy for the count phase
        np.testing.assert_array_equal(
            np.asarray(fused.lane_counts), np.asarray(derived.lane_counts)
        )
        np.testing.assert_array_equal(
            np.asarray(derived.lane_counts),
            np.minimum(np.asarray(counts), capacity),
        )


def test_ragged_count_phase_priced_in_row_bytes():
    """The phase-1 count vector is 4 bytes per lane, not a full row per
    lane: a wide-payload exchange pays a fraction of a row for it, a
    narrow-payload exchange up to one row per lane — never more.  (The old
    rule charged num_lanes rows regardless, biasing the policy gate against
    ragged on small records.)"""
    rng = np.random.default_rng(5)
    n, num_lanes, capacity = 128, 8, 32
    lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = np.ones(n, bool)

    def shipped_with(payload):
        mesh = jax.make_mesh((1,), ("data",))
        ex = make_exchange(
            ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), "ragged"
        )

        def body(lane, valid, data):
            res = ex(lane, valid, [Payload(data, 0)])
            return res.shipped_rows

        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(P("data"), P("data"), P("data")),
            out_specs=P(),
            check_vma=False,
        )
        return int(mapped(jnp.asarray(lane), jnp.asarray(valid), payload))

    rows = int(valid.sum())
    narrow = shipped_with(jnp.zeros(n, jnp.int32))            # 4 B/row
    wide = shipped_with(jnp.zeros((n, 16), jnp.float32))      # 64 B/row
    assert narrow == rows + num_lanes            # 4 B count == one 4 B row
    assert wide == rows + int(np.ceil(4 * num_lanes / 64))  # a fraction, ceil'd
    assert wide < narrow


def test_compat_ragged_all_to_all_shim_contract():
    """The shim itself, called directly: exactly ``send_sizes`` rows per
    lane move, and the unreceived region of the output keeps its initial
    values — the same contract whichever branch the installed jax takes
    (native collective on >= 0.5, masked dense fallback on 0.4.x)."""
    from repro.compat import ragged_all_to_all

    mesh = jax.make_mesh((1,), ("data",))
    operand = jnp.arange(8, dtype=jnp.float32)  # one lane of capacity 8

    def body(op):
        out = jnp.full_like(op, -1.0)
        sizes = jnp.asarray([3], jnp.int32)
        off = jnp.zeros(1, jnp.int32)
        return ragged_all_to_all(op, out, off, sizes, off, sizes,
                                 axis_name="data")

    mapped = shard_map(body, mesh=mesh, in_specs=P("data"),
                       out_specs=P("data"), check_vma=False)
    got = np.asarray(mapped(operand))
    np.testing.assert_array_equal(got, [0, 1, 2, -1, -1, -1, -1, -1])


# ---------------------------------------------------------------------------
# backhaul: the response hop rides the request lanes back
# ---------------------------------------------------------------------------


def _run_roundtrip(backend, lane, valid, vals, num_lanes, capacity):
    """Request-response through one exchange: ship, transform received rows
    in place, backhaul over the same lanes, gather per-record responses."""
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), backend
    )

    def body(lane, valid, vals):
        res = ex(lane, valid, [Payload(vals, -1.0)])
        resp = jnp.where(res.valid, res.payloads[0] * 2.0 + 1.0, 0.0)
        ret, back_shipped, back_occupied = ex.backhaul(resp, forward=res)
        out = take_from(ret, res.send)
        return out, res.shipped_rows + back_shipped, back_occupied

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P(), P()),
        check_vma=False,
    )
    out, shipped, occupied = mapped(lane, valid, vals)
    return np.asarray(out), int(shipped), int(occupied)


@pytest.mark.parametrize("skew", ["uniform", "hot"])
def test_backhaul_bit_identical_across_backends(skew):
    """The combine direction (MoE's return trip) is bit-identical dense vs
    ragged, and the ragged round trip ships the measured rows both ways —
    no second count phase."""
    rng = np.random.default_rng(9)
    n, num_lanes, capacity = 192, 4, 64
    lane = (np.zeros(n, np.int32) if skew == "hot"
            else rng.integers(0, num_lanes, n).astype(np.int32))
    valid = rng.random(n) < 0.85
    vals = rng.normal(size=(n,)).astype(np.float32)
    out = {
        be: _run_roundtrip(be, jnp.asarray(lane), jnp.asarray(valid),
                           jnp.asarray(vals), num_lanes, capacity)
        for be in ("dense", "ragged")
    }
    np.testing.assert_array_equal(out["dense"][0], out["ragged"][0])
    # per-record responses: f(x) = 2x + 1 for accepted records, 0 otherwise;
    # hot skew overflows lane 0 beyond capacity and dropped records return 0
    dropped = np.zeros(n, bool)
    if skew == "hot":
        order = np.cumsum(valid) - 1  # rank within lane 0
        dropped = valid & (order >= capacity)
    expect = np.where(valid & ~dropped, 2.0 * vals + 1.0, 0.0)
    np.testing.assert_allclose(out["dense"][0], expect)
    # traffic: dense pays the pad twice, ragged pays counted rows + counts
    rows = int(np.sum(valid & ~dropped))
    assert out["dense"][1] == 2 * num_lanes * capacity
    assert out["ragged"][1] == (rows + num_lanes) + rows  # fwd + backhaul
    assert out["ragged"][1] < out["dense"][1]
    # occupancy is backend-independent: with forward counts threaded the
    # dense backhaul reports the same counted rows the ragged one ships
    assert out["dense"][2] == out["ragged"][2] == rows


def test_ragged_backhaul_without_forward_counts_ships_dense():
    """A backhaul with no forward result to reuse falls back to the padded
    return trip — correctness never depends on the counts being threaded."""
    rng = np.random.default_rng(13)
    n, num_lanes, capacity = 64, 4, 32
    lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = np.ones(n, bool)
    vals = rng.normal(size=(n,)).astype(np.float32)
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), "ragged"
    )

    def body(lane, valid, vals):
        res = ex(lane, valid, [Payload(vals, 0.0)])
        ret, shipped, _occ = ex.backhaul(res.payloads[0])  # no forward threaded
        return take_from(ret, res.send), shipped

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P()),
        check_vma=False,
    )
    out, shipped = mapped(jnp.asarray(lane), jnp.asarray(valid), jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(out), vals)
    assert int(shipped) == num_lanes * capacity  # the dense pad


def test_moe_combine_backhaul_bit_identical_across_backends():
    """End to end through the MoE layer: dispatch + combine under the dense
    and ragged transports produce the same output bit for bit, match the
    dense oracle, and the ragged layer reports less measured traffic."""
    import dataclasses as dc

    from repro.configs.base import MoESpec
    from repro.models.modules import Policy
    from repro.moe.layer import init_moe, moe_apply, moe_ref

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = MoESpec(num_experts=4, top_k=2, d_ff_expert=16, shared_expert=False,
                   capacity_factor=4.0)
    d = 8
    p = init_moe(jax.random.PRNGKey(0), d, spec, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    inv = jnp.arange(4, dtype=jnp.int32)
    want = moe_ref(p, x, spec, "swiglu", Policy(), inv)
    got = {}
    for be in ("dense", "ragged"):
        pol = Policy(mesh=mesh, dp_axes=("data",), tp_axis="model",
                     exchange_backend=be)
        got[be] = jax.jit(
            lambda pp, xx, pol=pol: moe_apply(pp, xx, spec, "swiglu", pol, inv)
        )(p, x)
    np.testing.assert_array_equal(np.asarray(got["dense"].y),
                                  np.asarray(got["ragged"].y))
    np.testing.assert_allclose(np.asarray(got["dense"].y), np.asarray(want.y),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(got["dense"].counts),
                                  np.asarray(got["ragged"].counts))
    assert float(got["dense"].overflow) == float(got["ragged"].overflow) == 0.0
    # both directions accounted: the ragged layer moves fewer rows than the
    # padded round trip the dense layer reports
    assert int(got["ragged"].shipped_rows) < int(got["dense"].shipped_rows)


def test_local_backend_refuses_mesh_axis():
    spec = ExchangeSpec(num_lanes=2, capacity=4, axis="data")
    ex = make_exchange(spec, "local")
    res = ex.bucketize(jnp.zeros(3, jnp.int32), jnp.ones(3, bool),
                       [Payload(jnp.zeros(3), 0)])
    with pytest.raises(AssertionError):
        ex.all_to_all(res)


# ---------------------------------------------------------------------------
# split-phase pipeline: start() + finish() == the fused call, bit for bit
# ---------------------------------------------------------------------------


def _run_split_vs_fused(backend, lane, valid, vals, num_lanes, capacity):
    """Run the fused call and the start/finish pipeline side by side under
    one shard_map, returning both unpacked results + control accounting."""
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), backend
    )

    def body(lane, valid, vals):
        fused = ex(lane, valid, [Payload(vals, -1.0)])
        pending = ex.start(lane, valid, [Payload(vals, -1.0)])
        # every control output is already final on the in-flight value
        started = pending.buffers
        split = ex.finish(pending)
        return (
            fused.valid[None], fused.payloads[0][None], fused.shipped_rows,
            fused.send.overflow, fused.send.lane_overflow,
            split.valid[None], split.payloads[0][None], split.shipped_rows,
            started.shipped_rows, started.send.overflow,
            started.send.lane_overflow,
        )

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(), P(), P(),
                   P("data"), P("data"), P(), P(), P(), P()),
        check_vma=False,
    )
    return mapped(lane, valid, vals)


@pytest.mark.parametrize("backend", ["dense", "ragged"])
@pytest.mark.parametrize("skew", ["uniform", "hot"])
def test_split_phase_bit_identical_to_fused(backend, skew):
    """start() + finish() must reproduce the fused exchange exactly —
    including the overflow scalar, the per-lane overflow vector, and the
    measured shipped_rows, all of which are final at start (the hot skew
    overflows lane 0, exercising the accounting under drops)."""
    rng = np.random.default_rng(21)
    n, num_lanes, capacity = 192, 4, 32  # hot skew overflows lane 0
    lane = (np.zeros(n, np.int32) if skew == "hot"
            else rng.integers(0, num_lanes, n).astype(np.int32))
    valid = rng.random(n) < 0.85
    vals = rng.normal(size=(n,)).astype(np.float32)
    (f_va, f_v, f_ship, f_ov, f_lov,
     s_va, s_v, s_ship, p_ship, p_ov, p_lov) = _run_split_vs_fused(
        backend, jnp.asarray(lane), jnp.asarray(valid), jnp.asarray(vals),
        num_lanes, capacity)
    np.testing.assert_array_equal(np.asarray(f_va), np.asarray(s_va))
    np.testing.assert_array_equal(np.asarray(f_v), np.asarray(s_v))
    assert int(f_ship) == int(s_ship) == int(p_ship)
    assert int(f_ov) == int(p_ov)
    np.testing.assert_array_equal(np.asarray(f_lov), np.asarray(p_lov))
    if skew == "hot":
        assert int(f_ov) > 0  # the accounting was actually exercised


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=256),
    num_lanes=st.integers(min_value=1, max_value=8),
    capacity=st.sampled_from([1, 4, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_split_phase_local_bit_identical(n, num_lanes, capacity, seed):
    """The axis-free local backend: start/finish is the identity pipeline
    around bucketize — random shapes, including overflowing ones."""
    rng = np.random.default_rng(seed)
    lane, valid, vals, _ = _random_input(rng, n, num_lanes)
    ex = make_exchange(ExchangeSpec(num_lanes=num_lanes, capacity=capacity))
    fused = ex(lane, valid, [Payload(vals, 0.0)])
    split = ex.finish(ex.start(lane, valid, [Payload(vals, 0.0)]))
    np.testing.assert_array_equal(np.asarray(fused.valid), np.asarray(split.valid))
    for g, w in zip(split.payloads, fused.payloads):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert int(fused.send.overflow) == int(split.send.overflow)
    np.testing.assert_array_equal(
        np.asarray(fused.send.lane_overflow), np.asarray(split.send.lane_overflow)
    )


# ---------------------------------------------------------------------------
# backend resolution + cost rules
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_and_names():
    assert isinstance(resolve_backend(None, ExchangeSpec(2, 4)), LocalBackend)
    assert isinstance(resolve_backend(None, ExchangeSpec(2, 4, axis="data")), DenseBackend)
    assert isinstance(resolve_backend(None), DenseBackend)
    assert isinstance(resolve_backend("ragged"), RaggedBackend)
    be = RaggedBackend()
    assert resolve_backend(be) is be
    with pytest.raises(ValueError):
        resolve_backend("nccl")
    assert backend_name(None) == "auto"
    assert backend_name("dense") == "dense"
    assert backend_name(be) == "ragged"


@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_cost_rules_ordering(seed):
    """Ragged cost (mean real rows) never exceeds dense cost (padded peak);
    a local exchange is free."""
    rng = np.random.default_rng(seed)
    transfer = rng.random((6, 6)) * rng.integers(1, 100)
    np.fill_diagonal(transfer, 0.0)
    dense = DenseBackend().cost(None, transfer)
    ragged = RaggedBackend().cost(None, transfer)
    assert 0.0 <= ragged <= dense
    assert LocalBackend().cost(None, transfer) == 0.0
    assert DenseBackend().cost(None, np.zeros((0, 0))) == 0.0


def test_exchange_lane_cost_backend_rules():
    """The policy-facing cost helper: default == dense rule; ragged strictly
    cheaper on a skewed plan; local free."""
    old = uniform_partitioner(4, seed=0)
    new = uniform_partitioner(4, seed=3)
    plan = plan_migration(old, new, np.arange(512, dtype=np.int64))
    base = exchange_lane_cost(plan, num_workers=2)
    dense = exchange_lane_cost(plan, num_workers=2, backend=DenseBackend())
    ragged = exchange_lane_cost(plan, num_workers=2, backend=RaggedBackend())
    local = exchange_lane_cost(plan, num_workers=2, backend=LocalBackend())
    assert base == dense > 0
    assert 0 < ragged < dense  # a 2-worker fold has an empty diagonal to skip
    assert local == 0.0


def test_make_exchange_default_matches_pre_backend_behavior():
    """axis=None auto-selects the local transport; the collective verbs are
    identity, exactly the old ``Exchange`` with no axis."""
    ex = make_exchange(ExchangeSpec(num_lanes=3, capacity=4))
    assert isinstance(ex.backend, LocalBackend)
    res = ex(jnp.asarray([0, 1, 2], jnp.int32), jnp.ones(3, bool),
             [Payload(jnp.arange(3, dtype=jnp.float32), 0)])
    assert int(res.shipped_rows) == 0  # nothing crossed a mesh axis
    buf = np.asarray(res.payloads[0])
    assert buf[0, 0] == 0 and buf[1, 0] == 1 and buf[2, 0] == 2
