"""Model facade: family dispatch + abstract input specs for the dry-run.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of a given (arch x shape) cell — weak-type-correct,
shardable, no device allocation.  Modality frontends are stubs: whisper
receives precomputed frame embeddings, qwen2-vl precomputed patch
embeddings, per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.modules import Policy


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.encdec


def init_params(cfg: ArchConfig, key, pol: Policy) -> dict:
    if is_encdec(cfg):
        return encdec.init_params(cfg, key, pol)
    return transformer.init_params(cfg, key, pol)


def loss_fn(params, batch, cfg: ArchConfig, pol: Policy, inv_place=None):
    if is_encdec(cfg):
        return encdec.loss_fn(params, batch, cfg, pol, inv_place)
    return transformer.loss_fn(params, batch, cfg, pol, inv_place)


def prefill(params, batch, cfg: ArchConfig, pol: Policy, max_len: int, inv_place=None):
    if is_encdec(cfg):
        return encdec.prefill(params, batch, cfg, pol, max_len, inv_place)
    return transformer.prefill(params, batch, cfg, pol, max_len, inv_place)


def decode_step(params, cache, tokens, cfg: ArchConfig, pol: Policy, inv_place=None):
    if is_encdec(cfg):
        return encdec.decode_step(params, cache, tokens, cfg, pol, inv_place)
    return transformer.decode_step(params, cache, tokens, cfg, pol, inv_place)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, pol: Policy):
    if is_encdec(cfg):
        raise ValueError("enc-dec caches are produced by prefill()")
    return transformer.init_cache(cfg, batch, max_len, pol)


# ---------------------------------------------------------------------------
# abstract inputs for lowering
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig, pol: Policy) -> dict:
    """ShapeDtypeStruct batch for train/prefill of one cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if is_encdec(cfg):
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_len, cfg.d_model), pol.compute_dtype)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), pol.compute_dtype)
    if shape.kind != "train":
        batch.pop("labels")
        batch.pop("mask")
    return batch


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig, pol: Policy):
    """(cache_specs, token_specs) for one serve_step cell."""
    b, s = shape.global_batch, shape.seq_len
    if is_encdec(cfg):
        from repro.models.attention import head_layout, init_kv_cache

        lay = head_layout(cfg.num_heads, cfg.num_kv_heads, pol.tp)
        kv = jax.eval_shape(
            lambda: jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
                init_kv_cache(b, s, lay, cfg.head_dim, dtype=pol.compute_dtype),
            )
        )
        xkv = jax.eval_shape(
            lambda: jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
                {
                    "k": jnp.zeros((b, cfg.enc_len, lay.hkv_p, cfg.head_dim), pol.compute_dtype),
                    "v": jnp.zeros((b, cfg.enc_len, lay.hkv_p, cfg.head_dim), pol.compute_dtype),
                    "pos": jnp.zeros((b, cfg.enc_len), jnp.int32),
                    "offset": jnp.zeros((), jnp.int32),
                },
            )
        )
        cache = {"pos": jax.ShapeDtypeStruct((b,), jnp.int32), "blocks": kv,
                 "xcaches": xkv}
    else:
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s, pol))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return cache, tokens


def abstract_params(cfg: ArchConfig, pol: Policy):
    """Parameter ShapeDtypeStructs without allocating (jax.eval_shape)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k, pol), key)
