"""Exchange backends: the *how* of a routed exchange.

An :class:`ExchangeBackend` implements the verbs of the plane —
``bucketize`` / ``a2a_start`` / ``a2a_finish`` / ``backhaul`` / ``cost`` —
against one :class:`~repro.exchange.spec.ExchangeSpec`.  The collective is
split-phase: ``a2a_start`` runs everything the *control plane* needs (for
the ragged transport that is the phase-1 count all-to-all plus the traffic
accounting; for dense it is only the statically-known accounting) and
``a2a_finish`` moves the payload rows.  ``all_to_all`` is defined as the
composition ``a2a_finish(a2a_start(buffers))`` — bit-identical to the
fused call by construction — so drivers may hold the started exchange
in flight and overlap the row ship with unrelated work.  Three transports
ship:

* :class:`DenseBackend` — the capacity-padded all-to-all: every lane is
  padded to ``spec.capacity`` and the collective moves the whole
  ``[L, capacity]`` buffer.  Simple, one device round, and the worst case
  under skew: every consumer ships ``L * capacity`` rows even when the
  observed key distribution leaves most lanes nearly empty.
* :class:`RaggedBackend` — the count-first two-phase exchange: phase 1
  all-to-alls the per-lane *counts* (one int per lane), phase 2 ships
  row-compacted lanes sized by the measured occupancy, so traffic tracks
  real rows instead of padding (Partial Key Grouping's bounded per-worker
  load, AutoFlow's load-adapted routing).  The row phase rides
  :func:`repro.compat.ragged_all_to_all`: on jax >= 0.5 that is the native
  ragged collective — only the measured rows cross the interconnect, so the
  wall-clock follows the row counts — and on jax 0.4.x the bit-identical
  fallback that ships the dense pad with the receive buffer masked to the
  exchanged counts (``shipped_rows`` reports the ragged traffic either
  way).  The same counts make the *return* trip ragged for free: a
  ``backhaul`` handed the forward hop's counts ships compacted response
  rows with no second count phase.
* :class:`LocalBackend` — the ``axis=None`` single-host fast path: pure
  bucketize, no collective, zero shipped rows.
* :class:`HierarchicalBackend` — the topology-aware two-tier exchange:
  a dense all-to-all *within* each host followed by a stride-grouped hop
  *across* hosts (:func:`_two_hop_a2a`), composing to the flat collective's
  permutation bit for bit while every link round stays inside one tier.
  Traffic is accounted per distance class — the intra tier dense-priced,
  the inter tier by measured row counts.

``cost(spec, plan_rows)`` is each backend's sizing rule on a candidate
migration plan — what the control plane's
:func:`repro.core.migration.exchange_lane_cost` evaluates so
``RepartitionPolicy`` prices a repartition by what the *active* transport
would move: the dense rule pads every lane to the peak, the ragged rule
averages real rows over the lanes, a local exchange is free.

All device code is pure jnp and runs inside ``jit`` / ``shard_map``.
Backends are stateless; one instance may serve any number of specs.
"""
from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import ragged_all_to_all
from repro.exchange.spec import (
    DISTANCE_CLASSES,
    ExchangeResult,
    ExchangeSpec,
    Payload,
    SendInfo,
)
from repro.kernels import ref as kref

__all__ = [
    "ExchangeBackend",
    "DenseBackend",
    "RaggedBackend",
    "LocalBackend",
    "HierarchicalBackend",
    "resolve_backend",
    "backend_name",
]


@runtime_checkable
class ExchangeBackend(Protocol):
    """The verbs every exchange transport implements.

    ``all_to_all`` must equal ``a2a_finish(a2a_start(buffers))`` bit for
    bit; after ``a2a_start`` every control-plane output (``shipped_rows``,
    ``lane_counts``, ``recv_counts``) is final — ``a2a_finish`` only moves
    payload rows and stamps the received-validity mask.
    """

    name: str

    def bucketize(
        self,
        spec: ExchangeSpec,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
        buffers: tuple | None = None,
    ) -> ExchangeResult: ...

    def a2a_start(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult: ...

    def a2a_finish(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult: ...

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult: ...

    def backhaul(
        self,
        spec: ExchangeSpec,
        buffers: jax.Array,
        *,
        send_counts: jax.Array | None = None,
        recv_counts: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, jax.Array]: ...

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float: ...


def _bucketize(
    spec: ExchangeSpec,
    lane: jax.Array,
    valid: jax.Array,
    payloads: Sequence[Payload],
    slot: jax.Array | None = None,
    counts: jax.Array | None = None,
    buffers: tuple | None = None,
) -> ExchangeResult:
    """Scatter records into ``[L, capacity]`` buffers; count overflow.

    Shared by every backend — the send-side layout is transport-independent
    (a backend that wanted a different layout would override).  ``slot`` and
    ``counts`` may be precomputed (the fused route kernel emits both);
    otherwise they are derived with ``dispatch_count``.  With per-lane
    ``counts`` in hand the capacity drops per lane are just the excess over
    capacity — no second O(n) scatter pass.

    ``buffers`` is the reuse seam for the double-buffered pipeline: a
    ``(valid_buf, payload_bufs)`` set from a previous exchange (shapes and
    dtypes must match this call's buffers).  When provided, the scatter
    resets the passed-in set to its fill values and writes into it instead
    of materializing fresh ``zeros``/``full`` buffers — under a jit that
    donates the set, XLA performs both in place, so the steady-state loop
    never reallocates its ``[L, cap]`` send buffers.  The produced values
    are bit-identical to the fresh-allocation path by construction.
    """
    lane = jnp.where(valid, lane, 0).astype(jnp.int32)
    if slot is None:
        slot, counts = kref.dispatch_count_ref(lane, valid, num_parts=spec.num_lanes)
    # a valid record is lost either to a full lane or to a lane outside
    # [0, num_lanes) — both are counted, never silently dropped
    in_range = (lane >= 0) & (lane < spec.num_lanes)
    ok = valid & in_range & (slot >= 0) & (slot < spec.capacity)
    overflow = jnp.sum(valid & (~in_range | (slot >= spec.capacity))).astype(jnp.int32)
    if counts is not None:
        # per-lane capacity drops fall out of the dispatch counts (slots are
        # assigned 0..count-1, so the excess over capacity is exactly what
        # dropped); the buffer occupancy is the clipped count — both O(L)
        lane_overflow = jnp.maximum(counts - spec.capacity, 0).astype(jnp.int32)
        lane_counts = jnp.minimum(counts, spec.capacity).astype(jnp.int32)
    else:
        # per-lane view of the capacity drops: which lane filled up
        # (out-of-range records have no lane to charge — they count in the
        # scalar only)
        lane_overflow = (
            jnp.zeros(spec.num_lanes, jnp.int32)
            .at[lane]
            .add((valid & in_range & (slot >= spec.capacity)).astype(jnp.int32),
                 mode="drop")
        )
        lane_counts = None
    # rows without a slot land at column `capacity` and are dropped by
    # the out-of-range scatter (mode='drop') — counted above, never lost
    # silently.
    s = jnp.where(ok, slot, spec.capacity)
    shape = (spec.num_lanes, spec.capacity)
    if buffers is None:
        buf_valid = jnp.zeros(shape, bool).at[lane, s].set(ok, mode="drop")
        bufs = tuple(
            jnp.full(shape + p.data.shape[1:], p.fill, p.data.dtype)
            .at[lane, s].set(p.data, mode="drop")
            for p in payloads
        )
    else:
        prev_valid, prev_bufs = buffers
        assert prev_valid.shape == shape and len(prev_bufs) == len(payloads), (
            prev_valid.shape, shape, len(prev_bufs), len(payloads))
        # reset-then-scatter on the recycled set: same values as the fresh
        # path, but expressed as in-place updates so a donated set is
        # rewritten rather than reallocated
        buf_valid = prev_valid.at[:].set(False).at[lane, s].set(ok, mode="drop")
        bufs = tuple(
            b.at[:].set(jnp.asarray(p.fill, b.dtype))
            .at[lane, s].set(p.data, mode="drop")
            for b, p in zip(prev_bufs, payloads)
        )
    return ExchangeResult(
        buf_valid, bufs, SendInfo(lane, slot, ok, overflow, lane_overflow),
        shipped_rows=jnp.zeros((), jnp.int32),
        lane_counts=lane_counts,
        fills=tuple(p.fill for p in payloads),
    )


def _a2a(x: jax.Array, axis: str) -> jax.Array:
    """Tiled all-to-all over ``axis``: row j of the leading dim -> shard j."""
    return jax.lax.all_to_all(x, axis, 0, 0, tiled=True)


def _static_axis_size(axis: str) -> int:
    """Mesh axis size as a static int (psum of a unit constant), or -1 when
    it cannot be resolved statically — callers treat -1 as "not usable"."""
    try:
        return int(jax.lax.psum(1, axis))
    except Exception:  # noqa: BLE001 - traced/unbound axis: no static size
        return -1


def _row_bytes(payloads: tuple) -> int:
    """Bytes one exchanged row carries across all payload buffers."""
    return max(1, sum(
        int(np.prod(b.shape[2:], dtype=np.int64)) * b.dtype.itemsize
        for b in payloads
    ))


def _count_phase_rows(spec: ExchangeSpec, payloads: tuple) -> int:
    """The count phase's traffic in row-equivalents: one int32 per lane,
    normalized by the payload row width so narrow-payload exchanges are not
    over-charged (a 4-byte count next to a 256-byte row is ~free; next to a
    4-byte row it is a full row)."""
    return int(np.ceil(4 * spec.num_lanes / _row_bytes(payloads)))


def _me(spec: ExchangeSpec) -> jax.Array:
    """This worker's lane index, clipped into the lane range so degenerate
    test meshes (axis size 1 simulating L lanes) stay in bounds."""
    return jnp.minimum(jax.lax.axis_index(spec.axis), spec.num_lanes - 1)


def _by_class_dense(spec: ExchangeSpec) -> jax.Array:
    """Dense-priced per-class traffic: every lane ships its full capacity,
    so the split is just (lanes of each class from this worker) x capacity.
    The class tables are cached numpy constants on the topology — computed
    once at spec construction, closed over by the jitted step."""
    counts = jnp.asarray(spec.topology.class_lane_counts)[_me(spec)]
    return (counts * spec.capacity).astype(jnp.int32)


def _by_class_counts(spec: ExchangeSpec, counts: jax.Array) -> jax.Array:
    """Count-priced per-class traffic: the measured per-lane occupancy
    reduced over each distance class (one matmul against the cached
    per-worker one-hot class masks)."""
    onehot = jnp.asarray(spec.topology.class_onehot)[_me(spec)]  # [C, L]
    return (onehot @ counts.astype(jnp.int32)).astype(jnp.int32)


def _count_phase_class(spec: ExchangeSpec) -> int:
    """Which distance class the ragged count phase is charged to: the count
    all-to-all crosses the full axis, so its traffic rides the slowest tier
    the topology has (statically known)."""
    if spec.topology.num_hosts > 1:
        return 2
    return 1 if spec.num_lanes > 1 else 0


def _ragged_ship(
    spec: ExchangeSpec,
    arrays_with_fill: Sequence[tuple[jax.Array, int | float]],
    send_sizes: jax.Array,
    recv_sizes: jax.Array,
) -> tuple[jax.Array, ...]:
    """Move lane-major ``[L, capacity, ...]`` buffers as compacted rows
    through :func:`repro.compat.ragged_all_to_all` (native collective on
    jax >= 0.5, masked dense fallback on 0.4.x).

    ``bucketize`` packs each lane's rows contiguously from slot 0, so the
    flattened buffer is already in the shim's lane-major regular layout:
    lane ``i``'s rows start at ``i * capacity``, and this worker's rows land
    at ``axis_index * capacity`` on every receiver.  Valid only when lanes
    coincide with the shards on ``spec.axis`` — the shim's offset vectors
    are indexed by axis peer.  ``fill`` initializes the unreceived region of
    each output, matching what the dense collective would have shipped
    there (the sender's pad) bit for bit.
    """
    l, cap = spec.num_lanes, spec.capacity
    me = jax.lax.axis_index(spec.axis)
    in_off = jnp.arange(l, dtype=jnp.int32) * cap
    out_off = jnp.full((l,), me * cap, jnp.int32)
    out = []
    for b, fill in arrays_with_fill:
        flat = b.reshape((l * cap,) + b.shape[2:])
        out.append(ragged_all_to_all(
            flat, jnp.full_like(flat, fill), in_off, send_sizes, out_off,
            recv_sizes, axis_name=spec.axis,
        ).reshape(b.shape))
    return tuple(out)


class DenseBackend:
    """The capacity-padded transport (the pre-backend exchange, verbatim)."""

    name = "dense"

    def bucketize(self, spec, lane, valid, payloads, slot=None, counts=None,
                  buffers=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot, counts=counts,
                          buffers=buffers)

    def a2a_start(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """No count phase to run — only stamp the (statically known) traffic
        so control-plane reads never have to wait for the row ship."""
        if spec.axis is None:
            return buffers
        by = _by_class_dense(spec) if spec.topology is not None else None
        return buffers._replace(
            shipped_rows=jnp.asarray(spec.rows, jnp.int32),
            shipped_rows_by_class=by,
        )

    def a2a_finish(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Exchange lane-major buffers across ``spec.axis`` (row j -> shard j)."""
        if spec.axis is None:
            return buffers
        by = _by_class_dense(spec) if spec.topology is not None else None
        return buffers._replace(
            valid=_a2a(buffers.valid, spec.axis),
            payloads=tuple(_a2a(b, spec.axis) for b in buffers.payloads),
            shipped_rows=jnp.asarray(spec.rows, jnp.int32),  # the whole pad
            shipped_rows_by_class=by,
        )

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        return self.a2a_finish(self.a2a_start(spec, buffers))

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array, *,
                 send_counts: jax.Array | None = None,
                 recv_counts: jax.Array | None = None,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Reverse collective for already-laned response buffers; ships the
        whole pad back, whatever the counts say — but when counts *are*
        supplied, the measured occupancy is reported alongside so telemetry
        sees honest utilization even on the padded path."""
        if spec.axis is None:
            z = jnp.zeros((), jnp.int32)
            return buffers, z, z
        occupied = (jnp.sum(send_counts).astype(jnp.int32) if send_counts is not None
                    else jnp.asarray(spec.rows, jnp.int32))
        return _a2a(buffers, spec.axis), jnp.asarray(spec.rows, jnp.int32), occupied

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        """Every lane provisions (and ships) the peak planned lane mass."""
        plan_rows = np.asarray(plan_rows, np.float64)
        if plan_rows.size == 0:
            return 0.0
        return float(plan_rows.max()) * slack


class RaggedBackend:
    """Count-first two-phase transport: ship counts, then compacted rows."""

    name = "ragged"

    def bucketize(self, spec, lane, valid, payloads, slot=None, counts=None,
                  buffers=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot, counts=counts,
                          buffers=buffers)

    def _ship(self, spec: ExchangeSpec, buffers: ExchangeResult,
              recv_counts: jax.Array) -> ExchangeResult:
        """Phase 2: move the rows through :func:`repro.compat
        .ragged_all_to_all` — native on jax >= 0.5 (only the counted rows
        cross the interconnect), the masked dense collective on 0.4.x.
        ``bucketize`` packs each lane's rows contiguously from slot 0, so
        the flattened ``[L * capacity]`` buffer is already in the shim's
        lane-major regular layout: send offsets are ``lane * capacity``,
        and this worker's rows land at ``axis_index * capacity`` on every
        receiver.  The received occupancy needs no collective at all — it
        is exactly the phase-1 counts.
        """
        l, cap = spec.num_lanes, spec.capacity
        valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < recv_counts[:, None]
        fills = buffers.fills or (0,) * len(buffers.payloads)
        # the shim's offset vectors are indexed by axis peer, so it applies
        # only when lanes coincide with shards (the production layout);
        # degenerate meshes (tests, axis size 1) ride the bare dense ship —
        # whose pad rows already carry the payload fill, matching the shim's
        # output bit for bit, and `valid` above masks them off either way
        if _static_axis_size(spec.axis) == l:
            payloads = _ragged_ship(
                spec, tuple(zip(buffers.payloads, fills)),
                buffers.lane_counts, recv_counts,
            )
        else:
            payloads = tuple(_a2a(b, spec.axis) for b in buffers.payloads)
        return buffers._replace(
            valid=valid, payloads=payloads, recv_counts=recv_counts,
        )

    def a2a_start(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Phase 1: exchange per-lane occupancy (one int32 per lane) so every
        receiver knows how many rows each peer actually sends.  Everything
        the control plane reads — ``shipped_rows``, ``lane_counts``,
        ``recv_counts`` — is final after this phase; the row ship in
        :meth:`a2a_finish` can stay in flight."""
        if spec.axis is None:
            return buffers
        counts = buffers.lane_counts
        if counts is None:  # bucketize had no dispatch counts to reuse
            counts = jnp.sum(buffers.valid, axis=1, dtype=jnp.int32)
        recv_counts = _a2a(counts, spec.axis)
        # measured traffic: the rows this worker's lanes actually hold plus
        # the count phase itself, priced in bytes-normalized row units
        phase_rows = _count_phase_rows(spec, buffers.payloads)
        shipped = (jnp.sum(counts) + phase_rows).astype(jnp.int32)
        by = None
        if spec.topology is not None:
            # the count phase crosses the whole axis: charge it to the
            # slowest tier present so by-class totals still sum to shipped
            by = _by_class_counts(spec, counts).at[_count_phase_class(spec)].add(
                jnp.asarray(phase_rows, jnp.int32))
        return buffers._replace(
            shipped_rows=shipped, lane_counts=counts, recv_counts=recv_counts,
            shipped_rows_by_class=by,
        )

    def a2a_finish(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Phase 2: ship the compacted rows sized by the started counts."""
        if spec.axis is None:
            return buffers
        return self._ship(spec, buffers, buffers.recv_counts)

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        return self.a2a_finish(self.a2a_start(spec, buffers))

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array, *,
                 send_counts: jax.Array | None = None,
                 recv_counts: jax.Array | None = None,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Response rows ride the request lanes back.  With the forward
        hop's counts the return trip is ragged with *no second count phase*:
        this worker's response occupancy per lane is exactly what it
        received (``send_counts`` = the forward ``recv_counts``) and what
        comes back is exactly what it sent (``recv_counts`` = the forward
        ``lane_counts``).  Without counts (a caller that never ran the
        forward hop through this backend) the return trip ships dense.
        Rows beyond a lane's count are unspecified (zeros on the native
        path, the peer's pad on the fallback) — ``take_from`` never reads
        them.
        """
        if spec.axis is None:
            z = jnp.zeros((), jnp.int32)
            return buffers, z, z
        if send_counts is None or recv_counts is None:
            pad = jnp.asarray(spec.rows, jnp.int32)
            return _a2a(buffers, spec.axis), pad, pad
        shipped = jnp.sum(send_counts).astype(jnp.int32)
        if _static_axis_size(spec.axis) == spec.num_lanes:
            rows, = _ragged_ship(spec, ((buffers, 0),), send_counts, recv_counts)
        else:
            rows = _a2a(buffers, spec.axis)
        return rows, shipped, shipped

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        """A ragged transport moves real rows: the per-lane *average* planned
        mass (empty lanes are free), never more than the dense peak."""
        plan_rows = np.asarray(plan_rows, np.float64)
        if plan_rows.size == 0:
            return 0.0
        return float(plan_rows.sum()) / plan_rows.size * slack


def _two_hop_a2a(x: jax.Array, axis: str, num_hosts: int,
                 lanes_per_host: int) -> jax.Array:
    """The hierarchical all-to-all: intra-host hop, then inter-host hop.

    ``x`` is a lane-major ``[L, capacity, ...]`` send buffer over
    ``L = num_hosts * lanes_per_host`` lanes, lane ``j`` on host
    ``j // lanes_per_host`` at rank ``j % lanes_per_host``.  Hop 1 exchanges
    within each host over the *rank*-destination dimension, so afterwards
    worker ``(h, r)`` holds every row its host sends to rank ``r`` of any
    host; hop 2 exchanges across hosts (stride-``lanes_per_host`` groups)
    over the *host*-destination dimension, completing the permutation.  The
    composition lands row ``B_src[dst]`` at worker ``dst`` position ``src``
    — exactly the flat tiled all-to-all's layout, bit for bit — while each
    link round stays inside one tier of the mesh.  Applying it twice is the
    identity (each tiled hop is an involution and the transposes cancel),
    so the backhaul rides the same function.
    """
    h, g = num_hosts, lanes_per_host
    tail = x.shape[1:]
    intra = [[hh * g + r for r in range(g)] for hh in range(h)]
    inter = [[hh * g + r for hh in range(h)] for r in range(g)]
    perm = (1, 0) + tuple(range(2, x.ndim + 1))
    t = x.reshape((h, g) + tail).transpose(perm).reshape((g * h,) + tail)
    t = jax.lax.all_to_all(t, axis, 0, 0, tiled=True, axis_index_groups=intra)
    t = t.reshape((g, h) + tail).transpose(perm).reshape((h * g,) + tail)
    return jax.lax.all_to_all(t, axis, 0, 0, tiled=True, axis_index_groups=inter)


class HierarchicalBackend:
    """Two-tier transport: dense intra-host hop, count-priced inter-host hop.

    Composes the existing collectives as a two-level exchange over the
    spec's :class:`~repro.exchange.spec.ExchangeTopology`: hop 1 is a dense
    all-to-all *within* each host (cheap tier — padding is fine there),
    hop 2 crosses hosts in stride groups (slow tier).  The composed
    permutation is bit-identical to the flat all-to-all (see
    :func:`_two_hop_a2a`), so unpacked rows and overflow accounting match
    the flat backends exactly; only the *measured traffic* differs —
    ``shipped_rows_by_class`` prices the intra tier dense (the hop-1 pad)
    and the inter tier by real row counts, the same semantic-traffic
    convention the ragged fallback uses on jax 0.4.x.

    Without a usable topology (no topology on the spec, lanes not divisible
    by ``lanes_per_host``, a single host, or a mesh whose axis size differs
    from the lane count) the collective falls back to the flat dense
    all-to-all — still bit-identical, just untiered.
    """

    name = "hierarchical"

    def bucketize(self, spec, lane, valid, payloads, slot=None, counts=None,
                  buffers=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot, counts=counts,
                          buffers=buffers)

    def _plan(self, spec: ExchangeSpec) -> tuple[int, int] | None:
        """``(num_hosts, lanes_per_host)`` when the two-hop collective
        applies, else ``None`` — the flat dense collective."""
        topo, l = spec.topology, spec.num_lanes
        if topo is None:
            return None
        g = min(topo.lanes_per_host, l)
        if g <= 1 or g >= l or l % g:
            return None
        if _static_axis_size(spec.axis) != l:
            return None
        return l // g, g

    def _ship(self, spec: ExchangeSpec, x: jax.Array) -> jax.Array:
        plan = self._plan(spec)
        if plan is None:
            return _a2a(x, spec.axis)
        return _two_hop_a2a(x, spec.axis, *plan)

    def a2a_start(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Like dense, no count phase blocks the control plane — the traffic
        accounting is local: the intra tier ships its (statically known)
        pad, the inter tier only the measured per-lane occupancy."""
        if spec.axis is None:
            return buffers
        by = None
        if spec.topology is not None:
            counts = buffers.lane_counts
            if counts is None:
                counts = jnp.sum(buffers.valid, axis=1, dtype=jnp.int32)
            inter = _by_class_counts(spec, counts)[2]
            cap = spec.capacity
            by = jnp.stack([
                jnp.asarray(cap, jnp.int32),
                jnp.asarray((spec.num_lanes - 1) * cap, jnp.int32),
                inter,
            ])
            shipped = jnp.sum(by).astype(jnp.int32)
        else:
            shipped = jnp.asarray(spec.rows, jnp.int32)
        return buffers._replace(shipped_rows=shipped, shipped_rows_by_class=by)

    def a2a_finish(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Move the rows through the two-hop permutation (validity mask
        included — it is what the flat dense collective would have
        exchanged, hop-composed instead)."""
        if spec.axis is None:
            return buffers
        return buffers._replace(
            valid=self._ship(spec, buffers.valid),
            payloads=tuple(self._ship(spec, b) for b in buffers.payloads),
        )

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        return self.a2a_finish(self.a2a_start(spec, buffers))

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array, *,
                 send_counts: jax.Array | None = None,
                 recv_counts: jax.Array | None = None,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Responses ride the two-hop permutation backward — which is the
        same permutation (it is an involution), so the forward function
        ships the return trip.  Accounting mirrors the forward hop: dense
        intra pad plus counted inter rows when counts are known."""
        if spec.axis is None:
            z = jnp.zeros((), jnp.int32)
            return buffers, z, z
        pad = jnp.asarray(spec.rows, jnp.int32)
        if spec.topology is not None and send_counts is not None:
            # hop-1 pad (the whole buffer crosses the fast tier) + the real
            # rows that cross hosts — same convention as the forward hop
            inter = _by_class_counts(spec, send_counts)[2]
            shipped = (pad + inter).astype(jnp.int32)
            occupied = jnp.sum(send_counts).astype(jnp.int32)
        else:
            shipped, occupied = pad, (jnp.sum(send_counts).astype(jnp.int32)
                                      if send_counts is not None else pad)
        return self._ship(spec, buffers), shipped, occupied

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        """Sizing rule: the intra tier still pads every lane to the peak
        (dense rule) — the locality discount comes from
        :func:`repro.core.migration.exchange_lane_cost` weighting the plan
        by distance class before this rule prices it."""
        plan_rows = np.asarray(plan_rows, np.float64)
        if plan_rows.size == 0:
            return 0.0
        return float(plan_rows.max()) * slack


class LocalBackend:
    """``axis=None`` fast path: bucketize only, no collective, nothing ships."""

    name = "local"

    def bucketize(self, spec, lane, valid, payloads, slot=None, counts=None,
                  buffers=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot, counts=counts,
                          buffers=buffers)

    def a2a_start(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        assert spec.axis is None, (
            f"LocalBackend cannot cross mesh axis {spec.axis!r}; "
            "use the dense or ragged backend"
        )
        return buffers

    def a2a_finish(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        assert spec.axis is None, spec.axis
        return buffers

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        return self.a2a_finish(self.a2a_start(spec, buffers))

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array, *,
                 send_counts: jax.Array | None = None,
                 recv_counts: jax.Array | None = None,
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
        assert spec.axis is None, spec.axis
        z = jnp.zeros((), jnp.int32)
        return buffers, z, z

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        return 0.0


_BACKENDS = {
    "dense": DenseBackend,
    "ragged": RaggedBackend,
    "local": LocalBackend,
    "hierarchical": HierarchicalBackend,
}


def resolve_backend(
    backend: str | ExchangeBackend | None, spec: ExchangeSpec | None = None
) -> ExchangeBackend:
    """Turn a backend name (or instance, or ``None``) into an instance.

    ``None`` auto-selects: the local fast path when the spec has no mesh
    axis, otherwise dense — the pre-backend behavior, bit-identical.
    """
    if backend is None:
        return LocalBackend() if spec is not None and spec.axis is None else DenseBackend()
    if isinstance(backend, str):
        try:
            return _BACKENDS[backend]()
        except KeyError:
            raise ValueError(
                f"unknown exchange backend {backend!r}; have {sorted(_BACKENDS)}"
            ) from None
    return backend


def backend_name(backend: str | ExchangeBackend | None) -> str:
    """Stable display/cache name for a backend selection (``None`` = auto)."""
    if backend is None:
        return "auto"
    if isinstance(backend, str):
        return backend
    return getattr(backend, "name", type(backend).__name__)
