"""Fig. 6 — relative streaming-throughput increase from DR vs. Zipf
exponent, measured on the real micro-batch runtime (StreamingJob on the
local mesh; stateful count reducer, matching the paper's Flink setup).
Also measures the elastic-resize cost: rows shipped + wall time for a
grow 4->8 and a shrink 8->4, next to the plain migration rows."""
from __future__ import annotations

import time

import numpy as np

from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf, zipf_keys

EXPONENTS = [1.0, 1.3, 1.6, 2.0]


def _worker_time(job_metrics, per_record_us=1.0, per_batch_overhead_us=2000.0):
    """Straggler-bound completion: batches gated by the most loaded worker."""
    t = 0.0
    for m in job_metrics:
        t += m.worker_imbalance * per_record_us + per_batch_overhead_us * 1e-3
    return t


SMOKE = dict(batches=3, batch_size=4_096)  # CI bench-smoke profile


def run(batches: int = 6, batch_size: int = 16_384):
    rows = []
    state_capacity = 16_384
    for exp in EXPONENTS:
        metrics = {}
        mig_rows = 0
        reparts = 0
        for dr_on in (True, False):
            job = StreamingJob(
                num_partitions=8,
                state_capacity=state_capacity,
                dr_enabled=dr_on,
                dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2),
            )
            ms = job.run(drifting_zipf(batches, batch_size, num_keys=5_000,
                                       exponent=exp, drift_every=100, seed=int(exp * 7)))
            # throughput proxy: records / straggler-bound time
            imb = np.mean([m.imbalance for m in ms[1:]])
            metrics[dr_on] = imb
            if dr_on:
                mig_rows = sum(m.migration_rows for m in ms)
                reparts = sum(m.repartitioned for m in ms)
        gain = metrics[False] / metrics[True] - 1.0
        rows.append((f"fig6/throughput_gain/exp={exp}", gain,
                     "relative increase (paper: biggest at moderate exp)"))
        if reparts:
            # bounded exchange: rows shipped per repartition vs. the
            # full-state all-to-all (W * state_capacity rows per worker)
            full = job.num_workers * state_capacity
            rows.append((f"fig6/migration_rows_fraction/exp={exp}",
                         mig_rows / reparts / full,
                         f"{reparts} repartitions, full-state a2a = 1"))
    rows.extend(_resize_cost(4, 8, batch_size, state_capacity))
    rows.extend(_resize_cost(8, 4, batch_size, state_capacity))
    return rows


def _resize_cost(base_n: int, target_n: int, batch_size: int, state_capacity: int):
    """Elastic-resize cost: exchange rows + wall time for one grow/shrink.

    The resize batch pays the state migration *and* the shuffle-step rebuild
    (jit for the new lane count); a steady-state batch is reported alongside
    so the delta is visible."""
    job = StreamingJob(
        num_partitions=base_n,
        state_capacity=state_capacity,
        dr=DRConfig(imbalance_trigger=1e9),  # isolate the resize: no plain DR
    )
    warm = [zipf_keys(batch_size, num_keys=2_000, exponent=1.3, seed=s) for s in (20, 21)]
    for b in warm:
        steady = job.process_batch(b)
    job.resize(target_n)
    t0 = time.perf_counter()
    m = job.process_batch(zipf_keys(batch_size, num_keys=2_000, exponent=1.3, seed=22))
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert m.resized, m.reason
    tag = f"grow_{base_n}to{target_n}" if target_n > base_n else f"shrink_{base_n}to{target_n}"
    full = job.num_workers * state_capacity
    return [
        (f"fig6/resize_rows/{tag}", m.migration_rows,
         f"exchange buffer rows (plan {m.migration_plan_rows}; full-state a2a {full})"),
        (f"fig6/resize_wall_ms/{tag}", wall_ms,
         f"resize batch incl. step rebuild (steady batch {steady.wall_time_s * 1e3:.1f} ms)"),
    ]
