"""KIP-based expert -> EP-shard placement (the paper's technique in-model).

Mapping onto the paper's objects:

* keys            -> logical expert ids (all "heavy": E is small, tail empty)
* partitions      -> EP shards (the ``model`` mesh axis)
* key histogram   -> per-expert token loads (DRW = router statistics,
                     gathered during normal forward work, zero extra passes)
* state migration -> moving expert weights (+ optimizer moments) between
                     shards = permuting the stacked [E, ...] expert arrays

``update_placement`` runs KIPUPDATE on the expert-load histogram, then
post-processes the shard assignment into exactly ``E/shards`` slots per
shard (KIP knows load bounds, not slot counts), preferring to keep every
expert where it was — Algorithm 1's migration-minimality carried through.

The *whether* of a re-placement routes through the shared control plane:
router statistics feed a :class:`~repro.control.Telemetry` window, the
:class:`~repro.control.policy.PlacementPolicy` (paper §4's trigger over
shard loads, plus the shared cooldown guard) returns a typed action, and
every decision — declined ones included — lands in the controller's
:class:`~repro.control.DecisionLog`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import DecisionLog, PlacementPolicy, Replace, Telemetry
from repro.core.histogram import CounterSketch, Histogram
from repro.core.migration import MigrationPlan, exchange_lane_cost
from repro.core.partitioner import Partitioner, kip_update, uniform_partitioner
from repro.exchange.backends import resolve_backend

__all__ = ["ExpertPlacement", "PlacementController", "apply_placement_to_weights"]


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    place: np.ndarray      # int32[E_phys] physical slot -> logical expert
    inv_place: np.ndarray  # int32[E]      logical expert -> physical slot
    n_shards: int

    @property
    def num_experts(self) -> int:
        return len(self.inv_place)

    def shard_of(self, logical: np.ndarray) -> np.ndarray:
        e_loc = len(self.place) // self.n_shards
        return self.inv_place[logical] // e_loc

    @staticmethod
    def identity(num_experts: int, n_shards: int) -> "ExpertPlacement":
        p = np.arange(num_experts, dtype=np.int32)
        return ExpertPlacement(p.copy(), p.copy(), n_shards)


def _slot_constrained(shard_of: np.ndarray, loads: np.ndarray, n_shards: int) -> np.ndarray:
    """Evict lightest experts from over-full shards into free slots."""
    e = len(shard_of)
    e_loc = e // n_shards
    shard_of = shard_of.copy()
    for s in range(n_shards):
        members = np.where(shard_of == s)[0]
        if len(members) <= e_loc:
            continue
        # keep the heaviest e_loc here; move the rest to shards with room
        order = members[np.argsort(-loads[members])]
        for m in order[e_loc:]:
            room = [q for q in range(n_shards) if (shard_of == q).sum() < e_loc]
            # least-loaded shard with a free slot
            q = min(room, key=lambda q: loads[shard_of == q].sum())
            shard_of[m] = q
    return shard_of


def placement_from_assignment(
    shard_of: np.ndarray, prev: ExpertPlacement, n_shards: int
) -> ExpertPlacement:
    """Build slot tables, keeping an expert's previous slot when its shard
    did not change (zero migration for unmoved experts)."""
    e = len(shard_of)
    e_loc = e // n_shards
    place = np.full(e, -1, np.int32)
    taken = np.zeros(e, bool)
    # pass 1: unmoved experts keep their physical slot
    for ex in range(e):
        old_slot = prev.inv_place[ex]
        if old_slot // e_loc == shard_of[ex]:
            place[old_slot] = ex
            taken[old_slot] = True
    # pass 2: moved experts fill free slots of their new shard
    for ex in range(e):
        old_slot = prev.inv_place[ex]
        if old_slot // e_loc == shard_of[ex]:
            continue
        s = shard_of[ex]
        free = [p for p in range(s * e_loc, (s + 1) * e_loc) if not taken[p]]
        p = free[0]
        place[p] = ex
        taken[p] = True
    inv = np.zeros(e, np.int32)
    inv[place] = np.arange(e, dtype=np.int32)
    return ExpertPlacement(place, inv, n_shards)


class PlacementController:
    """DRM for experts: EWMA load sketch + KIP placement updates.

    ``expert_weight_bytes`` (bytes one expert's weights + moments occupy)
    turns on the richer placement costing: candidate placements are priced
    by folding the bytes they would move through the exchange backend's
    sizing rule (:func:`~repro.core.migration.exchange_lane_cost`), and the
    :class:`~repro.control.policy.PlacementPolicy` picks the candidate —
    including "stay" — whose balance gain best pays for its weight
    movement (``cost_weight`` scales how many imbalance units one full
    weight-set move is worth).  At 0.0 (default) the pre-costing behavior
    holds: the policy decides *whether*, this host computes the placement.
    """

    def __init__(self, num_experts: int, n_shards: int, *, eps: float = 0.02,
                 alpha: float = 0.5, trigger: float = 1.15, min_steps_between: int = 1,
                 expert_weight_bytes: float = 0.0, cost_weight: float = 1.0,
                 exchange_backend: str | object | None = None,
                 exchange_topology=None):
        self.placement = ExpertPlacement.identity(num_experts, n_shards)
        self.e, self.n = num_experts, n_shards
        self.eps, self.alpha, self.trigger = eps, alpha, trigger
        self.min_steps_between = min_steps_between
        self.expert_weight_bytes = float(expert_weight_bytes)
        self.cost_weight = float(cost_weight)
        self.exchange_backend = resolve_backend(exchange_backend)
        # EP-shard locality (ExchangeTopology over the shards): weight-move
        # candidates are priced per distance class, so two placements with
        # equal balance tie-break toward the one keeping experts on-host
        self.exchange_topology = exchange_topology
        self.loads_ewma = np.zeros(num_experts)
        self.steps = 0
        self.last_update = -(10**9)
        self.history: list[dict] = []
        # control plane: the trigger/cooldown decision is a shared policy,
        # fed by telemetry gathered from normal router statistics
        self.policy = PlacementPolicy()
        self.telemetry = Telemetry("moe")
        self.decisions = DecisionLog("moe")

    def shard_loads(self, loads: np.ndarray) -> np.ndarray:
        e_loc = self.e // self.n
        return loads[self.placement.place].reshape(self.n, e_loc).sum(axis=1)

    def observe(self, counts: np.ndarray, exchange=None) -> None:
        """Fold one step's router counts (and optionally its dispatch
        traffic, as a plane-constructed
        :class:`~repro.exchange.ExchangeStats` from
        ``MoEOut.exchange_stats()``) into the telemetry window."""
        c = np.asarray(counts, np.float64)
        tot = max(c.sum(), 1e-9)
        self.loads_ewma = (1 - self.alpha) * self.loads_ewma + self.alpha * (c / tot)
        self.steps += 1
        self.telemetry.record_batch(float(c.sum()))
        if exchange is not None:
            self.telemetry.record_exchange(exchange)

    def _prev_partitioner(self) -> Partitioner:
        """Previous placement as a Partitioner (explicit routing for all keys)."""
        base = uniform_partitioner(self.n, num_hosts=256, heavy_capacity=0)
        hk = np.arange(self.e, dtype=np.int32)
        order = np.argsort(hk)
        return Partitioner(
            self.n,
            hk[order],
            self.placement.shard_of(hk[order]).astype(np.int32),
            base.host_to_part,
        )

    def _build_candidate(self, choice: str, tight: bool) -> dict:
        """One KIP placement candidate, priced in expert-weight bytes."""
        hist = Histogram.from_counts(np.arange(self.e), np.maximum(self.loads_ewma, 1e-9))
        kip = kip_update(self._prev_partitioner(), hist, num_partitions=self.n,
                         eps=self.eps, heavy_capacity=self.e, tight=tight)
        shard_of = kip.lookup_np(np.arange(self.e, dtype=np.int32))
        shard_of = _slot_constrained(shard_of, self.loads_ewma, self.n)
        new = placement_from_assignment(shard_of, self.placement, self.n)
        # slot permutation: new physical slot p holds logical new.place[p],
        # whose weights currently sit at old slot inv_old[new.place[p]]
        perm = self.placement.inv_place[new.place].astype(np.int32)
        return self._describe(choice, new, perm)

    def _describe(self, choice: str, new: ExpertPlacement, perm: np.ndarray) -> dict:
        ex = np.arange(self.e, dtype=np.int32)
        old_shard = self.placement.shard_of(ex).astype(np.int32)
        new_shard = new.shard_of(ex).astype(np.int32)
        moved_mask = old_shard != new_shard
        bytes_each = self.expert_weight_bytes or 1.0
        transfer = np.zeros((self.n, self.n))
        np.add.at(transfer, (old_shard[moved_mask], new_shard[moved_mask]), bytes_each)
        plan = MigrationPlan(
            keys=ex[moved_mask].astype(np.int64),
            src=old_shard[moved_mask], dst=new_shard[moved_mask],
            weights=np.full(int(moved_mask.sum()), bytes_each),
            transfer=transfer,
            relative_migration=float(moved_mask.mean()),
            num_src=self.n, num_dst=self.n,
        )
        new_sl = self.loads_ewma[new.place].reshape(self.n, -1).sum(axis=1)
        return {
            "choice": choice,
            "placement": new,
            "perm": perm,
            "moved": int((perm != np.arange(self.e)).sum()),
            "planned_imbalance": float(new_sl.max() / max(new_sl.mean(), 1e-12)),
            # weight bytes through the active transport's sizing rule — the
            # same (locality-priced) cost model the streaming
            # RepartitionPolicy prices with
            "est_migration": exchange_lane_cost(
                plan, backend=self.exchange_backend,
                topology=self.exchange_topology,
            ),
        }

    def plan_candidates(self) -> list[dict]:
        """Candidate placements for the weight-costed policy gate: the two
        KIP host-binning modes plus the zero-move "stay" option."""
        stay = self._describe(
            "stay", self.placement, np.arange(self.e, dtype=np.int32)
        )
        return [
            stay,
            self._build_candidate("pack", tight=False),
            self._build_candidate("waterfill", tight=True),
        ]

    def maybe_update(self) -> tuple[bool, ExpertPlacement, np.ndarray]:
        """Returns (changed, placement, slot_perm) where ``slot_perm[p_new] =
        p_old`` is the permutation to apply to stacked expert weights."""
        sl = self.shard_loads(self.loads_ewma)
        signals = self.telemetry.snapshot(loads=sl, num_workers=self.n)
        action = self.policy.evaluate(self, signals)
        detail = {"choice": action.choice} if isinstance(action, Replace) and action.choice else {}
        self.decisions.record(action, tick=self.steps, imbalance=signals.imbalance,
                              detail=detail)
        if not isinstance(action, Replace):
            return False, self.placement, np.arange(self.e, dtype=np.int32)
        imb = signals.imbalance

        if action.placement is not None:
            # the policy already picked the winning (weight-costed) candidate
            new, perm = action.placement, np.asarray(action.perm, np.int32)
            est = action.est_migration
        else:
            cand = self._build_candidate("pack", tight=False)
            new, perm, est = cand["placement"], cand["perm"], cand["est_migration"]
        moved = int((perm != np.arange(self.e)).sum())
        new_sl = self.loads_ewma[new.place].reshape(self.n, -1).sum(axis=1)
        self.history.append({
            "step": self.steps, "imbalance_before": imb,
            "imbalance_planned": float(new_sl.max() / max(new_sl.mean(), 1e-12)),
            "experts_moved": moved,
            "migration_bytes": float(est) if self.expert_weight_bytes else 0.0,
            "choice": action.choice or "pack",
        })
        self.placement = new
        self.last_update = self.steps
        return moved > 0, new, perm


def replicated_assignment(loads: np.ndarray, n_shards: int, replicas: int,
                          eps: float = 0.02) -> tuple[np.ndarray, np.ndarray]:
    """Beyond-paper: heavy-expert replication (serving-oriented).

    The paper can only *isolate* a heavy key; an expert, unlike a keygroup,
    can be cloned — its traffic splits across replicas, beating the
    single-key floor N*f1 that caps every pure partitioner.  Greedy: give
    the ``replicas`` extra physical slots to the heaviest experts (halving/
    thirding their effective load), then KIP-place the E + R virtual
    experts onto shards.

    Returns (owner[E + R] -> logical expert, shard_of[E + R]).
    """
    e = len(loads)
    assert (e + replicas) % n_shards == 0, "E + R must divide into shard slots"
    loads = np.asarray(loads, np.float64) / max(loads.sum(), 1e-12)
    counts = np.ones(e, np.int64)  # replicas per expert
    for _ in range(replicas):
        eff = loads / counts
        counts[int(np.argmax(eff))] += 1
    owner = np.repeat(np.arange(e), counts).astype(np.int32)
    eff_load = (loads / counts)[owner]
    hist = Histogram.from_counts(np.arange(len(owner)), np.maximum(eff_load, 1e-9))
    part = kip_update(uniform_partitioner(n_shards, num_hosts=256, heavy_capacity=0),
                      hist, eps=eps, heavy_capacity=len(owner), tight=True)
    shard_of = part.lookup_np(np.arange(len(owner), dtype=np.int32))
    shard_of = _slot_constrained(shard_of, eff_load, n_shards)
    return owner, shard_of.astype(np.int32)


def apply_placement_to_weights(moe_params: dict, perm: np.ndarray) -> dict:
    """Permute stacked expert arrays to the new physical slots (the state
    migration — under jit/GSPMD this lowers to an expert all-to-all)."""
    perm = jnp.asarray(perm)

    def permute(name, arr):
        if name in ("wi", "wo"):
            return jnp.take(arr, perm, axis=0)
        return arr

    return {k: permute(k, v) if not isinstance(v, dict) else v for k, v in moe_params.items()}
