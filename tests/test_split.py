"""Hot-key splitting: replica tables, kernel/ref agreement, control-plane
Split/Unsplit actions, combiner-side merge, and the ExchangeStats redesign.

The invariants under test:

* the fused kernels' replica pick is bit-identical to the jnp ref and the
  host twin (``split_replica_rows``),
* with every replica count at 1 (d=1) the split-capable path is
  bit-identical to the pre-split path — serial and overlapped,
* a hot key whose load alone exceeds one worker's budget splits, the job
  balances, and the scattered partial aggregates sum to the exact unsplit
  answer; an unsplit merges them back home through the ordinary migration,
* replica tables and split-policy state survive snapshot/restore,
* ``Telemetry.record_exchange`` takes one plane-constructed
  ``ExchangeStats``; the legacy kwarg form warns, mixing both raises.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.control import Signals, Split, SplitPolicy, Telemetry, Unsplit  # noqa: E402
from repro.core.drm import DRConfig, DRMaster  # noqa: E402
from repro.core.partitioner import (  # noqa: E402
    Partitioner,
    heavy_capacity_for,
    split_replica_rows,
    uniform_partitioner,
)
from repro.core.streaming import StreamingJob  # noqa: E402
from repro.exchange import ExchangeStats  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


# ---------------------------------------------------------------------------
# replica tables on the Partitioner
# ---------------------------------------------------------------------------


def test_with_splits_stamps_and_clamps():
    p = uniform_partitioner(8, 4096, 0, heavy_capacity=128)
    q = p.with_splits({7: 4, 13: 2})
    assert q.split_map() == {7: 4, 13: 2}
    # homes are preserved for keys already routed by the base tables
    np.testing.assert_array_equal(
        q.lookup_np(np.array([7, 13], np.int32)),
        p.lookup_np(np.array([7, 13], np.int32)),
    )
    # d clamps to the partition count; d <= 1 drops out of the map
    assert q.with_splits({7: 100}).split_map() == {7: 8}
    assert q.with_splits({7: 1}).split_map() == {}
    # removing all splits leaves a plain-routing table
    assert q.with_splits({}).split_map() == {}


def test_heavy_capacity_for_matches_tile_padding():
    assert heavy_capacity_for(2.0, 8) == 128
    assert heavy_capacity_for(2.0, 128) == 256
    assert heavy_capacity_for(0.0, 8, floor=130) == 256
    assert heavy_capacity_for(0.0, 8) == 128  # at least one tile


# ---------------------------------------------------------------------------
# kernel == ref == host twin
# ---------------------------------------------------------------------------


def test_split_route_kernel_matches_ref_and_host():
    n_parts, lanes, cap = 8, 4, 64
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 500, 512).astype(np.int32)
    keys[::3] = 7  # hot
    valid = rng.random(512) < 0.9
    vals = rng.standard_normal((512, 2)).astype(np.float32)
    part = uniform_partitioner(n_parts, 4096, 0, heavy_capacity=128)
    part = part.with_splits({7: 4})
    t = part.tables()

    got = ops.route_bucketize(
        jnp.asarray(keys), jnp.asarray(valid), t, jnp.asarray(vals),
        num_hosts=part.num_hosts, seed=part.seed, num_lanes=lanes,
        capacity=cap, key_fill=2**31 - 1, num_partitions=n_parts,
        interpret=True,
    )
    want_part = ref.route_bucketize_ref(
        jnp.asarray(keys), jnp.asarray(valid), jnp.asarray(vals),
        t.heavy_keys, t.heavy_parts, t.host_to_part,
        seed=part.seed, num_hosts=part.num_hosts, num_lanes=lanes,
        capacity=cap, key_fill=2**31 - 1,
        heavy_repl=t.heavy_repl, num_partitions=n_parts,
    )[0]
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want_part))
    # the key fans out over its consecutive replica set
    home = int(part.lookup_np(np.array([7], np.int32))[0])
    hit_parts = np.unique(np.asarray(got[0])[(keys == 7) & valid])
    assert set(hit_parts.tolist()) <= {(home + j) % n_parts for j in range(4)}
    assert len(hit_parts) > 1  # it actually spread

    # host twin: per-partition split-row counts match the device route
    rows = split_replica_rows(part, keys, 1, valid)
    dev = np.bincount(np.asarray(got[0])[(keys == 7) & valid],
                      minlength=n_parts)
    np.testing.assert_array_equal(rows, dev)


def test_split_d1_bit_identical_route():
    """All-ones replica column routes exactly like the pre-split kernel."""
    n_parts, lanes, cap = 8, 4, 64
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 500, 512).astype(np.int32)
    valid = rng.random(512) < 0.9
    vals = rng.standard_normal((512, 1)).astype(np.float32)
    part = uniform_partitioner(n_parts, 4096, 0, heavy_capacity=128)
    t = part.tables()
    kwargs = dict(num_hosts=part.num_hosts, seed=part.seed, num_lanes=lanes,
                  capacity=cap, key_fill=2**31 - 1, interpret=True)
    plain = ops.route_bucketize(jnp.asarray(keys), jnp.asarray(valid), t,
                                jnp.asarray(vals), **kwargs)
    split = ops.route_bucketize(jnp.asarray(keys), jnp.asarray(valid), t,
                                jnp.asarray(vals), num_partitions=n_parts,
                                **kwargs)
    for a, b in zip(plain, split):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# SplitPolicy decisions
# ---------------------------------------------------------------------------


def _hot_sketch_drm(cfg, share=0.4, n=8):
    part = uniform_partitioner(n, 4096, 0, heavy_capacity=128)
    drm = DRMaster(part, cfg)
    keys = np.arange(100, dtype=np.int64)
    counts = np.ones(100)
    counts[7] = share * 99 / (1 - share)
    drm.observe(keys, counts)
    return drm


def test_split_policy_fires_and_prices():
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)
    drm = _hot_sketch_drm(cfg)
    a = drm.evaluate(Signals(loads=np.full(8, 1.0), num_workers=8,
                             at_safe_point=True))
    assert isinstance(a, Split)
    assert a.key == 7 and a.replicas >= 2
    assert a.est_relief > a.est_migration  # the pricing gate passed
    assert drm.split_keys == {7: a.replicas}
    assert drm.partitioner.split_map() == drm.split_keys


def test_split_policy_patience_and_dead_zone():
    cfg = DRConfig(split_keys_enabled=True, split_patience=2,
                   imbalance_trigger=100.0)
    drm = _hot_sketch_drm(cfg)
    sig = Signals(loads=np.full(8, 1.0), num_workers=8, at_safe_point=True)
    a1 = drm.evaluate(sig)
    # the split decline falls through to the repartition policy; the streak
    # carries the "sustained" evidence to the next safe point
    assert not a1.taken and drm.split_streak == 1
    a2 = drm.evaluate(sig)
    assert isinstance(a2, Split)
    # below the trigger nothing fires (dead zone)
    drm2 = _hot_sketch_drm(cfg, share=0.10)
    d = drm2.evaluate(sig)
    assert not d.taken and "split" not in d.kind


def test_unsplit_fires_when_cooled():
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)
    drm = _hot_sketch_drm(cfg)
    sig = Signals(loads=np.full(8, 1.0), num_workers=8, at_safe_point=True)
    assert isinstance(drm.evaluate(sig), Split)
    prev = drm.partitioner
    # the key cools: fresh sketch, uniform traffic
    drm.sketch = type(drm.sketch)(512, decay=0.9)
    drm.observe(np.arange(100, dtype=np.int64), np.ones(100))
    a = drm.evaluate(sig)
    assert isinstance(a, Unsplit) and a.key == 7
    assert a.prev.split_map() == prev.split_map()  # still-split partitioner
    assert drm.split_keys == {} and drm.partitioner.split_map() == {}


def test_split_config_needs_dead_zone():
    # validation is unconditional now (PR 10): the dead-zone requirement
    # raises a ValueError whether or not splitting is enabled
    with pytest.raises(ValueError, match="dead zone"):
        DRConfig(split_keys_enabled=True, split_trigger=0.7,
                 unsplit_trigger=0.8)
    with pytest.raises(ValueError, match="dead zone"):
        DRConfig(split_trigger=0.7, unsplit_trigger=0.8)


# ---------------------------------------------------------------------------
# end-to-end streaming: balance + exactness + snapshot + merge
# ---------------------------------------------------------------------------


def _hot_batches(num, size, hot_frac, hot_key=7, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        ks = rng.integers(100, 600, size=size).astype(np.int64)
        ks[rng.random(size) < hot_frac] = hot_key
        out.append(ks)
    return out


@pytest.mark.parametrize("overlap", [False, True])
def test_hot_key_splits_and_stays_exact(overlap, monkeypatch):
    if not overlap:
        monkeypatch.setenv("REPRO_DISABLE_OVERLAP", "1")
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)  # isolate the split mechanism
    job = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
    batches = _hot_batches(5, 4096, hot_frac=0.5)
    for b in batches:
        m = job.process_batch(b)
    assert any(mm.action == "split" for mm in job.metrics)
    assert m.split_keys == 1
    # splitting reduced the measured imbalance on the same workload
    assert job.metrics[-1].imbalance < job.metrics[0].imbalance
    # the scattered partials sum to the exact unsplit answer
    true = float(sum((b == 7).sum() for b in batches))
    assert job.state_count(7) == true
    # ...and are genuinely scattered over more than one worker
    sk = np.asarray(job.state_keys)
    holders = [i for i in range(job.num_workers) if (sk[i] == 7).any()]
    assert len(holders) > 1


def test_unsplit_merges_partials_home():
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)
    job = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
    total = 0.0
    for b in _hot_batches(3, 4096, hot_frac=0.5):
        total += float((b == 7).sum())
        job.process_batch(b)
    assert job.drm.split_keys == {7: 2}
    # cool the stream until the policy collapses the split
    for b in _hot_batches(8, 4096, hot_frac=0.0, seed=9):
        total += float((b == 7).sum())
        m = job.process_batch(b)
        if m.action == "unsplit":
            break
    assert m.action == "unsplit" and m.repartitioned  # it moved state
    assert job.drm.split_keys == {}
    assert job.state_count(7) == total
    sk = np.asarray(job.state_keys)
    holders = [i for i in range(job.num_workers) if (sk[i] == 7).any()]
    assert len(holders) == 1  # merged back to the home worker


def test_split_survives_snapshot_restore():
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)
    job = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
    batches = _hot_batches(4, 4096, hot_frac=0.5)
    for b in batches[:3]:
        job.process_batch(b)
    assert job.drm.split_keys
    snap = job.snapshot()
    restored = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
    restored.restore(snap)
    assert restored.drm.split_keys == job.drm.split_keys
    assert restored.drm.partitioner.split_map() == job.drm.partitioner.split_map()
    np.testing.assert_array_equal(restored.drm.partitioner.heavy_repl,
                                  job.drm.partitioner.heavy_repl)
    assert restored.drm.last_split == job.drm.last_split
    # both continue identically on the next batch
    m1 = job.process_batch(batches[3])
    m2 = restored.process_batch(batches[3])
    assert m1.imbalance == m2.imbalance and m1.action == m2.action
    np.testing.assert_array_equal(np.asarray(job.state_keys),
                                  np.asarray(restored.state_keys))


def test_disabled_split_trajectory_unchanged():
    """split_keys_enabled=False (the default) is the pre-split trajectory."""
    batches = _hot_batches(4, 2048, hot_frac=0.3)
    jobs = [StreamingJob(state_capacity=8192, dr=DRConfig(), seed=0),
            StreamingJob(state_capacity=8192,
                         dr=DRConfig(split_keys_enabled=False), seed=0)]
    for b in batches:
        m0 = jobs[0].process_batch(b)
        m1 = jobs[1].process_batch(b)
        assert (m0.imbalance, m0.action, m0.reason) == \
               (m1.imbalance, m1.action, m1.reason)
    np.testing.assert_array_equal(np.asarray(jobs[0].state_keys),
                                  np.asarray(jobs[1].state_keys))
    np.testing.assert_array_equal(np.asarray(jobs[0].state_vals),
                                  np.asarray(jobs[1].state_vals))


# ---------------------------------------------------------------------------
# ExchangeStats telemetry API
# ---------------------------------------------------------------------------


def test_record_exchange_takes_stats_record():
    t = Telemetry("test")
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.5, padded_rows=40,
                                    occupied_rows=8,
                                    replica_rows=np.array([1, 2, 3])))
    t.record_exchange(ExchangeStats(rows=5, replica_rows=np.array([0, 1, 0])))
    s = t.snapshot(loads=np.ones(3))
    assert s.exchange_rows == 15
    assert s.exchange_padded_rows == 45  # padded defaults to rows
    assert s.exchange_occupied_rows == 13
    np.testing.assert_array_equal(s.exchange_replica_rows, [1, 3, 3])


def test_record_exchange_legacy_kwargs_removed():
    # the loose-kwargs deprecation shim is gone: the only accepted call is
    # one plane-constructed ExchangeStats record
    t = Telemetry("test")
    with pytest.raises(TypeError, match="plane-constructed"):
        t.record_exchange(10, 0.5, padded_rows=40)
    with pytest.raises(TypeError, match="plane-constructed"):
        t.record_exchange({"rows": 10})  # not an ExchangeStats record


def test_record_exchange_rejects_stats_plus_kwargs():
    t = Telemetry("test")
    with pytest.raises(TypeError):
        t.record_exchange(ExchangeStats(rows=10), 0.5)
    with pytest.raises(TypeError):
        t.record_exchange(ExchangeStats(rows=10), padded_rows=4)


def test_streaming_telemetry_carries_replica_rows():
    cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                   imbalance_trigger=100.0)
    job = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
    # capture the Signals the policy stack actually sees each safe point
    seen = []
    orig = job.drm.evaluate

    def spy(signals, **kw):
        seen.append(signals)
        return orig(signals, **kw)

    job.drm.evaluate = spy
    for b in _hot_batches(3, 4096, hot_frac=0.5):
        job.process_batch(b)
    assert job.drm.split_keys
    # after the split installs, the shuffle records per-replica rows
    rr = seen[-1].exchange_replica_rows
    assert rr is not None and rr.sum() > 0
    assert (rr > 0).sum() > 1  # the hot key really landed on >1 partition


# ---------------------------------------------------------------------------
# least-load replica pick (DRConfig.split_least_load)
# ---------------------------------------------------------------------------


def test_least_load_two_choice_ref():
    """The two-choice pick steers split traffic off an overloaded replica
    partition, never leaves the replica set, and with an all-equal load
    vector is value-identical to the stateless pick (ties keep hash 1)."""
    n_parts = 8
    keys = np.full(512, 7, np.int32)
    part = uniform_partitioner(n_parts, 4096, 0, heavy_capacity=128)
    part = part.with_splits({7: 4})
    t = part.tables()
    home = int(part.lookup_np(np.array([7], np.int32))[0])
    homes = jnp.full(512, home, jnp.int32)
    kw = dict(seed=part.seed, num_partitions=n_parts)

    _, off0 = ref.split_choice_ref(jnp.asarray(keys), t.heavy_keys,
                                   t.heavy_repl, **kw)
    # all-equal loads: bit-identical routing to the stateless pick
    _, off_eq = ref.split_choice_ref(jnp.asarray(keys), t.heavy_keys,
                                     t.heavy_repl, home=homes,
                                     part_loads=jnp.ones(n_parts), **kw)
    np.testing.assert_array_equal(np.asarray(off0), np.asarray(off_eq))
    # no loads / no home: the load-aware block is inert
    _, off_nl = ref.split_choice_ref(jnp.asarray(keys), t.heavy_keys,
                                     t.heavy_repl, home=homes, **kw)
    np.testing.assert_array_equal(np.asarray(off0), np.asarray(off_nl))

    # overload the stateless pick's favourite replica: traffic moves off it
    dest0 = (home + np.asarray(off0)) % n_parts
    hot_rep = np.bincount(dest0, minlength=n_parts).argmax()
    loads = np.ones(n_parts, np.float32)
    loads[hot_rep] = 1e9
    _, off_l = ref.split_choice_ref(jnp.asarray(keys), t.heavy_keys,
                                    t.heavy_repl, home=homes,
                                    part_loads=jnp.asarray(loads), **kw)
    dest_l = (home + np.asarray(off_l)) % n_parts
    assert (dest_l == hot_rep).sum() < (dest0 == hot_rep).sum()
    # both hashes stay inside the key's consecutive replica window
    assert set(np.unique(dest_l).tolist()) <= {
        (home + j) % n_parts for j in range(4)
    }


def test_least_load_gates_pallas_statically():
    """part_loads is jnp-twin only: the plane refuses to route it through
    the Pallas kernel (the kernel keeps the stateless pick), and the
    default use_pallas resolution turns the kernel off when a load vector
    is present."""
    from repro.exchange import ExchangeSpec, make_exchange
    from repro.exchange.plane import route_dispatch

    n_parts = 8
    part = uniform_partitioner(n_parts, 4096, 0, heavy_capacity=128)
    part = part.with_splits({7: 4})
    keys = jnp.asarray(np.full(64, 7, np.int32))
    valid = jnp.ones(64, bool)
    loads = jnp.ones(n_parts, jnp.float32)
    with pytest.raises(AssertionError):
        route_dispatch(part.tables(), keys, valid, num_hosts=part.num_hosts,
                       seed=part.seed, num_lanes=4, num_partitions=n_parts,
                       part_loads=loads, use_pallas=True)
    # default resolution: loads present -> jnp twin, no raise
    p_l, _, _ = route_dispatch(part.tables(), keys, valid,
                               num_hosts=part.num_hosts, seed=part.seed,
                               num_lanes=4, num_partitions=n_parts,
                               part_loads=loads)
    p_0, _, _ = route_dispatch(part.tables(), keys, valid,
                               num_hosts=part.num_hosts, seed=part.seed,
                               num_lanes=4, num_partitions=n_parts)
    # equal loads route identically to the stateless pick
    np.testing.assert_array_equal(np.asarray(p_l), np.asarray(p_0))


def test_least_load_job_bit_identical_across_drivers():
    """split_least_load end-to-end: serial, depth-1 and depth-2 drivers all
    feed the same previous-batch load vector to the route at safe points,
    so their trajectories and final state stay bit-identical — and the
    split answer stays exact."""
    batches = _hot_batches(6, 4096, hot_frac=0.5)
    out = {}
    for name, (overlap, depth) in {"serial": (False, 1), "d1": (True, 1),
                                   "d2": (True, 2)}.items():
        cfg = DRConfig(split_keys_enabled=True, split_patience=1,
                       imbalance_trigger=100.0, split_least_load=True,
                       overlap_exchange=overlap, pipeline_depth=depth)
        job = StreamingJob(state_capacity=8192, dr=cfg, seed=0)
        ms = job.run(batches)
        out[name] = (job, [(m.action, m.reason, m.overflow, m.shipped_rows,
                            m.padded_rows, m.backend, m.split_keys,
                            round(m.imbalance, 9)) for m in ms])
    assert out["serial"][1] == out["d1"][1] == out["d2"][1]
    assert any(t[0] == "split" for t in out["d2"][1])
    true = float(sum((b == 7).sum() for b in batches))
    for name in out:
        assert out[name][0].state_count(7) == true, name
