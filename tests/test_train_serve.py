"""Optimizer, train-step, checkpoint crash-consistency, serving, scheduler."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import get_config
from repro.models import model
from repro.models.modules import Policy
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import DRScheduler
from repro.train import checkpoint
from repro.train.optimizer import OptConfig, apply_updates, init_opt
from repro.train.train_step import make_train_step

POL = Policy(attn_q_chunk=64, attn_kv_chunk=64)


def _smoke(arch="stablelm-1.6b"):
    return reduce_for_smoke(get_config(arch))


def _batch(cfg, rng, b=2, s=32):
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }


class TestOptimizer:
    def test_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup=1)
        st = init_opt(params, cfg)
        for _ in range(200):
            g = {"w": 2 * params["w"]}
            params, st, m = apply_updates(params, g, st, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clipping(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptConfig(clip_norm=1.0, warmup=1)
        st = init_opt(params, cfg)
        _, _, m = apply_updates(params, {"w": jnp.full(4, 100.0)}, st, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_moments(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptConfig(moment_dtype=jnp.bfloat16)
        st = init_opt(params, cfg)
        assert st.m["w"].dtype == jnp.bfloat16


def test_train_loss_decreases():
    cfg = _smoke("gemma-2b")
    rng = np.random.default_rng(0)
    params = model.init_params(cfg, jax.random.PRNGKey(0), POL)
    opt_cfg = OptConfig(lr=1e-2, warmup=5)
    opt = init_opt(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, POL, opt_cfg))
    batch = _batch(cfg, rng)  # overfit one batch
    losses = []
    for _ in range(15):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_moe_train_emits_expert_counts():
    cfg = _smoke("llama4-scout-17b-a16e")
    rng = np.random.default_rng(1)
    params = model.init_params(cfg, jax.random.PRNGKey(0), POL)
    opt_cfg = OptConfig()
    opt = init_opt(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, POL, opt_cfg))
    params, opt, metrics = step(params, opt, _batch(cfg, rng))
    counts = np.asarray(metrics["expert_counts"])
    assert counts.shape == (cfg.moe.num_experts,)
    assert counts.sum() == 2 * 32 * cfg.moe.top_k * cfg.num_layers


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": {"b": np.arange(6).reshape(2, 3)}, "c": [np.ones(2), np.zeros(1)]}
        checkpoint.save(str(tmp_path), 5, tree)
        step, back = checkpoint.restore(str(tmp_path), tree)
        assert step == 5
        np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
        np.testing.assert_array_equal(back["c"][0], tree["c"][0])

    def test_keep_last_k(self, tmp_path):
        tree = {"x": np.zeros(1)}
        for s in range(6):
            checkpoint.save(str(tmp_path), s, tree, keep=2)
        steps = sorted(os.listdir(tmp_path))
        assert len(steps) == 2 and steps[-1].endswith("05")

    def test_corruption_falls_back(self, tmp_path):
        tree = {"x": np.arange(4)}
        checkpoint.save(str(tmp_path), 1, {"x": np.arange(4)})
        checkpoint.save(str(tmp_path), 2, {"x": np.arange(4) * 2})
        # corrupt the newest
        path = os.path.join(str(tmp_path), "step_000000002", "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad\xbe\xef")
        out = checkpoint.restore(str(tmp_path), tree)
        assert out is not None
        step, back = out
        assert step == 1
        np.testing.assert_array_equal(back["x"], np.arange(4))

    def test_crash_mid_write_is_invisible(self, tmp_path):
        tree = {"x": np.arange(4)}
        checkpoint.save(str(tmp_path), 1, tree)
        # simulate a crash: a stale tmp dir left behind
        os.makedirs(os.path.join(str(tmp_path), ".tmp_9"))
        step, _ = checkpoint.restore(str(tmp_path), tree)
        assert step == 1

    def test_full_train_state_roundtrip(self, tmp_path):
        cfg = _smoke("xlstm-125m")
        params = model.init_params(cfg, jax.random.PRNGKey(0), POL)
        opt = init_opt(params, OptConfig())
        tree = {"params": params, "opt": opt}
        npy = jax.tree.map(np.asarray, tree)
        checkpoint.save(str(tmp_path), 7, npy)
        step, back = checkpoint.restore(str(tmp_path), npy)
        for a, b in zip(jax.tree.leaves(npy), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServe:
    def test_engine_completes_requests(self):
        cfg = _smoke("gemma-2b")
        params = model.init_params(cfg, jax.random.PRNGKey(0), POL)
        eng = ServeEngine(cfg, params, POL, slots=2, max_len=64)
        rng = np.random.default_rng(2)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4)
            for i in range(5)
        ]
        eng.run(reqs, max_ticks=100)
        assert all(len(r.out_tokens) >= 4 or r.done for r in reqs)
        assert eng.tokens_out >= 5 * 3

    def test_scheduler_balances_hot_sessions(self):
        """DR routing beats UHP on hot-tenant traffic (4 tenants x 10%)."""
        rng = np.random.default_rng(3)
        hot = np.array([7, 13, 99, 1234])
        r = rng.random(8000)
        keys = np.where(r < 0.4, hot[rng.integers(0, 4, 8000)],
                        rng.integers(0, 5000, 8000)).astype(np.int64)

        def run(dr_enabled):
            sched = DRScheduler(8)
            imb = []
            for i in range(8):
                win = keys[i * 1000 : (i + 1) * 1000]
                for k in win:
                    sched.route(int(k), cost_tokens=1.0)
                imb.append(sched.imbalance())
                if dr_enabled:
                    sched.checkpoint(win)
                sched.drain(tokens_per_replica=150)
            return sched, imb

        dr, imb_dr = run(True)
        uhp, imb_uhp = run(False)
        assert np.mean(imb_dr[2:]) < np.mean(imb_uhp[2:])
        assert dr.migrations > 0
