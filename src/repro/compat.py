"""Version-tolerance shims for jax APIs that moved between releases.

Every module that needs ``shard_map`` imports it from here instead of from
jax directly, so the repo tracks exactly one spelling of each API:

* ``shard_map``  — ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (<= 0.4.x), absorbing the ``check_rep`` -> ``check_vma`` rename and the
  ``auto`` -> ``axis_names`` inversion (old jax names the *auto* axes, new
  jax names the *manual* ones).
* ``set_mesh``   — ``jax.set_mesh`` (new) vs entering the ``Mesh`` context
  manager (old); both forms support ``with set_mesh(mesh): ...``.

Call sites use the modern spellings (``check_vma=``, ``axis_names=``); the
shim rewrites them for whatever jax is installed.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)

__all__ = ["shard_map", "set_mesh"]


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    check_rep: bool | None = None,
    axis_names=None,
    auto=None,
):
    """``shard_map`` with one signature across jax versions."""
    check = check_vma if check_vma is not None else check_rep
    kwargs = {}
    if "check_vma" in _PARAMS:  # new-style jax
        if check is not None:
            kwargs["check_vma"] = check
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        elif auto is not None:
            kwargs["axis_names"] = set(mesh.axis_names) - set(auto)
    else:  # old-style: check_rep + auto (complement of the manual axes)
        if check is not None:
            kwargs["check_rep"] = check
        if auto is not None:
            kwargs["auto"] = frozenset(auto)
        elif axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # older jax: Mesh is itself a context manager
