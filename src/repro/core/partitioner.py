"""Partitioning functions: UHP and the Key Isolator Partitioner (KIP).

A partitioner is represented by three small device-friendly tables so the
per-record lookup is fully vectorized (and has a Pallas kernel twin in
``repro.kernels.partition_apply``):

* ``heavy_keys``  int32[B]  sorted ascending, padded with ``KEY_SENTINEL``
* ``heavy_parts`` int32[B]  explicit partition of each heavy key
* ``host_to_part`` int32[H] weighted-hash routing: key -> host -> partition

``kip_update`` implements Algorithm 1 (KIPUPDATE) from the paper: heavy keys
try (1) their previous partition, (2) their plain-hash location, (3) the
least-loaded partition; hosts are then greedily re-binned so no partition
exceeds ``MAXLOAD = max(1/N, Hist[1].freq) + eps``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import DEFAULT_NUM_HOSTS, KEY_SENTINEL, hash_to_host
from repro.core.histogram import Histogram

__all__ = [
    "PartitionerTables",
    "Partitioner",
    "uniform_partitioner",
    "kip_update",
    "resize_partitioner",
]


class PartitionerTables(NamedTuple):
    """The jit-traversable device representation of a partitioner."""

    heavy_keys: jax.Array  # int32[B] sorted, padded with KEY_SENTINEL
    heavy_parts: jax.Array  # int32[B]
    host_to_part: jax.Array  # int32[H]


@dataclasses.dataclass(frozen=True)
class Partitioner:
    """Host-side partitioner object (numpy tables + metadata)."""

    num_partitions: int
    heavy_keys: np.ndarray  # int32[B] sorted ascending (sentinel padded)
    heavy_parts: np.ndarray  # int32[B]
    host_to_part: np.ndarray  # int32[H]
    seed: int = 0

    @property
    def num_hosts(self) -> int:
        return len(self.host_to_part)

    @property
    def num_heavy(self) -> int:
        return int((self.heavy_keys != KEY_SENTINEL).sum())

    def tables(self) -> PartitionerTables:
        return PartitionerTables(
            jnp.asarray(self.heavy_keys),
            jnp.asarray(self.heavy_parts),
            jnp.asarray(self.host_to_part),
        )

    # -- lookups ----------------------------------------------------------
    def lookup_np(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized host-side partition lookup (planning / benchmarks)."""
        keys = np.asarray(keys, np.int32)
        hosts = hash_to_host(keys, self.num_hosts, self.seed, xp=np)
        part = self.host_to_part[hosts]
        if self.num_heavy:
            idx = np.searchsorted(self.heavy_keys, keys)
            idx = np.minimum(idx, len(self.heavy_keys) - 1)
            hit = self.heavy_keys[idx] == keys
            part = np.where(hit, self.heavy_parts[idx], part)
        return part.astype(np.int32)

    def heavy_map(self) -> dict[int, int]:
        m = self.heavy_keys != KEY_SENTINEL
        return dict(zip(self.heavy_keys[m].tolist(), self.heavy_parts[m].tolist()))


def lookup_device(tables: PartitionerTables, keys: jax.Array, num_hosts: int, seed: int = 0) -> jax.Array:
    """jnp twin of :meth:`Partitioner.lookup_np` (used inside jit)."""
    keys = keys.astype(jnp.int32)
    hosts = hash_to_host(keys, num_hosts, seed, xp=jnp)
    part = tables.host_to_part[hosts]
    if tables.heavy_keys.shape[0] == 0:  # no explicit routing table
        return part.astype(jnp.int32)
    idx = jnp.clip(jnp.searchsorted(tables.heavy_keys, keys), 0, tables.heavy_keys.shape[0] - 1)
    hit = tables.heavy_keys[idx] == keys
    return jnp.where(hit, tables.heavy_parts[idx], part).astype(jnp.int32)


def _pad_heavy(keys: np.ndarray, parts: np.ndarray, capacity: int):
    """Sort by key and sentinel-pad heavy tables to fixed width."""
    order = np.argsort(keys, kind="stable")
    keys, parts = keys[order], parts[order]
    pad = capacity - len(keys)
    assert pad >= 0, f"heavy table overflow: {len(keys)} > {capacity}"
    keys = np.concatenate([keys, np.full(pad, KEY_SENTINEL, np.int32)])
    parts = np.concatenate([parts, np.zeros(pad, np.int32)])
    return keys.astype(np.int32), parts.astype(np.int32)


def uniform_partitioner(
    num_partitions: int,
    num_hosts: int = DEFAULT_NUM_HOSTS,
    seed: int = 0,
    heavy_capacity: int = 0,
) -> Partitioner:
    """UHP — the Spark/Flink default: hash(key) mod N (host table = h mod N)."""
    host_to_part = (np.arange(num_hosts, dtype=np.int64) % num_partitions).astype(np.int32)
    hk, hp = _pad_heavy(np.zeros(0, np.int32), np.zeros(0, np.int32), heavy_capacity)
    return Partitioner(num_partitions, hk, hp, host_to_part, seed)


def kip_update(
    prev: Partitioner,
    hist: Histogram,
    num_partitions: int | None = None,
    eps: float = 0.01,
    heavy_capacity: int | None = None,
    tight: bool = False,
) -> Partitioner:
    """Algorithm 1 — KIPUPDATE(KI, HASH, H, Hist, N, eps).

    ``prev`` is KI (the partitioner of the previous stage); its
    ``host_to_part`` also serves as the HASH host mapping when probing a
    heavy key's fallback location.  ``num_partitions`` may differ from
    ``prev.num_partitions`` (elastic resize uses this).
    """
    n = int(num_partitions or prev.num_partitions)
    h = prev.num_hosts
    seed = prev.seed
    b = len(hist)
    cap = heavy_capacity if heavy_capacity is not None else max(b, prev.heavy_keys.shape[0])

    keys = hist.keys.astype(np.int64)
    freqs = hist.freqs.astype(np.float64)

    # line 1: allowed load level
    top_freq = float(freqs[0]) if b else 0.0
    maxload = max(1.0 / n, top_freq) + eps
    # line 2: average load carried by one host (tail mass spread over hosts)
    hostload = max(0.0, 1.0 - float(freqs.sum())) / h

    load = np.zeros(n, np.float64)
    prev_heavy = prev.heavy_map()
    # previous assignment of each heavy key under KI
    prev_part = prev.lookup_np(keys.astype(np.int32))
    # the pure-hash (future non-heavy) location under the previous host map
    hash_host = hash_to_host(keys.astype(np.int32), h, seed, xp=np)
    hash_part = prev.host_to_part[hash_host]
    if n < prev.num_partitions:  # elastic shrink: fold removed partitions
        prev_part = prev_part % n
        hash_part = hash_part % n
        prev_heavy = {k: p % n for k, p in prev_heavy.items()}

    heavy_parts = np.zeros(b, np.int32)
    for i in range(b):  # Hist is ordered by decreasing frequency
        f = freqs[i]
        p = int(prev_heavy.get(int(keys[i]), prev_part[i]))  # line 4: KI(k)
        if load[p] < maxload - f:  # line 5
            heavy_parts[i] = p
            load[p] += f
            continue
        p = int(hash_part[i])  # line 7: HASH(k)
        if load[p] < maxload - f:  # line 8
            heavy_parts[i] = p
            load[p] += f
            continue
        p = int(np.argmin(load))  # line 10: lowest-load partition
        heavy_parts[i] = p
        load[p] += f

    # lines 11-13: add host loads under the previous host->partition mapping
    host_to_part = prev.host_to_part.copy()
    if n < prev.num_partitions:
        host_to_part = host_to_part % n
    hosts_per_part = np.bincount(host_to_part, minlength=n).astype(np.float64)
    load = load + hostload * hosts_per_part

    # lines 14-15: greedy bin packing — move hosts off overloaded partitions
    if tight and hostload > 0:
        # Beyond-paper 'tight' mode: Algorithm 1 only rebins hosts when a
        # partition exceeds MAXLOAD, which for f1 >> 1/N leaves the tail
        # spread untouched.  Waterfill instead: equalize total loads at the
        # level L solving sum_p max(0, L - heavy_load[p]) = tail_mass, and
        # move the minimal number of hosts toward per-partition quotas.
        heavy_only = load - hostload * hosts_per_part
        tail_mass = hostload * h
        lo, hi = heavy_only.min(), heavy_only.max() + tail_mass + hostload
        for _ in range(60):  # bisection on the waterline
            mid = 0.5 * (lo + hi)
            if np.maximum(0.0, mid - heavy_only).sum() > tail_mass:
                hi = mid
            else:
                lo = mid
        quota = np.maximum(0.0, hi - heavy_only) / hostload
        quota = np.floor(quota).astype(int)
        # distribute leftover host slots to lowest-load partitions
        leftover = h - quota.sum()
        order = np.argsort(heavy_only + quota * hostload)
        for i in range(leftover):
            quota[order[i % n]] += 1
        hosts_of = [list(np.where(host_to_part == p)[0]) for p in range(n)]
        surplus = []
        for p in range(n):
            while len(hosts_of[p]) > quota[p]:
                surplus.append(hosts_of[p].pop())
        for p in range(n):
            while len(hosts_of[p]) < quota[p] and surplus:
                hh = surplus.pop()
                host_to_part[hh] = p
                hosts_of[p].append(hh)
        hosts_per_part = np.bincount(host_to_part, minlength=n).astype(np.float64)
        load = heavy_only + hostload * hosts_per_part
    elif hostload > 0:
        order_src = np.argsort(-load, kind="stable")
        # hosts grouped per partition for O(H) moves
        hosts_of = [np.where(host_to_part == p)[0].tolist() for p in range(n)]
        dst_iter = 0
        dsts = np.argsort(load, kind="stable").tolist()
        for p in order_src.tolist():
            while load[p] > maxload and hosts_of[p]:
                # first partition with room for one more host
                while dst_iter < len(dsts) and (
                    dsts[dst_iter] == p or load[dsts[dst_iter]] >= maxload - hostload
                ):
                    dst_iter += 1
                if dst_iter >= len(dsts):
                    break  # nowhere below the bound: leave residual imbalance
                q = dsts[dst_iter]
                hh = hosts_of[p].pop()
                host_to_part[hh] = q
                hosts_of[q].append(hh)
                load[p] -= hostload
                load[q] += hostload

    hk, hp = _pad_heavy(keys.astype(np.int32), heavy_parts, max(cap, b))
    return Partitioner(n, hk, hp, host_to_part.astype(np.int32), seed)


def resize_partitioner(
    prev: Partitioner,
    num_partitions: int,
    hist: Histogram | None = None,
    *,
    eps: float = 0.01,
    heavy_capacity: int | None = None,
    tight: bool = True,
) -> Partitioner:
    """Elastic grow/shrink: re-plan ``prev`` for a different partition count.

    This is :func:`kip_update` with ``num_partitions != prev.num_partitions``
    — shrink folds removed partitions (``p % n``), grow relies on the host
    re-binning (waterfill under ``tight``) to populate the new partitions —
    plus the degenerate case of a resize *before any histogram exists*: an
    empty histogram still re-bins hosts, so every partition receives hash
    traffic immediately after the resize.
    """
    n = int(num_partitions)
    if n < 1:
        raise ValueError(f"num_partitions must be >= 1, got {n}")
    if hist is None:
        hist = Histogram(np.zeros(0, np.int64), np.zeros(0), 0.0)
    return kip_update(
        prev, hist, num_partitions=n, eps=eps, heavy_capacity=heavy_capacity, tight=tight
    )


# ---------------------------------------------------------------------------
# Balance metrics (paper's evaluation currency)
# ---------------------------------------------------------------------------


def load_imbalance(partitioner: Partitioner, key_stream: np.ndarray) -> float:
    """max(load) / mean(load) over the actual key stream (paper Fig. 2/3)."""
    parts = partitioner.lookup_np(np.asarray(key_stream, np.int32))
    loads = np.bincount(parts, minlength=partitioner.num_partitions)
    return float(loads.max() / max(loads.mean(), 1e-12))


def expected_loads(partitioner: Partitioner, hist: Histogram) -> np.ndarray:
    """Planner's view of per-partition load given a histogram."""
    n = partitioner.num_partitions
    load = np.zeros(n)
    parts = partitioner.lookup_np(hist.keys.astype(np.int32))
    np.add.at(load, parts, hist.freqs)
    hosts_per_part = np.bincount(partitioner.host_to_part, minlength=n)
    load += hist.tail_mass / partitioner.num_hosts * hosts_per_part
    return load
