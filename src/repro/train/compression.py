"""int8 gradient compression with error feedback (distributed-opt trick).

For bandwidth-bound DP training the cross-replica gradient reduction can
run on int8 tensors: quantize per-tensor (symmetric, stochastic-rounding
free since error feedback absorbs bias), all-reduce the int8 payload in
f32 accumulation, dequantize, and carry the quantization residual into the
next step (error feedback keeps convergence unbiased).

Used via ``shard_map`` over the data axes as an explicit grad-sync stage —
the jit/GSPMD path keeps its fused bf16 reductions; this is the opt-in
4x-compression alternative for ICI-constrained pods.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["init_error_feedback", "compressed_grad_sync"]


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_grad_sync(mesh: Mesh, axes: tuple[str, ...] = ("data",)):
    """Returns jitted ``sync(local_grads, error) -> (mean_grads, new_error)``.

    ``local_grads`` are per-replica (unsynced) gradients sharded over
    ``axes``; output gradients are the exact int8-compressed mean with the
    per-replica quantization error carried in ``error``.
    """
    naxes = 1
    for a in axes:
        naxes *= mesh.shape[a]

    def sync_one(g, e):
        def local(g_loc, e_loc):
            g32 = g_loc.astype(jnp.float32) + e_loc
            q, scale = _quantize(g32)
            # all-reduce the small int8 payload (accumulate in f32)
            summed = jax.lax.psum(q.astype(jnp.float32) * scale, axes)
            mean = summed / naxes
            new_e = g32 - q.astype(jnp.float32) * scale  # error feedback
            return mean, new_e

        spec = P()  # grads replicated within a replica; reduced across axes
        return shard_map(
            local, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_vma=False,
        )(g, e)

    @jax.jit
    def sync(grads, error):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error)
        out = [sync_one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in out]),
                jax.tree.unflatten(tdef, [o[1] for o in out]))

    return sync
