"""Fault-tolerant checkpointing: atomic npz shards + manifest, keep-last-k.

Layout::

    <dir>/step_000123/
        arrays.npz        flattened param/opt/DR pytree (one file; TPU-pod
                          deployments would shard this per-host — the layout
                          keeps one npz per *process*)
        manifest.json     step, tree structure, adler32 checksums

Writes go to ``<dir>/.tmp_<step>`` then ``os.rename`` — a crash mid-write
never corrupts the latest checkpoint.  ``restore`` verifies checksums and
falls back to the newest intact checkpoint (crash-consistency test in
tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(SEP)] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray], like: Any, prefix: str = "") -> Any:
    if isinstance(like, dict):
        return {k: _unflatten(flat, v, f"{prefix}{k}{SEP}") for k, v in like.items()}
    if isinstance(like, tuple):
        vals = [_unflatten(flat, v, f"{prefix}{i}{SEP}") for i, v in enumerate(like)]
        return type(like)(*vals) if hasattr(like, "_fields") else tuple(vals)
    if isinstance(like, list):
        return [_unflatten(flat, v, f"{prefix}{i}{SEP}") for i, v in enumerate(like)]
    if like is None:
        return None
    return flat[prefix.rstrip(SEP)]


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(directory, f".tmp_{step}")
    final = os.path.join(directory, f"step_{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "checksums": {k: zlib.adler32(np.ascontiguousarray(v).tobytes()) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def _intact(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for k, want in manifest["checksums"].items():
                got = zlib.adler32(np.ascontiguousarray(z[k]).tobytes())
                if got != want:
                    return False
        return True
    except Exception:
        return False


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    )
    return steps[-1] if steps else None


def restore(directory: str, like: Any) -> tuple[int, Any] | None:
    """Restore the newest *intact* checkpoint (corrupted ones are skipped)."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        (d for d in os.listdir(directory) if d.startswith("step_")), reverse=True
    )
    for d in steps:
        path = os.path.join(directory, d)
        if not _intact(path):
            continue
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            step = json.load(f)["step"]
        return step, _unflatten(flat, like)
    return None
