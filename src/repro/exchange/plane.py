"""The unified exchange plane: ``route -> bucketize -> all_to_all -> unpack``.

The paper's DR module works because repartitioning "reuses normal DDPS
communication".  This module is that communication, implemented once and
split **spec + backend**: an :class:`~repro.exchange.spec.ExchangeSpec`
names the static shape of one exchange (lanes x capacity over an optional
mesh axis), an :class:`~repro.exchange.backends.ExchangeBackend` moves the
buffers (dense capacity-padded, ragged count-first, or local no-collective),
and :class:`Exchange` binds the two for the consumers — the micro-batch
shuffle (``repro.core.shuffle``), operator-state migration
(``make_migrate_step``) and MoE expert dispatch (``repro.moe.layer``).
Following Partial Key Grouping / AutoFlow, the routing+exchange primitive is
the pluggable unit; the balancing policy (KIP, KIP placement, migration
planning) layers on top and never touches collectives directly — and the
backend's measured ``shipped_rows`` / ``cost`` feed the control plane, so
policy decisions price what the active transport would actually move.

All functions are pure jnp and run inside ``jit`` / ``shard_map``.  The
routing hot path has a fused Pallas kernel
(``repro.kernels.lookup_dispatch``) with a bit-identical jnp twin; the twin
is the default off-TPU.
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.core.partitioner import PartitionerTables
from repro.exchange.backends import ExchangeBackend, resolve_backend
from repro.exchange.spec import (
    ExchangeResult,
    ExchangeSpec,
    Payload,
    SendInfo,
    take_from,
)
from repro.kernels import ref as kref

__all__ = [
    "ExchangeSpec",
    "Payload",
    "SendInfo",
    "ExchangeResult",
    "Exchange",
    "make_exchange",
    "route_dispatch",
    "take_from",
]


def route_dispatch(
    tables: PartitionerTables,
    keys: jax.Array,
    valid: jax.Array,
    *,
    num_hosts: int,
    seed: int,
    num_lanes: int,
    use_pallas: bool | None = None,
):
    """Fused key -> partition lookup + lane slot assignment.

    Returns ``(part[n], slot[n], counts[num_lanes])`` where ``slot`` ranks
    each valid record within its ``part % num_lanes`` lane and ``counts``
    is the per-lane occupancy the same pass already tallied — hand both to
    ``bucketize`` so it derives neither again (the ragged backend's count
    phase and the per-lane overflow both reuse them).  On TPU this is one
    fused Pallas kernel (``repro.kernels.lookup_dispatch``); elsewhere the
    bit-identical jnp twin.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from repro.kernels import ops

        part, slot, counts = ops.route_slots(
            keys, valid, tables, num_hosts=num_hosts, seed=seed, num_lanes=num_lanes
        )
    else:
        part, slot, counts = kref.lookup_dispatch_ref(
            keys, valid, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
            seed=seed, num_hosts=num_hosts, num_lanes=num_lanes,
        )
    return part, slot, counts


class Exchange:
    """One :class:`ExchangeSpec` bound to one :class:`ExchangeBackend`.

    Calling it runs the full ``bucketize -> all_to_all -> unpack`` sequence;
    ``bucketize`` alone builds the lane-major send buffers (local dispatch),
    and ``backhaul`` runs the reverse collective for request-response
    patterns (MoE combine).  The backend decides *how* buffers move and what
    ``shipped_rows`` the move costs; the call sites are identical across
    backends.
    """

    def __init__(self, spec: ExchangeSpec, backend: str | ExchangeBackend | None = None):
        self.spec = spec
        self.backend = resolve_backend(backend, spec)

    # -- step 2: capacity-padded send-buffer builder -----------------------
    def bucketize(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
    ) -> ExchangeResult:
        return self.backend.bucketize(
            self.spec, lane, valid, payloads, slot=slot, counts=counts
        )

    # -- step 3: the collective -------------------------------------------
    def all_to_all(self, buffers: ExchangeResult) -> ExchangeResult:
        return self.backend.all_to_all(self.spec, buffers)

    def backhaul(
        self, buffers: jax.Array, forward: ExchangeResult | None = None
    ) -> tuple[jax.Array, jax.Array]:
        """Reverse collective for already-laned response buffers.

        ``forward`` is the exchanged result of the request hop; when it
        carries counts (the ragged transport's phase 1) the response ships
        compacted rows with no second count phase — the response occupancy
        *is* the forward ``recv_counts``, and what comes back is the forward
        ``lane_counts``.  Returns ``(rows, shipped_rows)``: the response
        buffers plus the rows this worker's transport measured moving, so
        request-response consumers (the MoE combine) account both
        directions.
        """
        send_counts = forward.recv_counts if forward is not None else None
        recv_counts = forward.lane_counts if forward is not None else None
        return self.backend.backhaul(
            self.spec, buffers, send_counts=send_counts, recv_counts=recv_counts
        )

    # -- the full primitive ------------------------------------------------
    def __call__(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
        counts: jax.Array | None = None,
    ) -> ExchangeResult:
        return self.all_to_all(
            self.bucketize(lane, valid, payloads, slot=slot, counts=counts)
        )


def make_exchange(
    spec: ExchangeSpec, backend: str | ExchangeBackend | None = None
) -> Exchange:
    """Build the exchange primitive for one static spec.

    ``backend`` selects the transport — ``"dense"`` / ``"ragged"`` /
    ``"local"``, an :class:`ExchangeBackend` instance, or ``None`` to
    auto-select (local when ``spec.axis is None``, else dense).
    """
    return Exchange(spec, backend)
