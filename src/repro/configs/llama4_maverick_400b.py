"""llama4-maverick-400b-a17b [moe]: 48L, d=5120, 40H (kv=8), vocab=202048,
MoE 128 experts top-1 (interleaved every other layer, d_ff_expert=8192,
shared expert) + dense layers d_ff=16384.  [hf:meta-llama/Llama-4 family]"""
from repro.configs.base import ArchConfig, Block, MoESpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=202048,
    pattern=(Block("attn", "dense"), Block("attn", "moe")),
    moe=MoESpec(num_experts=128, top_k=1, d_ff_expert=8192, shared_expert=True),
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=False,
    subquadratic=False,
    notes="DR/KIP expert placement applies (128e top-1 is maximally skew-prone); long_500k skipped (full attention)",
)
