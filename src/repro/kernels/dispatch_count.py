"""Pallas TPU kernel: shuffle bucketing — per-record send slots + counts.

Given each record's destination partition, the capacity-padded all-to-all
buffer needs, for record ``i`` with destination ``d``::

    slot[i] = #{ j < i : dest[j] == d }      (stable rank within destination)
    counts[d] = total records destined to d

The rank is computed block-wise with the classic TPU MoE-dispatch trick: an
exclusive prefix sum over the one-hot destination matrix expressed as a
lower-triangular matmul (MXU) instead of a sequential scan, with the running
per-destination counts carried across the sequential grid in a VMEM
accumulator.

VMEM budget (block = 512, N <= 1024):
  tri 512^2*4B = 1 MiB; one-hot 512*1024*4B = 2 MiB; counts 4 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
ROWS = 4  # 512 records per grid step
BLK = LANES * ROWS


def _kernel(dest_ref, valid_ref, slot_ref, counts_ref, *, num_parts: int):
    dest = dest_ref[...].reshape(BLK)
    valid = valid_ref[...].reshape(BLK).astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    part_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, num_parts), 1)
    onehot = (dest[:, None] == part_iota).astype(jnp.float32) * valid[:, None]

    # exclusive prefix inside the block via strictly-lower-triangular matmul
    r = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 1)
    tri = (c < r).astype(jnp.float32)  # strictly lower triangular
    prefix = jax.lax.dot_general(
        tri, onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [BLK, N] — # of earlier same-dest records in this block

    running = counts_ref[...]  # [1, N] running counts from earlier blocks
    base = jnp.sum(onehot * running, axis=1)  # running[dest[i]]
    rank = jnp.sum(onehot * prefix, axis=1)
    slot = (base + rank).astype(jnp.int32)
    slot = jnp.where(valid > 0, slot, -1)
    slot_ref[...] = slot.reshape(ROWS, LANES)
    counts_ref[...] = running + jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("num_parts", "interpret"))
def dispatch_count(
    dest: jax.Array,  # int32[n] destination partition per record
    valid: jax.Array,  # bool[n]
    *,
    num_parts: int,
    interpret: bool = True,
):
    """Returns (slot int32[n]  — rank within destination, -1 for invalid;
                counts int32[num_parts])."""
    n = dest.shape[0]
    assert n % BLK == 0, f"pad records to a multiple of {BLK}"
    dest2d = dest.reshape(n // LANES, LANES)
    valid2d = valid.astype(jnp.int32).reshape(n // LANES, LANES)

    slot, counts = pl.pallas_call(
        functools.partial(_kernel, num_parts=num_parts),
        grid=(n // BLK,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, num_parts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((1, num_parts), jnp.float32),
        ],
        interpret=interpret,
    )(dest2d, valid2d)
    return slot.reshape(n), counts[0].astype(jnp.int32)
