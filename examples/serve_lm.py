"""Serve a small model with batched requests + DR session routing.

Requests carry session keys (hot tenants appear); the DRScheduler routes
sessions to replicas with KIP and migrates sessions (KV caches) at
checkpoints when tenants heat up.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

if __name__ == "__main__":
    args = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma-2b",
        "--requests", "12",
        "--max-new", "6",
        "--slots", "3",
        "--replicas", "3",
    ] + sys.argv[1:]
    raise SystemExit(subprocess.call(args))
