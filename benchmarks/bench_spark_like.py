"""Fig. 4 — load imbalance + total processing time for 10M-record ZIPF jobs
as a function of the Zipf exponent, DR on vs. off (35 partitions).

Reproduces the paper's finding: DR helps at moderate exponents; at ~1 the
distribution is barely skewed, at large exponents the single heaviest key
dominates and no partitioner can help."""
from __future__ import annotations

import numpy as np

from benchmarks.common import stage_time
from repro.core import Histogram, kip_update, load_imbalance, uniform_partitioner
from repro.data.generators import zipf_keys

N_PARTS = 35
WORKERS = 35
# Regime note: the paper sweeps exponents 1..2 over 1M keys; with our 100K
# key universe the heaviest key's mass f1 crosses 1/N around exponent ~1.0,
# so the same three regimes (no skew / moderate: DR wins / single-key
# dominated: nothing helps) appear shifted to [0.6, 2.0].
EXPONENTS = [0.6, 0.8, 1.0, 1.2, 1.6, 2.0]


SMOKE = dict(n_records=50_000, num_keys=10_000)  # CI bench-smoke profile


def run(n_records: int = 500_000, num_keys: int = 100_000):
    rows = []
    speedups = {}
    for exp in EXPONENTS:
        keys = zipf_keys(n_records, num_keys=num_keys, exponent=exp, seed=int(exp * 10))
        uhp = uniform_partitioner(N_PARTS)
        hist = Histogram.exact(keys[: n_records // 10]).top(4 * N_PARTS)  # 10% sample
        kip = kip_update(uhp, hist, eps=0.003)
        t_hash = stage_time(uhp, keys, workers=WORKERS)
        t_dr = stage_time(kip, keys, workers=WORKERS)
        speedups[exp] = t_hash / t_dr
        rows.append((f"fig4/imbalance_hash/exp={exp}", load_imbalance(uhp, keys), ""))
        rows.append((f"fig4/imbalance_dr/exp={exp}", load_imbalance(kip, keys), ""))
        rows.append((f"fig4/speedup/exp={exp}", speedups[exp], "stage-time model"))
    # DR is most beneficial at moderate skew (paper Fig. 4): the peak sits
    # strictly inside the sweep, not at either end
    peak = max(speedups, key=speedups.get)
    # paper-property gates need realistic N: below it the per-partition
    # scheduling overhead drowns the skew signal (smoke runs skip them)
    if n_records >= 500_000:
        assert peak not in (EXPONENTS[0], EXPONENTS[-1]), speedups
        assert speedups[peak] > 1.2, speedups
    rows.append(("fig4/peak_speedup", speedups[peak],
                 f"at exp={peak}; paper: 1.5-2.0 at moderate exponents"))
    return rows
