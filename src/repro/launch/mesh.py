"""Production mesh builders (functions, never module-level constants:
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) over ("data", "model").
    Multi-pod: 2 pods = 512 chips (2, 16, 16) over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    return n
