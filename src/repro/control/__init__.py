"""The system-aware control plane: one telemetry-driven policy stack.

The paper's core claim is *system-aware* repartitioning — decisions driven
by observed system signals, with migration cost weighed against balance
gain.  This package is where every such decision lives:

* :mod:`repro.control.signals` — the :class:`Signals` record every consumer
  (streaming job, serving scheduler, MoE placement loop) emits at safe
  points, and the :class:`Telemetry` accumulator that builds it during
  normal work.
* :mod:`repro.control.actions` — the typed decisions a policy can return:
  :class:`NoOp`, :class:`Repartition`, :class:`Resize`, :class:`Replace`,
  :class:`SwitchBackend`, :class:`Split`, :class:`Unsplit`.
* :mod:`repro.control.policy` — composable policy objects
  (:class:`RepartitionPolicy`, :class:`ResizePolicy`,
  :class:`PlacementPolicy`, :class:`BackendPolicy`, :class:`SplitPolicy`)
  sharing one exchange-lane cost model and one :class:`CooldownGuard`
  hysteresis rule.
* :mod:`repro.control.health` — the failure-domain layer: per-lane
  :class:`LaneHealth` (EWMA straggle + failure streaks) and the
  :class:`HealthPolicy` emitting :class:`Quarantine` / :class:`Evict` /
  :class:`Recover` — first in the evaluate precedence, because a sick lane
  invalidates every load-based signal downstream.
* :mod:`repro.control.log` — the :class:`DecisionLog` recording every
  decision, including declined ones, with reasons.

``repro.core.drm.DRMaster`` hosts the stack; the runtimes are thin drivers
that feed signals in and execute the returned actions.
"""
from repro.control.actions import (
    Action,
    Evict,
    NoOp,
    Quarantine,
    Recover,
    Repartition,
    Replace,
    Resize,
    Split,
    SwitchBackend,
    Unsplit,
)
from repro.control.health import HealthPolicy, LaneHealth
from repro.control.log import Decision, DecisionLog
from repro.control.policy import (
    BackendPolicy,
    CooldownGuard,
    PlacementPolicy,
    RepartitionPolicy,
    ResizePolicy,
    SplitPolicy,
)
from repro.control.signals import Signals, Telemetry

__all__ = [
    "Action",
    "BackendPolicy",
    "CooldownGuard",
    "Decision",
    "DecisionLog",
    "Evict",
    "HealthPolicy",
    "LaneHealth",
    "NoOp",
    "PlacementPolicy",
    "Quarantine",
    "Recover",
    "Repartition",
    "RepartitionPolicy",
    "Replace",
    "Resize",
    "ResizePolicy",
    "Signals",
    "Split",
    "SplitPolicy",
    "SwitchBackend",
    "Telemetry",
    "Unsplit",
]
