"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, cells_for, reduce_for_smoke

_MODULES = {
    "whisper-base": "repro.configs.whisper_base",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair that must pass the dry-run."""
    return [(a, s) for a in ARCH_IDS for s in cells_for(get_config(a))]
