"""Multi-device shuffle/migration correctness on 8 XLA host devices.

Runs in a subprocess because device count must be fixed before jax init
(the main test process keeps the default 1 CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8

    from repro.core import Histogram, kip_update, uniform_partitioner
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    job = StreamingJob(
        mesh=mesh, num_partitions=8, state_capacity=4096,
        dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1),
    )
    batches = list(drifting_zipf(5, 8192, num_keys=2000, exponent=1.3,
                                 drift_every=100, seed=0))
    ms = job.run(batches)

    # 1. exact stateful aggregation across a real 8-way all_to_all
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:10]:
        got = job.state_count(int(key))
        want = float((all_keys == key).sum())
        assert got == want, (key, got, want)

    # 2. DR fired and improved balance on the skewed stream
    assert any(m.repartitioned for m in ms), [m.reason for m in ms]
    assert ms[-1].imbalance < ms[0].imbalance

    # 3. each worker shard holds only keys the partitioner maps to it
    sk = np.asarray(job.state_keys)
    part = job.drm.partitioner
    for w in range(8):
        keys_w = sk[w][sk[w] != 2**31 - 1]
        if len(keys_w):
            assert np.all(part.lookup_np(keys_w.astype(np.int32)) % 8 == w)

    print("DISTRIBUTED-OK")
    """
)


@pytest.mark.slow
def test_shuffle_and_dr_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "DISTRIBUTED-OK" in out.stdout, out.stdout + "\n" + out.stderr


RESIZE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.hashing import KEY_SENTINEL
    from repro.core.streaming import StreamingJob
    from repro.data.generators import zipf_keys

    mesh = jax.make_mesh((4,), ("data",))
    job = StreamingJob(mesh=mesh, num_partitions=4, state_capacity=4096,
                       dr=DRConfig(imbalance_trigger=1e9))
    batches = [zipf_keys(8192, num_keys=1000, exponent=1.4, seed=s) for s in range(5)]
    job.process_batch(batches[0]); job.process_batch(batches[1])

    # grow 4->8 across a real 4-way all_to_all: state must physically move
    job.resize(8)
    m = job.process_batch(batches[2])
    assert m.resized and m.reason == "resize 4->8", m.reason
    assert m.overflow == 0, m.overflow
    assert m.relative_migration > 0  # cross-worker shipping actually happened
    assert m.migration_rows <= 4 * max(8, 2 * m.migration_plan_rows)

    job.resize(4)
    m = job.process_batch(batches[3])
    assert m.resized and m.reason == "resize 8->4", m.reason
    assert m.overflow == 0, m.overflow
    job.process_batch(batches[4])

    # exact per-key counts across both resizes
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:10]:
        got, want = job.state_count(int(key)), float((all_keys == key).sum())
        assert got == want, (key, got, want)

    # each worker shard holds only keys the resized partitioner maps to it
    sk = np.asarray(job.state_keys)
    part = job.drm.partitioner
    for w in range(4):
        keys_w = sk[w][sk[w] != KEY_SENTINEL]
        if len(keys_w):
            assert np.all(part.lookup_np(keys_w.astype(np.int32)) % 4 == w)

    print("RESIZE-DISTRIBUTED-OK")
    """
)


@pytest.mark.slow
def test_elastic_resize_on_4_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", RESIZE_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "RESIZE-DISTRIBUTED-OK" in out.stdout, out.stdout + "\n" + out.stderr


BACKEND_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    batches = list(drifting_zipf(5, 8192, num_keys=2000, exponent=1.5,
                                 drift_every=2, drift_fraction=0.4, seed=3))
    # three transports: dense, ragged (native ragged_all_to_all on
    # jax >= 0.5, masked dense on 0.4.x), and ragged with the native
    # collective force-disabled — on jax >= 0.5 that makes the run a real
    # native-vs-fallback bit-identity check across an 8-way all_to_all
    jobs = {}
    for be, force_fallback in (("dense", False), ("ragged", False),
                               ("ragged_fallback", True)):
        if force_fallback:
            os.environ["REPRO_DISABLE_NATIVE_RAGGED"] = "1"
        else:
            os.environ.pop("REPRO_DISABLE_NATIVE_RAGGED", None)
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=4096,
            dr=DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0),
            exchange_backend=be.split("_")[0],
        )
        jobs[be] = (job, job.run(batches))
    os.environ.pop("REPRO_DISABLE_NATIVE_RAGGED", None)

    # 1. backend equivalence across a real 8-way all_to_all: bit-identical
    #    keyed state (exact aggregation) and identical overflow accounting,
    #    native ragged path included
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:32]:
        got = {be: job.state_count(int(key)) for be, (job, _) in jobs.items()}
        want = float((all_keys == key).sum())
        assert all(g == want for g in got.values()), (key, got, want)
    ov = {be: [m.overflow for m in ms] for be, (_, ms) in jobs.items()}
    assert ov["dense"] == ov["ragged"] == ov["ragged_fallback"], ov

    # 2. all backends repartitioned identically (same decisions, the
    #    transport must not change the control plane's view of the stream)
    acts = {be: [m.action for m in ms] for be, (_, ms) in jobs.items()}
    assert acts["dense"] == acts["ragged"] == acts["ragged_fallback"], acts
    assert any(m.repartitioned for m in jobs["dense"][1])

    # 3. the ragged transport moved strictly fewer rows than the dense pad,
    #    and the native path reports exactly the fallback's accounting
    shipped = {be: sum(m.shipped_rows for m in ms) for be, (_, ms) in jobs.items()}
    padded = {be: sum(m.padded_rows for m in ms) for be, (_, ms) in jobs.items()}
    assert shipped["dense"] == padded["dense"], (shipped, padded)
    assert shipped["ragged"] < padded["ragged"], (shipped, padded)
    assert shipped["ragged"] == shipped["ragged_fallback"], shipped
    print("BACKEND-EQUIVALENCE-OK", shipped, padded)
    """
)


@pytest.mark.slow
def test_backend_equivalence_on_8_devices():
    """Dense vs ragged on 8 real shards: bit-identical state, fewer rows."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", BACKEND_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "BACKEND-EQUIVALENCE-OK" in out.stdout, out.stdout + "\n" + out.stderr


OVERLAP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    batches = list(drifting_zipf(6, 8192, num_keys=2000, exponent=1.4,
                                 drift_every=2, drift_fraction=0.4, seed=7))
    # the same skewed stream through the serial driver and the split-phase
    # overlapped driver, across a real 8-way all_to_all
    jobs = {}
    for mode, overlap in (("serial", False), ("overlap", True)):
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=4096,
            dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1,
                        overlap_exchange=overlap),
        )
        jobs[mode] = (job, job.run(batches))
    (job_s, ms_s), (job_o, ms_o) = jobs["serial"], jobs["overlap"]
    assert not any(m.overlapped for m in ms_s)
    assert all(m.overlapped for m in ms_o)

    # 1. identical trajectories: same decisions, same accounting
    traj = lambda ms: [(m.action, m.reason, m.repartitioned, m.overflow,
                        m.shipped_rows, round(m.imbalance, 9)) for m in ms]
    assert traj(ms_s) == traj(ms_o), (traj(ms_s), traj(ms_o))
    assert any(m.repartitioned for m in ms_o)  # migrations ran in-flight

    # 2. bit-identical keyed state after draining the pipeline
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:32]:
        got = job_o.state_count(int(key))
        want = float((all_keys == key).sum())
        assert got == want == job_s.state_count(int(key)), (key, got, want)

    # 3. the hidden phase was actually measured on the overlapped run
    assert job_o.telemetry.wall_ewma.get("dense", 0.0) > 0.0
    print("OVERLAP-OK")
    """
)


@pytest.mark.slow
def test_overlap_matches_serial_on_8_devices():
    """Split-phase overlapped driver vs serial on 8 real shards."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", OVERLAP_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "OVERLAP-OK" in out.stdout, out.stdout + "\n" + out.stderr


DEPTH2_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro import compat
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    batches = list(drifting_zipf(6, 8192, num_keys=2000, exponent=1.4,
                                 drift_every=2, drift_fraction=0.4, seed=7))
    # the same skewed stream through the serial driver and the depth-2
    # batch-ahead pipeline, across a real 8-way all_to_all
    jobs = {}
    for mode, (overlap, depth) in (("serial", (False, 1)),
                                   ("depth2", (True, 2))):
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=4096,
            dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1,
                        overlap_exchange=overlap, pipeline_depth=depth),
        )
        jobs[mode] = (job, job.run(batches))
    (job_s, ms_s), (job_2, ms_2) = jobs["serial"], jobs["depth2"]
    assert all(m.overlapped for m in ms_2)
    assert any(m.pipelined for m in ms_2)  # the lookahead actually staged
    assert not any(m.pipelined for m in ms_s)

    # 1. identical trajectories: same decisions, same accounting
    traj = lambda ms: [(m.action, m.reason, m.repartitioned, m.overflow,
                        m.shipped_rows, round(m.imbalance, 9)) for m in ms]
    assert traj(ms_s) == traj(ms_2), (traj(ms_s), traj(ms_2))
    assert any(m.repartitioned for m in ms_2)  # drains fired mid-pipeline

    # 2. bit-identical keyed state after draining both in-flight stages
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:32]:
        got = job_2.state_count(int(key))
        want = float((all_keys == key).sum())
        assert got == want == job_s.state_count(int(key)), (key, got, want)

    # 3. steady state is sync-free on real shards too: noop batches after
    #    the pipeline refills perform zero audited host transfers
    calm = StreamingJob(mesh=mesh, num_partitions=8, state_capacity=4096,
                        dr=DRConfig(imbalance_trigger=1e9, pipeline_depth=2))
    calm.run(batches[:2])  # warmup: compile + fill the pipeline
    compat.reset_host_sync_count()
    ms_c = calm.run(batches[2:])
    assert compat.host_sync_count() == 0, compat.host_sync_count()
    assert all(m.pipelined for m in ms_c[1:])
    print("DEPTH2-OK")
    """
)


@pytest.mark.slow
def test_depth2_pipeline_on_8_devices():
    """Depth-2 batch-ahead pipeline vs serial on 8 real shards."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", DEPTH2_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "DEPTH2-OK" in out.stdout, out.stdout + "\n" + out.stderr


HIERARCHICAL_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf
    from repro.exchange import ExchangeSpec, ExchangeTopology, Payload, make_exchange
    from repro.exchange.backends import _two_hop_a2a

    mesh = jax.make_mesh((8,), ("data",))

    # 0. the collective itself: the two-tier (intra-host, then inter-host)
    #    all_to_all must equal the flat tiled all_to_all bit for bit, and be
    #    its own inverse (the backhaul reuses the forward permutation)
    x = jnp.arange(8 * 8 * 4, dtype=jnp.int32).reshape(8, 8, 4)
    def body(x):
        flat = jax.lax.all_to_all(x[0], "data", 0, 0, tiled=True)
        two = _two_hop_a2a(x[0], "data", num_hosts=2, lanes_per_host=4)
        back = _two_hop_a2a(two, "data", num_hosts=2, lanes_per_host=4)
        return flat[None], two[None], back[None]
    flat, two, back = shard_map(
        body, mesh=mesh, in_specs=(P("data"),),
        out_specs=(P("data"), P("data"), P("data")), check_vma=False,
    )(x)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(two))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    # two hosts x four lanes: lanes 0-3 on host 0, lanes 4-7 on host 1
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    batches = list(drifting_zipf(5, 8192, num_keys=2000, exponent=1.5,
                                 drift_every=2, drift_fraction=0.4, seed=3))
    jobs = {}
    for name, kw in (
        ("flat", dict(exchange_backend="dense")),
        ("dense", dict(exchange_backend="dense", topology=topo)),
        ("hier", dict(exchange_backend="hierarchical", topology=topo)),
    ):
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=4096,
            dr=DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0),
            **kw,
        )
        jobs[name] = (job, job.run(batches))

    # 1. bit-identity across a real two-tier exchange: exact aggregation,
    #    identical overflow, identical control-plane decisions
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:32]:
        got = {n: job.state_count(int(key)) for n, (job, _) in jobs.items()}
        want = float((all_keys == key).sum())
        assert all(g == want for g in got.values()), (key, got, want)
    ov = {n: [m.overflow for m in ms] for n, (_, ms) in jobs.items()}
    assert ov["flat"] == ov["dense"] == ov["hier"], ov
    acts = {n: [m.action for m in ms] for n, (_, ms) in jobs.items()}
    assert acts["flat"] == acts["dense"] == acts["hier"], acts
    assert any(m.repartitioned for m in jobs["flat"][1])

    # 2. per-class accounting: the flat job reports no classes; the
    #    topology jobs' classes sum to the scalar; hierarchical ships
    #    strictly fewer inter-host rows than the flat dense pad
    assert all(m.shipped_rows_by_class == (0, 0, 0) for m in jobs["flat"][1])
    by = {n: np.sum([m.shipped_rows_by_class for m in ms], axis=0)
          for n, (_, ms) in jobs.items() if n != "flat"}
    tot = {n: sum(m.shipped_rows for m in ms) for n, (_, ms) in jobs.items()}
    for n in ("dense", "hier"):
        assert by[n].sum() == tot[n], (n, by[n], tot[n])
    assert by["hier"][2] < by["dense"][2], by
    assert by["hier"][2] > 0, by  # rows did cross the host boundary
    assert jobs["hier"][0].telemetry.snapshot(
        loads=np.ones(8)).inter_host_fraction < 0.5

    print("HIERARCHICAL-OK", dict(tot), {n: v.tolist() for n, v in by.items()})
    """
)


@pytest.mark.slow
def test_hierarchical_backend_on_8_devices():
    """Two-tier exchange on 8 real shards (2 hosts x 4 lanes): bit-identical
    state + overflow, strictly fewer inter-host rows than flat dense."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", HIERARCHICAL_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "HIERARCHICAL-OK" in out.stdout, out.stdout + "\n" + out.stderr


MOE_BACKHAUL_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.base import MoESpec
    from repro.models.modules import Policy
    from repro.moe.layer import init_moe, moe_ref, moe_apply
    from repro.compat import set_mesh

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MoESpec(num_experts=8, top_k=2, d_ff_expert=32, shared_expert=False,
                   capacity_factor=8.0)  # generous: nothing drops
    d = 16
    p = init_moe(jax.random.PRNGKey(0), d, spec, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))
    inv = jnp.arange(8, dtype=jnp.int32)
    want = moe_ref(p, x, spec, "swiglu", Policy(), inv)

    got = {}
    for be in ("dense", "ragged"):
        pol = Policy(mesh=mesh, dp_axes=("data",), tp_axis="model",
                     exchange_backend=be)
        with set_mesh(mesh):
            xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
            ps = dict(jax.device_put(p, NamedSharding(mesh, P())))
            ps["wi"] = jax.device_put(p["wi"], NamedSharding(mesh, P("model")))
            ps["wo"] = jax.device_put(p["wo"], NamedSharding(mesh, P("model")))
            got[be] = jax.jit(
                lambda pp, xx, pol=pol: moe_apply(pp, xx, spec, "swiglu", pol, inv)
            )(ps, xs)

    # bit-identity across a real 4-way dispatch + backhaul: the ragged
    # combine (count-reusing return trip, native collective on jax >= 0.5)
    # must match the dense pad exactly, and both match the oracle
    np.testing.assert_array_equal(np.asarray(got["dense"].y),
                                  np.asarray(got["ragged"].y))
    np.testing.assert_allclose(np.asarray(got["dense"].y), np.asarray(want.y),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(got["dense"].counts),
                                  np.asarray(got["ragged"].counts))
    assert float(got["dense"].overflow) == float(got["ragged"].overflow) == 0.0
    # both directions measured: ragged < the dense round-trip pad
    sd, sr = int(got["dense"].shipped_rows), int(got["ragged"].shipped_rows)
    assert 0 < sr < sd, (sr, sd)
    print("MOE-BACKHAUL-OK", sr, sd)
    """
)


@pytest.mark.slow
def test_moe_ragged_backhaul_on_8_devices():
    """MoE dispatch + ragged combine backhaul vs dense on real shards."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", MOE_BACKHAUL_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "MOE-BACKHAUL-OK" in out.stdout, out.stdout + "\n" + out.stderr


FAULT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    assert len(jax.devices()) == 8

    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf
    from repro.exchange import FaultPlan, FaultyBackend, LaneFault

    batches = list(drifting_zipf(8, 8192, num_keys=2000, exponent=1.3,
                                 drift_every=100, seed=0))
    all_keys = np.concatenate(batches)
    probe = np.unique(all_keys)[:10]

    def run(dr, backend=None):
        mesh = jax.make_mesh((8,), ("data",))
        kw = {"exchange_backend": backend} if backend is not None else {}
        job = StreamingJob(mesh=mesh, num_partitions=8, state_capacity=4096,
                           dr=dr, **kw)
        ms = job.run(batches)
        return job, ms

    def traj(ms):
        return [(m.action, m.reason, m.overflow, m.shipped_rows) for m in ms]

    # 1. never-firing identity, serial AND depth-2: an installed FaultPlan
    #    that never fires is bit-identical to no seam at all
    for depth in (1, 2):
        dr = lambda: DRConfig(imbalance_trigger=1.1,
                              migration_cost_weight=0.1,
                              pipeline_depth=depth)
        ref_job, ref_ms = run(dr())
        seam_job, seam_ms = run(dr(), FaultyBackend("dense", FaultPlan()))
        assert traj(ref_ms) == traj(seam_ms), (depth, traj(ref_ms),
                                               traj(seam_ms))
        for key in probe:
            assert ref_job.state_count(int(key)) == \\
                seam_job.state_count(int(key)), (depth, key)

    # 2. kill a worker mid-stream: recover via restore + replay onto the
    #    shrunk topology with zero rows lost
    ref_job, _ = run(DRConfig(imbalance_trigger=1e9))
    plan = FaultPlan(faults=(LaneFault(4, 5, "kill"),))
    job, ms = run(DRConfig(imbalance_trigger=1e9, snapshot_interval=3),
                  FaultyBackend("dense", plan))
    assert len(job.recoveries) == 1, job.recoveries
    rec = job.recoveries[0]
    assert rec.kind == "evict" and rec.lane == 5, rec
    assert job.num_workers == 7
    assert ms[-1].lanes == 7
    for key in probe:
        got = job.state_count(int(key))
        want = float((all_keys == key).sum())
        assert got == want, (key, got, want)
    # survivors hold only keys the partitioner folds onto them
    sk = np.asarray(job.state_keys)
    part = job.drm.partitioner
    for w in range(7):
        keys_w = sk[w][sk[w] != 2**31 - 1]
        if len(keys_w):
            assert np.all(part.lookup_np(keys_w.astype(np.int32)) % 7 == w)

    print("FAULTS-OK")
    """
)


@pytest.mark.slow
@pytest.mark.chaos
def test_fault_recovery_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", FAULT_SCRIPT], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert "FAULTS-OK" in out.stdout, out.stdout + "\n" + out.stderr
