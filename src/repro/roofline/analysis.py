"""Roofline terms from the compiled dry-run artifact.

``cost_analysis`` gives HLO FLOPs and HBM bytes; collective traffic is not
in there, so ``collective_bytes`` parses the (stable)HLO text and sums the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

    compute term    = HLO_FLOPs / (chips * peak FLOP/s)
    memory term     = HLO_bytes / (chips * HBM bw)
    collective term = collective_bytes / (chips * link bw)
"""
from __future__ import annotations

import re

import numpy as np

from repro.roofline.hw import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
    r"\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]"
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op, by kind.

    Shapes in the compiled module are *per-participant*, so the totals are
    per-device traffic volumes (what the ICI link actually carries, modulo
    algorithm factors: ring all-reduce moves ~2x, all-gather (n-1)/n x)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape = m.group(1) or m.group(2)
        kind = m.group(3)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape)
    return out


def roofline_terms(*, flops_dev: float, hbm_dev: float, hbm_dev_fused: float,
                   coll_dev: float) -> dict:
    """Three terms in seconds per step + dominant bottleneck.

    All inputs are PER-DEVICE quantities from the loop-aware HLO analysis.
    ``memory`` is reported as a [fused, unfused] range: the CPU-backend HLO
    fuses less than a TPU compile would, so the fused estimate is the one a
    TPU deployment tracks; bottleneck selection uses it."""
    compute = flops_dev / PEAK_FLOPS_BF16
    mem_lo = hbm_dev_fused / HBM_BW
    mem_hi = hbm_dev / HBM_BW
    collective = coll_dev / ICI_BW
    terms = {
        "compute_s": compute,
        "memory_s": mem_lo,
        "memory_s_upper": mem_hi,
        "collective_s": collective,
    }
    dom = {"compute": compute, "memory": mem_lo, "collective": collective}
    terms["bottleneck"] = max(dom, key=dom.get)
    total = max(dom.values())
    terms["roofline_fraction"] = compute / total if total > 0 else 0.0
    return terms
