"""Fig. 7/8 — web-crawl use case: fetch lists partitioned by host with a
heavy-tailed host distribution, and the NER streaming app (heavy per-record
processing, large keyed states).

The paper reduces crawl round 7 from 69.1 to 24.9 minutes (2.8x) and the
NER app by ~6x (heavy processing amplifies balance gains)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import stage_time
from repro.core import Histogram, kip_update, load_imbalance, uniform_partitioner
from repro.data.generators import host_skew_keys

WORKERS = 8


def _host_costs(keys: np.ndarray, seed: int, sigma: float) -> np.ndarray:
    """Per-record cost driven by the record's HOST: content-management tech
    (dynamic rendering, doc length) is a property of the site, so cost skew
    is keyed — exactly why host partitioning amplifies imbalance (§6)."""
    rng = np.random.default_rng(seed)
    uniq = np.unique(keys)
    mult = rng.lognormal(mean=0.0, sigma=sigma, size=len(uniq))
    lut = dict(zip(uniq.tolist(), mult.tolist()))
    return np.fromiter((lut[k] for k in keys.tolist()), np.float64, len(keys))


def _weighted_hist(keys: np.ndarray, cost: np.ndarray, top: int) -> Histogram:
    uniq, inv = np.unique(keys, return_inverse=True)
    w = np.zeros(len(uniq))
    np.add.at(w, inv, cost)
    return Histogram.from_counts(uniq, w).top(top)


SMOKE = dict(n_pages=20_000)  # CI bench-smoke profile


def run(n_pages: int = 200_000):
    rows = []
    # --- crawl rounds: host universe + dynamic-content skew grow per round
    speedups = []
    for rnd in range(1, 8):
        vals = []
        for seed in range(3):
            giant_mass = min(0.1 + 0.06 * rnd, 0.5)
            keys = host_skew_keys(n_pages, num_hosts=64 + 128 * rnd, giants=16,
                                  giant_mass=giant_mass, seed=7 * rnd + seed)
            cost = _host_costs(keys, seed=7 * rnd + seed, sigma=0.9)
            n = 3 * WORKERS
            uhp = uniform_partitioner(n)
            # DR measures work, not records: cost-weighted histogram (the
            # DRW sample observes per-record processing time)
            hist = _weighted_hist(keys[: n_pages // 5], cost[: n_pages // 5], 6 * n)
            kip = kip_update(uhp, hist, eps=0.003)
            t_hash = stage_time(uhp, keys, workers=WORKERS, record_cost=cost,
                                per_partition_overhead_us=500.0)
            t_dr = stage_time(kip, keys, workers=WORKERS, record_cost=cost,
                              per_partition_overhead_us=500.0)
            vals.append(t_hash / t_dr)
            if rnd == 7 and seed == 0:
                rows.append(("fig7/balance_hash/round=7", load_imbalance(uhp, keys), ""))
                rows.append(("fig7/balance_dr/round=7", load_imbalance(kip, keys), ""))
        speedups.append(float(np.mean(vals)))
        rows.append((f"fig8/crawl_speedup/round={rnd}", speedups[-1], "mean of 3 seeds"))
    rows.append(("fig8/mean_crawl_speedup", float(np.mean(speedups)),
                 "paper: 69.1 -> 24.9 min (2.8x) at round 7; qualitative — "
                 "absolute gain depends on executor scheduling specifics"))
    # paper-property gates need realistic N (smoke runs skip them)
    if n_pages >= 200_000:
        assert np.mean(speedups) > 1.08, speedups
        assert max(speedups) > 1.2, speedups

    # --- NER app: streaming (pinned operators), heavy host-keyed records.
    # The paper reports ~6x; a linear straggler model reproduces the
    # direction and the all-partition-configs consistency, not the
    # magnitude (their gain also includes GC/memory pressure on the huge
    # windowed states, which we do not model) — noted in EXPERIMENTS.md.
    keys = host_skew_keys(40_000, num_hosts=1024, giants=64, giant_mass=0.5, seed=42)
    cost = _host_costs(keys, seed=5, sigma=0.8)  # NLP cost ~ doc length, per domain
    ner = []
    for parts_per_worker in [1, 2, 4]:
        n = parts_per_worker * 6
        uhp = uniform_partitioner(n)
        kip = kip_update(uhp, _weighted_hist(keys[:8000], cost[:8000], 6 * n), eps=0.003)
        t_hash = stage_time(uhp, keys, workers=6, record_cost=cost,
                            per_partition_overhead_us=500.0, pinned=True)
        t_dr = stage_time(kip, keys, workers=6, record_cost=cost,
                          per_partition_overhead_us=500.0, pinned=True)
        ner.append(t_hash / t_dr)
        rows.append((f"fig8/ner_speedup/parts={n}", t_hash / t_dr,
                     "paper: ~6x for all partition configs (streaming, pinned state)"))
    assert all(s > 1.05 for s in ner), ner
    assert max(ner) > 1.25, ner
    return rows
