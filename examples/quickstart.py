"""Quickstart: Dynamic Repartitioning in 30 lines.

A skewed key stream is shuffled across workers with the default uniform
hash partitioner; DR observes the histogram during normal work, swaps in a
KIP at the micro-batch boundary, and imbalance drops while the stateful
counts stay exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf

job = StreamingJob(
    num_partitions=8,
    state_capacity=16_384,
    dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2),
)

batches = list(drifting_zipf(8, 16_384, num_keys=5_000, exponent=1.3,
                             drift_every=100, seed=0))
print(f"{'batch':>5} {'imbalance':>10} {'repartition?':>13} {'migrated':>9}")
for m in job.run(batches):
    print(f"{m.batch:>5} {m.imbalance:>10.3f} {str(m.repartitioned):>13} "
          f"{m.relative_migration:>9.3f}")

# stateful counts survived every partitioner swap exactly
all_keys = np.concatenate(batches)
key = int(np.unique(all_keys)[0])
got, want = job.state_count(key), float((all_keys == key).sum())
assert got == want, (got, want)
print(f"\nexact stateful count for key {key}: {got:.0f} == {want:.0f}  OK")
print(f"heavy keys isolated: {job.drm.partitioner.num_heavy}")
