"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived,backend,rows_self,rows_intra,rows_inter``
CSV rows (value column is the figure's metric: imbalance ratio / speedup /
us, per the row name; the backend column tags rows measured under a
specific exchange transport — ``-`` for backend-independent rows; the three
trailing per-distance-class columns split a row's exchanged rows by lane
locality — self / intra-host / inter-host, blank for rows with no class
split).  Modules return 3-tuples ``(name, value, derived)``, 4-tuples
``(..., backend)``, or 5-tuples ``(..., backend, (self, intra, inter))``.

    python -m benchmarks.run [only] [--smoke] [--out bench.csv]

``only`` filters modules by substring.  ``--smoke`` runs each module's
small-N profile (its module-level ``SMOKE`` kwargs) — the CI gate profile;
the streaming + migration modules sweep the dense *and* ragged exchange
backends and raise (nonzero exit) on any exact-count mismatch between them.
``--out`` additionally writes the CSV rows to a file (CI artifact).

A module that raises prints a ``<name>/FAILED`` row *and* makes the process
exit nonzero, so failures gate CI instead of hiding in the CSV.
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    "bench_partitioners",   # Fig 2
    "bench_migration",      # Fig 3
    "bench_spark_like",     # Fig 4
    "bench_overpartition",  # Fig 5
    "bench_streaming",      # Fig 6
    "bench_webcrawl",       # Fig 7/8
    "bench_sketches",       # §4 + extended paper
    "bench_moe",            # beyond paper: KIP expert placement
    "bench_kernels",        # Pallas hot paths
]


def main(argv: list[str] | None = None) -> int:
    import importlib

    ap = argparse.ArgumentParser(description="paper benchmark harness")
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on module names")
    ap.add_argument("--smoke", action="store_true",
                    help="small-N profile (each module's SMOKE kwargs)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV rows to this file")
    args = ap.parse_args(argv)

    lines: list[str] = []

    def emit(line: str) -> None:
        lines.append(line)
        print(line)

    emit("name,us_per_call,derived,backend,rows_self,rows_intra,rows_inter")
    failures: list[tuple[str, BaseException]] = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kwargs = getattr(mod, "SMOKE", {}) if args.smoke else {}
            rows = mod.run(**kwargs)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            emit(f"{name}/FAILED,0,{type(e).__name__}: {e},-,,,")
            continue
        for row in rows:
            row_name, value, derived = row[:3]
            backend = row[3] if len(row) > 3 else "-"
            by_class = row[4] if len(row) > 4 else ("", "", "")
            cls = ",".join(str(c) for c in by_class)
            emit(f"{row_name},{value:.6g},{derived},{backend},{cls}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
    if failures:
        for name, e in failures:
            print(f"FAILED {name}: {type(e).__name__}: {e}", file=sys.stderr)
        print(f"{len(failures)} benchmark module(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
