"""Deterministic fault injection for the exchange plane.

A :class:`FaultPlan` is a seeded, serializable schedule of per-lane faults —
added latency (stragglers), transient exchange failures with bounded
retry + backoff, and hard worker loss — and :class:`FaultyBackend` is the
seam that installs it: a delegating :class:`~repro.exchange.backends
.ExchangeBackend` wrapper, so every transport (dense / ragged / local /
hierarchical) can be exercised under faults without touching a single
consumer call site (``resolve_backend`` passes instances through, so the
wrapper flows wherever a backend name would).

**Where faults fire.**  The wrapped verbs (``bucketize`` / ``a2a_start`` /
...) run at *trace* time inside the jitted steps — raising or sleeping
there would bake the fault into the compiled program.  Faults therefore
fire at the *host* boundary: the driver-level step wrappers in
:mod:`repro.core.shuffle` call :func:`maybe_inject` once per issued
exchange start, and the wrapper's :meth:`FaultyBackend.inject` consults the
plan for that tick.  Because the traced verbs delegate to the inner
backend verbatim, an installed-but-never-firing plan is bit-identical to
no plan at all — serial, depth-1 and depth-2 drivers alike — by
construction, not by luck.

**Determinism.**  Ticks count host-issued exchange starts (one per shuffle
or migrate start; the serial fused step counts as its start).  For a fixed
config and input stream the start sequence is a pure function of the
decision trajectory, so the same plan (same seed) reproduces the same
faults at the same points — the chaos tests' seed-determinism contract.

**Lane identity.**  Plan lanes are *original* lane ids (the mesh positions
at job construction).  Quarantine/evict/recover renumber the live lanes;
the driver keeps the current -> original map and the wrapper keeps an
``inactive`` set so faults scheduled for a removed lane never fire while
it is out of the collective (and resume if it is recovered).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.exchange.backends import resolve_backend

__all__ = [
    "FaultPlan",
    "FaultyBackend",
    "LaneFault",
    "TransientExchangeError",
    "WorkerLostError",
    "maybe_inject",
]

FAULT_KINDS = ("latency", "transient", "kill")


class TransientExchangeError(RuntimeError):
    """One failed exchange attempt on a lane — retryable."""

    def __init__(self, lane: int, tick: int, attempt: int):
        super().__init__(f"transient exchange failure on lane {lane} "
                         f"(tick {tick}, attempt {attempt})")
        self.lane = int(lane)
        self.tick = int(tick)
        self.attempt = int(attempt)


class WorkerLostError(RuntimeError):
    """A lane is gone for good: hard loss, or transient failures past the
    retry budget.  The driver's recovery protocol catches this."""

    def __init__(self, lane: int, tick: int, cause: str = "killed"):
        super().__init__(f"worker lost on lane {lane} (tick {tick}: {cause})")
        self.lane = int(lane)
        self.tick = int(tick)
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class LaneFault:
    """One scheduled fault: at exchange ``tick`` on (original) ``lane``.

    * ``latency`` — sleep ``delay_s`` per exchange for ``span`` consecutive
      ticks (a straggling lane).
    * ``transient`` — the exchange fails ``failures`` times before
      succeeding; each failed attempt costs one retry with exponential
      backoff.  ``failures`` beyond the plan's ``max_retries`` escalates to
      :class:`WorkerLostError`.
    * ``kill`` — hard worker loss; the lane fails every exchange until the
      driver evicts it (:meth:`FaultyBackend.note_evicted`).
    """

    tick: int
    lane: int
    kind: str
    delay_s: float = 0.0
    failures: int = 1
    span: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {FAULT_KINDS}")
        if self.tick < 0 or self.lane < 0:
            raise ValueError(f"fault tick/lane must be >= 0, got "
                             f"({self.tick}, {self.lane})")
        if self.delay_s < 0.0 or self.failures < 1 or self.span < 1:
            raise ValueError(f"degenerate fault parameters: {self!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, serializable schedule of :class:`LaneFault` records.

    ``max_retries`` bounds the per-fault retry loop (a transient fault with
    more failures than retries escalates to worker loss); ``backoff_s`` is
    the base of the exponential retry backoff (0.0 = retry immediately —
    the test-friendly default).  An empty plan never fires.
    """

    faults: tuple = ()
    max_retries: int = 2
    backoff_s: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0 or self.backoff_s < 0.0:
            raise ValueError("max_retries and backoff_s must be >= 0, got "
                             f"({self.max_retries}, {self.backoff_s})")
        object.__setattr__(self, "faults", tuple(self.faults))

    @property
    def never_fires(self) -> bool:
        return not self.faults

    @classmethod
    def generate(cls, seed: int, *, num_lanes: int, ticks: int,
                 latency_rate: float = 0.05, transient_rate: float = 0.02,
                 delay_s: float = 0.005, kill_at: tuple | None = None,
                 max_retries: int = 2, backoff_s: float = 0.0) -> "FaultPlan":
        """Deterministic schedule from one seed: per (tick, lane) cell draw
        a latency fault with ``latency_rate`` and a transient fault with
        ``transient_rate``; ``kill_at`` optionally adds one hard loss as
        ``(tick, lane)``.  The same seed always yields the same plan."""
        rng = np.random.default_rng(seed)
        faults: list[LaneFault] = []
        for t in range(ticks):
            for lane in range(num_lanes):
                u = rng.random()
                if u < latency_rate:
                    faults.append(LaneFault(t, lane, "latency",
                                            delay_s=delay_s))
                elif u < latency_rate + transient_rate:
                    faults.append(LaneFault(
                        t, lane, "transient",
                        failures=int(rng.integers(1, max_retries + 1))))
        if kill_at is not None:
            kt, kl = kill_at
            faults.append(LaneFault(int(kt), int(kl), "kill"))
        return cls(faults=tuple(faults), max_retries=max_retries,
                   backoff_s=backoff_s, seed=seed)

    # -- serialization (plain JSON-ready dicts) ---------------------------
    def to_dict(self) -> dict:
        return {
            "max_retries": self.max_retries,
            "backoff_s": self.backoff_s,
            "seed": self.seed,
            "faults": [dataclasses.asdict(f) for f in self.faults],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(
            faults=tuple(LaneFault(**f) for f in d.get("faults", ())),
            max_retries=int(d.get("max_retries", 2)),
            backoff_s=float(d.get("backoff_s", 0.0)),
            seed=int(d.get("seed", 0)),
        )


class FaultyBackend:
    """Delegating exchange backend that injects a :class:`FaultPlan`.

    Every :class:`~repro.exchange.backends.ExchangeBackend` verb forwards
    to ``inner`` verbatim (the traced program is untouched); the host-side
    :meth:`inject` hook — called once per issued exchange start by the
    step wrappers via :func:`maybe_inject` — is where faults fire.

    ``drain_report`` hands the window's per-lane fault evidence (straggle
    seconds, retry counts) to the driver, which feeds it into
    :class:`~repro.control.signals.Telemetry` — the lane-health layer's
    input.  ``inner`` is deliberately mutable: a control-plane backend
    switch or a restore re-points the wrapped transport while the fault
    seam stays armed.
    """

    def __init__(self, inner, plan: FaultPlan | None = None):
        self.inner = resolve_backend(inner)
        self.plan = plan or FaultPlan()
        self._tick = 0
        self._dead: dict[int, int] = {}      # lane -> tick it died
        self._inactive: set[int] = set()     # evicted / quarantined lanes
        self._report: dict[int, dict] = {}   # lane -> window fault evidence
        # lifetime counters (test/bench observability)
        self.injected_sleep_s = 0.0
        self.transients = 0
        self.retries = 0
        self.kills = 0
        # point faults by tick; latency spans as (start, end, fault)
        self._at: dict[int, list[LaneFault]] = {}
        self._spans: list[tuple[int, int, LaneFault]] = []
        for f in self.plan.faults:
            if f.kind == "latency":
                self._spans.append((f.tick, f.tick + f.span, f))
            else:
                self._at.setdefault(f.tick, []).append(f)

    # -- identity forwards to the wrapped transport -----------------------
    @property
    def name(self) -> str:
        return self.inner.name

    def __getattr__(self, attr):
        # anything beyond the Protocol verbs (backend-specific attributes
        # the plane or pricing may probe) resolves on the inner transport
        return getattr(self.inner, attr)

    # -- ExchangeBackend verbs: verbatim delegation (trace-time) ----------
    def bucketize(self, spec, lane, valid, payloads, slot=None, counts=None,
                  buffers=None):
        return self.inner.bucketize(spec, lane, valid, payloads, slot=slot,
                                    counts=counts, buffers=buffers)

    def a2a_start(self, spec, buffers):
        return self.inner.a2a_start(spec, buffers)

    def a2a_finish(self, spec, buffers):
        return self.inner.a2a_finish(spec, buffers)

    def all_to_all(self, spec, buffers):
        return self.inner.all_to_all(spec, buffers)

    def backhaul(self, spec, buffers, *, send_counts=None, recv_counts=None):
        return self.inner.backhaul(spec, buffers, send_counts=send_counts,
                                   recv_counts=recv_counts)

    def cost(self, spec, plan_rows, slack: float = 1.25) -> float:
        return self.inner.cost(spec, plan_rows, slack=slack)

    # -- lane lifecycle (driver bookkeeping) ------------------------------
    def note_evicted(self, lane: int) -> None:
        """The driver removed ``lane`` for good: its faults never fire again
        (a killed lane's standing failure is silenced here)."""
        self._inactive.add(int(lane))
        self._dead.pop(int(lane), None)

    def note_restarted(self, lane: int) -> None:
        """The driver restarted ``lane`` in place (single-worker recovery):
        the standing death is cleared but the lane stays *active* — a
        replacement worker can fail again on later scheduled faults."""
        self._dead.pop(int(lane), None)

    def note_quarantined(self, lane: int) -> None:
        """``lane`` left the collective temporarily: faults are suspended
        until :meth:`note_recovered` re-admits it."""
        self._inactive.add(int(lane))

    def note_recovered(self, lane: int) -> None:
        self._inactive.discard(int(lane))

    def drain_report(self) -> dict:
        """Per-lane fault evidence since the last drain:
        ``{lane: {"straggle_s", "retries", "failures"}}``."""
        report, self._report = self._report, {}
        return report

    def _lane_report(self, lane: int) -> dict:
        return self._report.setdefault(
            int(lane), {"straggle_s": 0.0, "retries": 0, "failures": 0})

    # -- the host-side seam ----------------------------------------------
    def inject(self, phase: str = "exchange") -> None:
        """Consult the plan for this exchange tick; sleep, retry, or raise.

        Called by the driver-level step wrappers immediately before each
        exchange start is issued.  Raising :class:`WorkerLostError` here —
        before the device work enqueues — models the transport discovering
        a dead peer at connection time; the driver's recovery protocol owns
        everything after that.
        """
        t, self._tick = self._tick, self._tick + 1
        if self.plan.never_fires and not self._dead:
            return
        # a killed, not-yet-evicted lane fails every subsequent exchange:
        # loss is a standing condition, not a one-tick event
        for lane in sorted(self._dead):
            if lane not in self._inactive:
                raise WorkerLostError(lane, t, cause="lane is down")
        for start, end, f in self._spans:
            if start <= t < end and f.lane not in self._inactive:
                if f.delay_s > 0.0:
                    time.sleep(f.delay_s)
                self.injected_sleep_s += f.delay_s
                self._lane_report(f.lane)["straggle_s"] += f.delay_s
        for f in self._at.get(t, ()):
            if f.lane in self._inactive:
                continue
            if f.kind == "kill":
                self.kills += 1
                self._dead[f.lane] = t
                raise WorkerLostError(f.lane, t)
            # transient: a genuine bounded-retry loop — each failed attempt
            # raises internally, backs off exponentially, and retries; the
            # attempt past the retry budget escalates to worker loss
            self.transients += 1
            rec = self._lane_report(f.lane)
            rec["failures"] += 1
            for attempt in range(f.failures + 1):
                try:
                    if attempt < f.failures:
                        raise TransientExchangeError(f.lane, t, attempt)
                    break  # this attempt succeeded
                except TransientExchangeError:
                    if attempt >= self.plan.max_retries:
                        self._dead[f.lane] = t
                        raise WorkerLostError(
                            f.lane, t,
                            cause=f"{attempt + 1} transient failures exceed "
                                  f"retry budget {self.plan.max_retries}",
                        ) from None
                    if self.plan.backoff_s > 0.0:
                        time.sleep(self.plan.backoff_s * (2 ** attempt))
                    self.retries += 1
                    rec["retries"] += 1


def maybe_inject(backend, phase: str = "exchange") -> None:
    """Fire the backend's host-side fault hook if it has one (no-op for
    plain transports — the common path costs one attribute probe)."""
    inject = getattr(backend, "inject", None)
    if inject is not None:
        inject(phase)
