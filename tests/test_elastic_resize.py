"""Elastic resize end-to-end: safe-point protocol, policy hook, exactness.

The acceptance scenario: a skewed stream grows 4->8 partitions at a
checkpoint tick, every per-key state count survives bit-exactly across the
resize (and back down 8->4), and the state ships through exchange lanes
bounded by ``migration_capacity`` of the cross-size plan.
"""
import numpy as np
import pytest

from repro.core.drm import DRConfig, DRMaster
from repro.core.partitioner import uniform_partitioner
from repro.core.streaming import StreamingJob
from repro.data.generators import zipf_keys
from repro.exchange import ExchangeSpec
from repro.serve.scheduler import DRScheduler


def _pow2_lanes(plan_rows: int, state_capacity: int) -> int:
    """The lane capacity StreamingJob actually jits for a planned size."""
    cap = 8
    while cap < min(plan_rows, state_capacity):
        cap *= 2
    return min(cap, state_capacity)


def _assert_counts_exact(job: StreamingJob, batches) -> None:
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:10]:
        assert job.state_count(int(key)) == float((all_keys == key).sum()), int(key)


# ---------------------------------------------------------------------------
# DRM policy hook (synthetic loads: the pure decision logic)
# ---------------------------------------------------------------------------


def _elastic_cfg(**kw) -> DRConfig:
    base = dict(elastic=True, min_partitions=2, max_partitions=16,
                grow_trigger=1.5, shrink_trigger=1.05, resize_patience=2)
    base.update(kw)
    return DRConfig(**base)


def test_decide_resize_grow_needs_sustained_imbalance():
    drm = DRMaster(uniform_partitioner(4), _elastic_cfg())
    hot = np.array([10.0, 1.0, 1.0, 1.0])
    assert drm.decide_resize(hot) is None          # patience 1/2
    assert drm.decide_resize(hot) == 8             # sustained -> grow
    assert drm.grow_streak == 0                    # acted: streak reset


def test_decide_resize_streak_resets_when_balance_recovers():
    drm = DRMaster(uniform_partitioner(4), _elastic_cfg())
    assert drm.decide_resize(np.array([10.0, 1.0, 1.0, 1.0])) is None
    assert drm.decide_resize(np.array([1.1, 1.0, 1.0, 0.9])) is None  # resets
    assert drm.decide_resize(np.array([10.0, 1.0, 1.0, 1.0])) is None  # 1/2 again


def test_decide_resize_shrink_floors_at_workers():
    drm = DRMaster(uniform_partitioner(4), _elastic_cfg(min_partitions=1))
    flat = np.ones(4)
    assert drm.decide_resize(flat, num_workers=4) is None
    assert drm.decide_resize(flat, num_workers=4) is None  # 4 == floor: no-op
    drm2 = DRMaster(uniform_partitioner(4), _elastic_cfg(min_partitions=1))
    assert drm2.decide_resize(flat, num_workers=1) is None
    assert drm2.decide_resize(flat, num_workers=1) == 2


def test_decide_resize_respects_max_partitions():
    drm = DRMaster(uniform_partitioner(8), _elastic_cfg(max_partitions=8))
    hot = np.array([50.0] + [1.0] * 7)
    assert drm.decide_resize(hot) is None
    assert drm.decide_resize(hot) is None  # already at max: never fires
    # headroom below a non-power-of-factor ceiling is used, clamped to it
    drm2 = DRMaster(uniform_partitioner(8), _elastic_cfg(max_partitions=12))
    assert drm2.decide_resize(hot) is None
    assert drm2.decide_resize(hot) == 12


def test_decide_resize_disabled_by_default():
    drm = DRMaster(uniform_partitioner(4), DRConfig())
    assert drm.decide_resize(np.array([100.0, 1.0, 1.0, 1.0])) is None


def test_note_resize_counts_as_safe_point_decision():
    drm = DRMaster(uniform_partitioner(4), _elastic_cfg())
    seen = drm.batches_seen
    drm.note_resize(uniform_partitioner(8))
    assert drm.partitioner.num_partitions == 8
    assert drm.batches_seen == seen + 1
    assert drm.last_repartition == drm.batches_seen
    assert drm.history[-1]["resize"] == (4, 8)


# ---------------------------------------------------------------------------
# StreamingJob: the acceptance scenario
# ---------------------------------------------------------------------------


def test_grow_and_shrink_preserve_counts_with_bounded_rows():
    """Skewed keys grow 4->8 at a checkpoint tick; counts stay bit-exact
    across grow and the shrink back; shipped rows are bounded by the
    cross-size plan's migration_capacity (pow2-rounded lanes)."""
    job = StreamingJob(
        num_partitions=4,
        state_capacity=8192,
        checkpoint_interval=2,
        dr=DRConfig(elastic=True, min_partitions=4, max_partitions=8,
                    grow_trigger=1.4, shrink_trigger=1.3, resize_patience=1,
                    imbalance_trigger=1e9),  # isolate the elastic path
    )
    batches = [zipf_keys(8192, num_keys=2_000, exponent=1.5, seed=s) for s in range(4)]
    ms = [job.process_batch(b) for b in batches]
    grow = [m for m in ms if m.resized]
    assert grow, [m.reason for m in ms]
    g = grow[0]
    assert g.reason == "resize 4->8" and g.num_partitions == 8
    assert (g.batch + 1) % 2 == 0  # fired exactly at a checkpoint tick
    # lanes sized from the cross-size plan, nothing dropped
    assert g.overflow == 0
    assert g.migration_rows == job.num_workers * _pow2_lanes(g.migration_plan_rows, 8192)
    assert job.num_partitions == 8
    _assert_counts_exact(job, batches)

    # shrink back down 8->4 (driver scale-in at the next safe point)
    job.resize(4)
    more = [zipf_keys(8192, num_keys=2_000, exponent=1.5, seed=s) for s in (10, 11)]
    ms2 = [job.process_batch(b) for b in more]
    s = [m for m in ms2 if m.resized][0]
    assert s.reason == "resize 8->4" and job.num_partitions == 4
    assert s.overflow == 0
    assert s.migration_rows == job.num_workers * _pow2_lanes(s.migration_plan_rows, 8192)
    _assert_counts_exact(job, batches + more)


def test_resize_waits_for_checkpoint_tick():
    job = StreamingJob(num_partitions=4, state_capacity=4096, checkpoint_interval=3,
                       dr_enabled=False)
    job.resize(8)
    rng = np.random.default_rng(0)
    m1 = job.process_batch(rng.integers(0, 1000, 2048))
    m2 = job.process_batch(rng.integers(0, 1000, 2048))
    assert not m1.resized and not m2.resized and job.num_partitions == 4
    m3 = job.process_batch(rng.integers(0, 1000, 2048))
    assert m3.resized and job.num_partitions == 8  # third batch is the tick


def test_resize_below_worker_count_rejected():
    job = StreamingJob(num_partitions=4)
    with pytest.raises(ValueError):
        job.resize(0)


def test_snapshot_restore_roundtrip_across_resize():
    """A snapshot taken after a resize restores into a job built with the
    old topology and resumes with the new one."""
    mk = lambda: StreamingJob(num_partitions=4, state_capacity=4096,
                              dr=DRConfig(imbalance_trigger=1e9))
    job = mk()
    batches = [zipf_keys(4096, num_keys=500, exponent=1.3, seed=s) for s in range(4)]
    job.process_batch(batches[0])
    job.resize(8)
    job.process_batch(batches[1])
    assert job.num_partitions == 8
    snap = job.snapshot()

    job2 = mk()  # constructed at 4 partitions — must resume at 8
    job2.restore(snap)
    assert job2.num_partitions == 8
    assert job2.drm.partitioner.num_partitions == 8
    job.process_batch(batches[2])
    job2.process_batch(batches[2])
    all_keys = np.concatenate(batches[:3])
    for key in np.unique(all_keys)[:8]:
        want = float((all_keys == key).sum())
        assert job2.state_count(int(key)) == want
        assert job.state_count(int(key)) == want


def test_exchange_spec_rederivation():
    spec = ExchangeSpec(num_lanes=4, capacity=128, axis="data")
    grown = spec.resized(num_lanes=8)
    assert grown == ExchangeSpec(num_lanes=8, capacity=128, axis="data")
    recap = spec.resized(capacity=512)
    assert recap == ExchangeSpec(num_lanes=4, capacity=512, axis="data")
    assert spec.resized() == spec


# ---------------------------------------------------------------------------
# Serving: the same mechanism one level up (replica scale-out/in)
# ---------------------------------------------------------------------------


def test_scheduler_elastic_scale_out_and_in():
    rng = np.random.default_rng(3)
    sched = DRScheduler(4, dr=DRConfig(lam=4.0, elastic=True, min_partitions=2,
                                       max_partitions=8, grow_trigger=1.5,
                                       shrink_trigger=1.02, resize_patience=1,
                                       imbalance_trigger=1e9))
    hot = [7, 8, 9]
    results = []
    for _ in range(2):
        window = []
        for _ in range(400):
            s = int(rng.choice(hot)) if rng.random() < 0.7 else int(rng.integers(100, 5000))
            sched.route(s, 32.0)
            window.append(s)
        results.append(sched.checkpoint(np.array(window)))
        sched.drain(3000.0)
    assert len(sched.replicas) == 8
    assert any(r.get("resized") for r in results)
    # every session lives exactly where the resized partitioner maps it
    for rep in sched.replicas:
        for s in rep.sessions:
            assert int(sched.drm.partitioner.lookup_np(np.asarray([s], np.int32))[0]) == rep.rid
    # explicit scale-in folds sessions and queued work onto survivors
    before = {s for rep in sched.replicas for s in rep.sessions}
    sched.resize(2)
    assert len(sched.replicas) == 2
    after = {s for rep in sched.replicas for s in rep.sessions}
    assert after == before
    for rep in sched.replicas:
        for s in rep.sessions:
            assert int(sched.drm.partitioner.lookup_np(np.asarray([s], np.int32))[0]) == rep.rid
