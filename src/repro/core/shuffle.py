"""Device-side keyed shuffle: the DDPS stage boundary on a JAX mesh.

One shuffle step, executed under ``shard_map`` over the ``data`` axis, built
entirely on the unified exchange plane (``repro.exchange``):

1. every worker routes its local keys with the fused
   lookup+dispatch+bucketize path (one Pallas kernel on TPU, the jnp twin
   elsewhere — bit-identical),
2. the exchange primitive runs the selected backend's collective — dense
   capacity-padded or ragged count-first — and unpacks the received rows
   (overflow is counted per lane, never silently lost),
3. the DRW hook emits the local top-k histogram + global per-partition loads
   (a ``psum`` — reusing normal DDPS communication, as the paper requires).

The step is **split-phase**: the factories below expose a fused serial step
(exactly the historical call) *plus* ``.start`` / ``.finish`` halves built
from the same per-worker locals.  ``start`` runs route + bucketize + the
transport's control phase and returns every control-plane output (loads,
histograms, overflow, shipped rows) with the un-shipped buffers as an
opaque pending value; ``finish`` ships the rows.  Because the serial step
is literally ``finish_local(start_local(...))`` traced into one program,
the overlapped driver (``repro.core.streaming``) that holds ``finish`` in
flight across a batch boundary is bit-identical to the serial one by
construction.

Partitions may outnumber workers (over-partitioning, paper Fig. 5);
``worker = partition % W``.

State migration (``make_migrate_step``) is the *same* exchange with lanes
sized by the planner: ``repro.core.migration.migration_capacity`` bounds the
per-lane rows to the planned peak transfer x slack, so a repartition ships a
buffer proportional to what actually moves instead of ``W * state_capacity``
rows.  The migrate step routes with the same fused ``route_dispatch`` pass
the shuffle uses (worker granularity), so its bucketize reuses the dispatch
counts instead of recomputing them.  Both steps report the backend's
measured ``shipped_rows`` (globally summed) next to the spec's padded
provision, so the control plane sees what the transport moved, not just
what it reserved.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.hashing import KEY_SENTINEL
from repro.core.histogram import local_topk_histogram
from repro.core.partitioner import PartitionerTables
from repro.exchange.spec import DISTANCE_CLASSES
from repro.exchange import (
    ExchangeBackend,
    ExchangeResult,
    ExchangeSpec,
    ExchangeStats,
    ExchangeTopology,
    Payload,
    PendingExchange,
    SendInfo,
    make_exchange,
    maybe_inject,
    route_bucketize,
    route_dispatch,
)

__all__ = [
    "ShuffleResult",
    "ShuffleStart",
    "make_shuffle_step",
    "make_migrate_step",
    "shuffle_stats",
    "migrate_stats",
]


class ShuffleResult(NamedTuple):
    keys: jax.Array       # int32[W, W*cap]   received keys per worker (sentinel padded)
    values: jax.Array     # f32[W, W*cap, D]  received payloads
    valid: jax.Array      # bool[W, W*cap]
    part: jax.Array       # int32[W, W*cap]   destination partition of each record
    loads: jax.Array      # int32[N]          global per-partition record counts
    hist_keys: jax.Array  # int32[W, K]       DRW local top-k keys
    hist_counts: jax.Array  # int32[W, K]
    overflow: jax.Array   # int32[]           records dropped for capacity globally
    lane_overflow: jax.Array  # int32[W]      global per-lane capacity drops
    shipped_rows: jax.Array   # int32[]       rows the backend moved, all workers
    shipped_rows_by_class: jax.Array  # int32[C] shipped split by lane distance
                          # class (self/intra-host/inter-host); zeros when the
                          # spec carries no topology


class ShuffleStart(NamedTuple):
    """Control-plane outputs of the shuffle's start phase — everything a
    decision needs, available before (and without) the row ship."""

    loads: jax.Array          # int32[N]
    hist_keys: jax.Array      # int32[W, K]
    hist_counts: jax.Array    # int32[W, K]
    overflow: jax.Array       # int32[]
    lane_overflow: jax.Array  # int32[W]
    shipped_rows: jax.Array   # int32[]
    shipped_rows_by_class: jax.Array  # int32[C]


class _Pending(NamedTuple):
    """The in-flight exchange at the jit boundary: just the array leaves
    (send buffers + phase-1 counts), stacked ``[W, ...]`` per worker.
    ``SendInfo`` and the static fills are re-stamped at finish — the ship
    phase never reads them."""

    valid: jax.Array   # bool[W, L, cap]
    payloads: tuple    # each [W, L, cap, ...]
    lane_counts: jax.Array | None
    recv_counts: jax.Array | None


def _pack_pending(started: ExchangeResult) -> _Pending:
    return _Pending(
        started.valid[None],
        tuple(b[None] for b in started.payloads),
        None if started.lane_counts is None else started.lane_counts[None],
        None if started.recv_counts is None else started.recv_counts[None],
    )


def _unpack_pending(pending: _Pending, fills: tuple) -> ExchangeResult:
    return ExchangeResult(
        pending.valid[0],
        tuple(b[0] for b in pending.payloads),
        SendInfo(None, None, None, None, None),
        lane_counts=None if pending.lane_counts is None else pending.lane_counts[0],
        recv_counts=None if pending.recv_counts is None else pending.recv_counts[0],
        fills=fills,
    )


def _pool_sharding(mesh: Mesh, axis: str):
    """Sharding for freshly allocated send-buffer sets: identical to what
    the jitted ``start`` emits for its pending buffers (lane axis over the
    mesh; jit canonicalizes a size-1 axis out of the spec).  Committing the
    fresh set at allocation keeps the jit signature stable when the
    ping-pong pool first supplies a recycled (committed) set — otherwise
    the first pool hit recompiles the start program mid-stream."""
    spec = P(axis) if mesh.shape[axis] > 1 else P()
    return jax.sharding.NamedSharding(mesh, spec)


def make_shuffle_step(
    mesh: Mesh,
    *,
    num_partitions: int,
    capacity: int,
    hist_k: int = 64,
    num_hosts: int,
    seed: int = 0,
    axis: str = "data",
    backend: str | ExchangeBackend | None = None,
    topology: ExchangeTopology | None = None,
    least_load: bool = False,
):
    """Build the jitted shuffle step for a fixed mesh/capacity/topology.

    Returns the fused serial step (the historical call: ``step(tables,
    keys, vals, valid) -> ShuffleResult``) with two extra callables attached
    for the overlapped driver:

    * ``step.start(tables, keys, vals, valid) -> (pending, ShuffleStart)``
    * ``step.finish(pending) -> (keys, values, valid, part)`` stacked [W, ...]

    The serial step traces ``finish_local(start_local(...))`` into one
    program, so ``start`` + ``finish`` is bit-identical to it by
    construction.  An elastic resize rebuilds the step: ``num_partitions``
    fixes the loads vector width, so the new topology needs a new closure
    (the migrate step does *not* — it routes at worker granularity, see
    :func:`make_migrate_step`).  ``backend`` selects the exchange transport
    (dense / ragged / an :class:`ExchangeBackend` instance).

    The split-phase halves double-buffer their ``[L, cap]`` send buffers:
    ``finish`` recycles each drained pending's buffer set into a two-set
    ping-pong pool and the next ``start`` scatters into a recycled set
    (donated, so XLA rewrites it in place) instead of allocating fresh —
    at pipeline depth 2 one set is still in flight while the other is
    being filled.  Values are bit-identical to the fresh path.

    ``least_load=True`` (static) switches the split-key replica pick to
    the two-choice least-load tiebreak: ``step``/``step.start`` accept a
    ``part_loads`` vector (fed from ``Signals`` at safe points) and route
    on the jnp twin — the Pallas kernel keeps the stateless hash, so the
    gate is per-factory, never per-batch.
    """
    num_workers = mesh.shape[axis]
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_workers, capacity=capacity, axis=axis,
                     topology=topology),
        backend,
    )
    fills = (KEY_SENTINEL, 0, 0)

    def _start_core(tables, keys, vals, valid, bufs, part_loads):
        # keys [n] local records of this worker; the fused route pass
        # produces partition ids, slots, per-lane counts AND the bucketized
        # send buffers in one chain (one Pallas kernel on TPU) — bucketize
        # derives nothing again, and the ragged backend's count phase
        # reuses the counts
        tables = PartitionerTables(*tables)
        # num_partitions switches the split-key replica pick on: heavy keys
        # whose tables.heavy_repl > 1 fan out over their replica partitions
        # (an all-ones column routes bit-identically to the pre-split path)
        part, buffers = route_bucketize(
            ex, tables, keys, valid, vals, num_hosts=num_hosts, seed=seed,
            num_partitions=num_partitions,
            buffers=None if bufs is None else (bufs[0][0], tuple(b[0] for b in bufs[1])),
            part_loads=part_loads if least_load else None,
        )
        dest = jnp.where(valid, part, 0)
        started = ex.start_from(buffers).buffers
        # DRW: sample local keys during normal work (no extra pass)
        hk, hc, _ = local_topk_histogram(keys, valid, hist_k)
        # global per-partition loads (normal DDPS comms: one psum)
        my_loads = jnp.zeros(num_partitions, jnp.int32).at[dest].add(valid.astype(jnp.int32))
        loads = jax.lax.psum(my_loads, axis)
        overflow = jax.lax.psum(started.send.overflow, axis)
        lane_overflow = jax.lax.psum(started.send.lane_overflow, axis)
        shipped = jax.lax.psum(started.shipped_rows, axis)
        by_class = started.shipped_rows_by_class
        if by_class is None:  # flat spec: no topology, keep zeros
            by_class = jnp.zeros(DISTANCE_CLASSES, jnp.int32)
        by_class = jax.lax.psum(by_class, axis)
        start = ShuffleStart(loads, hk[None], hc[None], overflow, lane_overflow,
                             shipped, by_class)
        return _pack_pending(started), start

    def _start_local(tables, keys, vals, valid, bufs, part_loads):
        return _start_core(tables, keys, vals, valid, bufs, part_loads)

    def _finish_local(pending):
        res = ex.finish(PendingExchange(_unpack_pending(pending, fills)))
        rva, (rk, rv, rp) = res.unpack()
        return rk[None], rv[None], rva[None], rp[None]

    def _local(tables, keys, vals, valid, part_loads):
        # the fused serial step's send buffers never cross the jit boundary,
        # so there is nothing to recycle — fresh transient buffers (bufs
        # None) keep the trace identical to the pre-reuse step
        pending, start = _start_core(tables, keys, vals, valid, None, part_loads)
        rk, rv, rva, rp = _finish_local(pending)
        return (rk, rv, rva, rp, start.loads, start.hist_keys, start.hist_counts,
                start.overflow, start.lane_overflow, start.shipped_rows,
                start.shipped_rows_by_class)

    in_specs = (
        (P(), P(), P(), P()),  # partitioner tables replicated
        P(axis),  # keys sharded over workers
        P(axis),
        P(axis),
    )
    bufs_spec = (P(axis), (P(axis), P(axis), P(axis)))
    mapped = shard_map(
        _local, mesh=mesh, in_specs=in_specs + (P(),),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(axis), P(axis),
                   P(), P(), P(), P()),
        check_vma=False,
    )
    start_mapped = shard_map(
        _start_local, mesh=mesh, in_specs=in_specs + (bufs_spec, P()),
        out_specs=(P(axis), ShuffleStart(P(), P(axis), P(axis), P(), P(), P(), P())),
        check_vma=False,
    )
    finish_mapped = shard_map(
        _finish_local, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_vma=False,
    )

    # donate the per-batch buffers so the exchange compaction reuses them
    # instead of double-allocating; the recycled send-buffer set (arg 4 of
    # start) is donated too — its reset+scatter rewrites it in place.  The
    # finish phase must NOT donate: each drained pending's buffers re-enter
    # the ping-pong pool, so they have to survive the ship.  (CPU has no
    # donation — skip the warning.)
    donate = () if jax.default_backend() == "cpu" else (1, 2, 3)
    start_donate = () if jax.default_backend() == "cpu" else (1, 2, 3, 4)
    jstep = jax.jit(mapped, donate_argnums=donate)
    jstart = jax.jit(start_mapped, donate_argnums=start_donate)
    jfinish = jax.jit(finish_mapped)

    zero_loads = jnp.zeros(num_partitions, jnp.float32)
    recycled: list = []  # drained send-buffer sets, ping-pong pool (<= 2)
    buf_sharding = _pool_sharding(mesh, axis)

    def _fresh_bufs(vals):
        shape = (num_workers, num_workers, capacity)
        return jax.device_put((
            jnp.zeros(shape, bool),
            (jnp.full(shape, KEY_SENTINEL, jnp.int32),
             jnp.zeros(shape + vals.shape[1:], vals.dtype),
             jnp.zeros(shape, jnp.int32)),
        ), buf_sharding)

    def step(tables: PartitionerTables, keys, vals, valid,
             part_loads=None) -> ShuffleResult:
        maybe_inject(ex.backend, "shuffle")  # host boundary: faults fire here
        pl = zero_loads if part_loads is None else part_loads
        return ShuffleResult(*jstep(tuple(tables), keys, vals, valid, pl))

    def start(tables: PartitionerTables, keys, vals, valid, part_loads=None):
        maybe_inject(ex.backend, "shuffle")
        bufs = recycled.pop() if recycled else None
        if bufs is not None and (bufs[1][1].shape[3:] != vals.shape[1:]
                                 or bufs[1][1].dtype != vals.dtype):
            bufs = None  # payload width changed: the set cannot be reused
        if bufs is None:
            bufs = _fresh_bufs(vals)
        pl = zero_loads if part_loads is None else part_loads
        return jstart(tuple(tables), keys, vals, valid, bufs, pl)

    def finish(pending: _Pending):
        out = jfinish(pending)
        if len(recycled) < 2:
            # the drained pending's buffers become the next idle set — two
            # sets bound the pool because at most two exchanges are in
            # flight (pipeline depth 2)
            recycled.append((pending.valid, pending.payloads))
        return out

    step.start = start
    step.finish = finish
    return step


def make_migrate_step(
    mesh: Mesh,
    *,
    state_capacity: int,
    num_hosts: int,
    lane_capacity: int | None = None,
    seed: int = 0,
    axis: str = "data",
    spec: ExchangeSpec | None = None,
    backend: str | ExchangeBackend | None = None,
    topology: ExchangeTopology | None = None,
):
    """Jitted operator-state migration for a partitioner swap.

    Each worker re-evaluates the new partitioner on its stored keys and
    ships rows whose worker changed through the exchange plane.  Routing
    rides the same fused ``route_dispatch`` pass as the shuffle (worker
    granularity), so the bucketize reuses the dispatch slots/counts instead
    of recomputing them; lane ``me`` never ships (its rows stay put), so
    its count is zeroed before they reach the exchange.
    ``lane_capacity`` bounds the per-(src, dst) rows of the all-to-all —
    pass ``migration_capacity(plan, num_workers=W)`` to size the exchange to
    the planned peak transfer x slack instead of the full state table
    (defaults to ``state_capacity``, the correctness-first upper bound).
    ``spec`` overrides the derived :class:`ExchangeSpec` entirely (the
    elastic-resize path re-derives the shuffle's spec); ``backend`` selects
    the transport.  The migrate step routes at *worker* granularity
    (``lookup % W``), so one step serves any partition count — a resize
    migration reuses the same jit cache.

    Returns the fused step (kept state + received rows + relative-migration
    metric + overflow + per-lane overflow + globally shipped rows) with
    ``.start`` / ``.finish`` halves attached: ``start`` keeps every control
    output and the kept state local (the ship stays pending), ``finish``
    ships the moving rows — the overlapped driver leaves it in flight
    across the safe point.
    """
    num_workers = mesh.shape[axis]
    if spec is None:
        cap = state_capacity if lane_capacity is None else min(lane_capacity, state_capacity)
        spec = ExchangeSpec(num_lanes=num_workers, capacity=cap, axis=axis,
                            topology=topology)
    ex = make_exchange(spec, backend)
    fills = (KEY_SENTINEL, 0)

    def _start_core(new_tables, state_keys, state_vals, bufs):
        # state tables arrive stacked [1, S] / [1, S, D] per shard
        state_keys, state_vals = state_keys[0], state_vals[0]
        new_tables = PartitionerTables(*new_tables)
        me = jax.lax.axis_index(axis)
        valid = state_keys != KEY_SENTINEL
        # home routing on purpose (no num_partitions): a migration is where
        # a split key's scattered partials converge — every replica's rows
        # ship to the key's home partition, whose merge_into sums them.
        # Routing state by replica pick would scatter it instead.
        part, slot, counts = route_dispatch(
            new_tables, state_keys, valid,
            num_hosts=num_hosts, seed=seed, num_lanes=num_workers,
        )
        dest = jnp.where(valid, part % num_workers, me)
        moving = valid & (dest != me)
        # the fused route ranked *all* valid rows; rows on lane `me` stay
        # put (they are not `moving`), so their lane count is zeroed — on
        # every other lane valid == moving and the slots/counts coincide
        # with ranking the moving rows alone
        counts = counts.at[me].set(0)
        moved_w = jnp.sum(moving)
        total_w = jax.lax.psum(jnp.sum(valid), axis)

        buffers = ex.bucketize(
            jnp.where(moving, dest, me),
            moving,
            [
                Payload(jnp.where(moving, state_keys, KEY_SENTINEL), KEY_SENTINEL),
                Payload(state_vals, 0),
            ],
            slot=slot,
            counts=counts,
            buffers=None if bufs is None else (bufs[0][0], tuple(b[0] for b in bufs[1])),
        )
        started = ex.start_from(buffers).buffers

        kept_keys = jnp.where(moving, KEY_SENTINEL, state_keys)
        kept_valid = valid & ~moving
        moved_total = jax.lax.psum(moved_w, axis)
        overflow = jax.lax.psum(started.send.overflow, axis)
        lane_overflow = jax.lax.psum(started.send.lane_overflow, axis)
        shipped = jax.lax.psum(started.shipped_rows, axis)
        by_class = started.shipped_rows_by_class
        if by_class is None:  # flat spec: no topology, keep zeros
            by_class = jnp.zeros(DISTANCE_CLASSES, jnp.int32)
        by_class = jax.lax.psum(by_class, axis)
        return (
            _pack_pending(started),
            kept_keys[None],
            state_vals[None],
            kept_valid[None],
            moved_total,
            total_w,
            overflow,
            lane_overflow,
            shipped,
            by_class,
        )

    def _start_local(new_tables, state_keys, state_vals, bufs):
        return _start_core(new_tables, state_keys, state_vals, bufs)

    def _finish_local(pending):
        res = ex.finish(PendingExchange(_unpack_pending(pending, fills)))
        rva, (rk, rv) = res.unpack()
        return rk[None], rv[None], rva[None]

    def _local(new_tables, state_keys, state_vals):
        pending, kk, vv, kva, moved, total, ov, lov, shipped, by = _start_core(
            new_tables, state_keys, state_vals, None
        )
        rk, rv, rva = _finish_local(pending)
        return kk, vv, kva, rk, rv, rva, moved, total, ov, lov, shipped, by

    in_specs = ((P(), P(), P(), P()), P(axis), P(axis))
    bufs_spec = (P(axis), (P(axis), P(axis)))
    mapped = shard_map(
        _local, mesh=mesh, in_specs=in_specs,
        out_specs=(P(axis),) * 6 + (P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    start_mapped = shard_map(
        _start_local, mesh=mesh, in_specs=in_specs + (bufs_spec,),
        out_specs=(P(axis),) * 4 + (P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    finish_mapped = shard_map(
        _finish_local, mesh=mesh, in_specs=(P(axis),),
        out_specs=(P(axis), P(axis), P(axis)),
        check_vma=False,
    )

    # donate the state tables: the kept/received outputs alias them, so the
    # exchange compaction doesn't double-allocate the state; the recycled
    # send-buffer set (arg 3 of start) is donated and rewritten in place.
    # finish keeps its pending alive — drained sets re-enter the ping-pong
    # pool (CPU: no donation at all).
    donate = () if jax.default_backend() == "cpu" else (1, 2)
    start_donate = () if jax.default_backend() == "cpu" else (1, 2, 3)
    jmig = jax.jit(mapped, donate_argnums=donate)
    jstart = jax.jit(start_mapped, donate_argnums=start_donate)
    jfinish = jax.jit(finish_mapped)

    recycled: list = []  # drained send-buffer sets, ping-pong pool (<= 2)
    buf_sharding = _pool_sharding(mesh, axis)

    def _fresh_bufs(state_vals):
        shape = (num_workers, spec.num_lanes, spec.capacity)
        return jax.device_put((
            jnp.zeros(shape, bool),
            (jnp.full(shape, KEY_SENTINEL, jnp.int32),
             jnp.zeros(shape + state_vals.shape[2:], state_vals.dtype)),
        ), buf_sharding)

    def migrate(new_tables, state_keys, state_vals):
        maybe_inject(ex.backend, "migrate")  # host boundary: faults fire here
        return jmig(tuple(new_tables), state_keys, state_vals)

    def start(new_tables, state_keys, state_vals):
        maybe_inject(ex.backend, "migrate")
        bufs = recycled.pop() if recycled else None
        if bufs is not None and (bufs[1][1].shape[3:] != state_vals.shape[2:]
                                 or bufs[1][1].dtype != state_vals.dtype):
            bufs = None  # payload width changed: the set cannot be reused
        if bufs is None:
            bufs = _fresh_bufs(state_vals)
        return jstart(tuple(new_tables), state_keys, state_vals, bufs)

    def finish(pending: _Pending):
        out = jfinish(pending)
        if len(recycled) < 2:
            recycled.append((pending.valid, pending.payloads))
        return out

    migrate.start = start
    migrate.finish = finish
    return migrate


# ---------------------------------------------------------------------------
# Plane-side telemetry constructors (the ExchangeStats API): consumers hand
# these records whole to ``Telemetry.record_exchange(stats)`` instead of
# assembling keyword soup at every call site.
# ---------------------------------------------------------------------------


def shuffle_stats(
    res: "ShuffleResult | ShuffleStart",
    spec: ExchangeSpec,
    num_workers: int,
    *,
    wall_s: float = 0.0,
    count_wall_s: float | None = None,
    backend: str | None = None,
    replica_rows: np.ndarray | None = None,
) -> ExchangeStats:
    """:class:`ExchangeStats` for one shuffle step.

    ``ShuffleResult`` and ``ShuffleStart`` share every control field this
    reads (loads / overflow / lane_overflow / shipped_rows), so the serial
    and overlapped drivers construct identical records.  Rows are per worker
    (the globally-psummed counters divided by ``num_workers``); ``padded``
    is the spec's per-worker provision.

    Sync-free: device inputs stay device-side — the per-worker arithmetic
    runs as (async) jnp ops and the record carries device scalars, which
    ``Telemetry.record_exchange`` accepts and folds to host ints only at
    ``snapshot()`` (the safe point).  Host inputs produce a host record as
    before.
    """
    dev = isinstance(res.shipped_rows, jax.Array)
    if dev:
        shipped = res.shipped_rows // num_workers
        occupied = jnp.maximum(jnp.sum(res.loads) - res.overflow, 0) // num_workers
    else:
        shipped = int(np.asarray(res.shipped_rows)) // num_workers
        occupied = max(int(np.asarray(res.loads).sum()) - int(res.overflow), 0) // num_workers
    by_class = None
    if spec.topology is not None and res.shipped_rows_by_class is not None:
        by_class = (res.shipped_rows_by_class // num_workers if dev
                    else np.asarray(res.shipped_rows_by_class, np.int64) // num_workers)
    return ExchangeStats(
        rows=shipped,
        wall_s=wall_s,
        padded_rows=spec.rows,
        occupied_rows=occupied,
        lane_overflow=res.lane_overflow if dev else np.asarray(res.lane_overflow),
        count_wall_s=count_wall_s,
        backend=backend,
        replica_rows=replica_rows,
        rows_by_class=by_class,
    )


def migrate_stats(
    *,
    shipped_rows,
    buffer_rows: int,
    moved_rows: int,
    overflow: int,
    num_workers: int,
    lane_overflow=None,
    wall_s: float = 0.0,
    backend: str | None = None,
    shipped_rows_by_class=None,
) -> ExchangeStats:
    """:class:`ExchangeStats` for one state migration.

    ``buffer_rows`` is the per-worker lane provision (``W * lane_cap``),
    ``moved_rows`` the rows that actually crossed workers (globally summed,
    like ``shipped_rows`` and ``overflow``); ``shipped_rows_by_class`` the
    globally-summed per-distance-class split (``None`` on a flat spec).

    Migrations only happen at safe points (the driver drains before acting),
    so the host conversions here are sanctioned — they route through
    :func:`repro.compat.host_fetch` so the sync audit sees them.
    """
    from repro.compat import host_fetch

    by_class = None
    if shipped_rows_by_class is not None:
        by_class = np.asarray(host_fetch(shipped_rows_by_class), np.int64)
        by_class = None if not by_class.any() else by_class // num_workers
    return ExchangeStats(
        rows=int(host_fetch(shipped_rows)) // num_workers,
        wall_s=wall_s,
        padded_rows=int(buffer_rows),
        occupied_rows=max(int(moved_rows) - int(overflow), 0) // num_workers,
        lane_overflow=None if lane_overflow is None else host_fetch(lane_overflow),
        backend=backend,
        rows_by_class=by_class,
    )
