"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp ref oracles,
plus cross-checks against the host (numpy) implementations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Histogram, kip_update, uniform_partitioner
from repro.data.generators import zipf_keys
from repro.kernels import ops, ref
from repro.kernels.dispatch_count import dispatch_count
from repro.kernels.partition_apply import partition_apply
from repro.kernels.sketch_update import sketch_update


# ---------------------------------------------------------------------------
# partition_apply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("b", [128, 512])
@pytest.mark.parametrize("num_hosts", [1024, 4096])
def test_partition_apply_sweep(n, b, num_hosts):
    rng = np.random.default_rng(n + b)
    keys = rng.integers(0, 2**30, n).astype(np.int32)
    heavy = np.sort(rng.choice(2**30, b // 2, replace=False)).astype(np.int32)
    hk = np.concatenate([heavy, np.full(b - len(heavy), 2**31 - 1, np.int32)])
    hp = np.concatenate(
        [rng.integers(0, 16, len(heavy)), np.zeros(b - len(heavy))]
    ).astype(np.int32)
    table = rng.integers(0, 16, num_hosts).astype(np.int32)
    # route some keys through the heavy path
    keys[: b // 4] = heavy[: b // 4]

    got = partition_apply(
        jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp), jnp.asarray(table),
        seed=0, num_hosts=num_hosts, interpret=True,
    )
    want = ref.partition_apply_ref(
        jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp), jnp.asarray(table),
        seed=0, num_hosts=num_hosts,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_partition_apply_matches_host_partitioner():
    """Kernel == Partitioner.lookup_np == lookup_device on a real KIP."""
    stream = zipf_keys(8192, num_keys=2_000, exponent=1.2, seed=0)
    hist = Histogram.exact(stream).top(64)
    kip = kip_update(uniform_partitioner(16), hist)
    keys = stream[:4096].astype(np.int32)
    got = ops.apply_partitioner(jnp.asarray(keys), kip.tables(), num_hosts=kip.num_hosts, seed=kip.seed)
    want = kip.lookup_np(keys)
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), n_pow=st.integers(1, 4))
def test_prop_partition_apply_range(seed, n_pow):
    n = 256 * n_pow
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**30, n).astype(np.int32)
    table = rng.integers(0, 8, 1024).astype(np.int32)
    hk = np.full(128, 2**31 - 1, np.int32)
    hp = np.zeros(128, np.int32)
    got = np.asarray(
        partition_apply(jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp),
                        jnp.asarray(table), seed=seed, num_hosts=1024, interpret=True)
    )
    assert got.min() >= 0 and got.max() < 8


# ---------------------------------------------------------------------------
# sketch_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 2048])
@pytest.mark.parametrize("depth,width", [(2, 512), (4, 2048), (8, 1024)])
def test_sketch_update_sweep(n, depth, width):
    rng = np.random.default_rng(n + depth)
    keys = rng.integers(0, 10_000, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    got = sketch_update(jnp.asarray(keys), jnp.asarray(valid), depth=depth, width=width, interpret=True)
    want = ref.sketch_update_ref(jnp.asarray(keys), jnp.asarray(valid), depth=depth, width=width)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


def test_sketch_matches_host_cms():
    """Kernel rows == host CountMinSketch table (bit-identical hashing)."""
    from repro.core import CountMinSketch

    rng = np.random.default_rng(3)
    keys = rng.integers(0, 5_000, 2048).astype(np.int32)
    cms = CountMinSketch(depth=4, width=512)
    cms.update(keys)
    got = np.asarray(ops.count_sketch(jnp.asarray(keys), depth=4, width=512))
    np.testing.assert_allclose(got, cms.table, atol=0)


def test_sketch_total_mass():
    keys = jnp.arange(1024, dtype=jnp.int32)
    sk = np.asarray(ops.count_sketch(keys, depth=3, width=256))
    np.testing.assert_allclose(sk.sum(axis=1), 1024.0)


# ---------------------------------------------------------------------------
# dispatch_count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [512, 2048])
@pytest.mark.parametrize("num_parts", [4, 16, 256])
def test_dispatch_count_sweep(n, num_parts):
    rng = np.random.default_rng(n + num_parts)
    dest = rng.integers(0, num_parts, n).astype(np.int32)
    valid = rng.random(n) < 0.85
    got_slot, got_counts = dispatch_count(
        jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts, interpret=True
    )
    want_slot, want_counts = ref.dispatch_count_ref(
        jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts
    )
    np.testing.assert_array_equal(np.asarray(got_slot), np.asarray(want_slot))
    np.testing.assert_array_equal(np.asarray(got_counts.astype(jnp.int32)), np.asarray(want_counts))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), num_parts=st.sampled_from([2, 8, 64]))
def test_prop_dispatch_slots_bijective(seed, num_parts):
    """slots within one destination are exactly 0..count-1 (a bijection) —
    the invariant that makes the scatter into [N, capacity] collision-free."""
    rng = np.random.default_rng(seed)
    n = 1024
    dest = rng.integers(0, num_parts, n).astype(np.int32)
    valid = rng.random(n) < 0.7
    slot, counts = ops.dispatch_slots(jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts)
    slot, counts = np.asarray(slot), np.asarray(counts)
    for p in range(num_parts):
        s = np.sort(slot[(dest == p) & valid])
        assert len(s) == counts[p]
        np.testing.assert_array_equal(s, np.arange(len(s)))
    assert np.all(slot[~valid] == -1)


def test_dispatch_order_stable():
    dest = jnp.asarray([0, 1, 0, 1, 0], jnp.int32)
    valid = jnp.ones(5, bool)
    slot, counts = ops.dispatch_slots(dest, valid, num_parts=2)
    np.testing.assert_array_equal(np.asarray(slot), [0, 0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(counts), [3, 2])


# ---------------------------------------------------------------------------
# route_bucketize (fused route + slot + scatter)
# ---------------------------------------------------------------------------


def _kip(num_lanes, seed=0):
    stream = zipf_keys(8192, num_keys=2_000, exponent=1.2, seed=seed)
    hist = Histogram.exact(stream).top(64)
    return kip_update(uniform_partitioner(num_lanes), hist), stream


@pytest.mark.parametrize("n,num_lanes,capacity", [(512, 4, 32), (1024, 8, 128),
                                                  (2048, 16, 200)])
def test_route_bucketize_sweep(n, num_lanes, capacity):
    """Kernel (interpret) == jnp ref on all seven outputs, including lanes
    past capacity (dropped scatter) and a capacity that is not a tile
    multiple (the wrapper's pad-and-slice)."""
    kip, stream = _kip(num_lanes)
    rng = np.random.default_rng(n)
    keys = jnp.asarray(stream[:n].astype(np.int32))
    valid = np.asarray(rng.random(n) < 0.85)
    vals = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    t = kip.tables()
    got = ops.route_bucketize(
        keys, jnp.asarray(valid), t, vals, num_hosts=kip.num_hosts,
        seed=kip.seed, num_lanes=num_lanes, capacity=capacity,
        key_fill=2**31 - 1, interpret=True,
    )
    want = ref.route_bucketize_ref(
        keys, jnp.asarray(valid), vals, t.heavy_keys, t.heavy_parts,
        t.host_to_part, seed=kip.seed, num_hosts=kip.num_hosts,
        num_lanes=num_lanes, capacity=capacity, key_fill=2**31 - 1,
    )
    for name, g, w in zip(
        ("part", "slot", "counts", "buf_valid", "buf_keys", "buf_vals", "buf_part"),
        got, want,
    ):
        g, w = np.asarray(g), np.asarray(w)
        if name == "part":
            # the kernel pads the heavy table to a full tile with sentinel
            # rows; a sentinel can only match an invalid record, whose part
            # every consumer masks — compare the consumed view
            g, w = np.where(valid, g, 0), np.where(valid, w, 0)
        np.testing.assert_array_equal(g, w, err_msg=name)


def test_route_bucketize_empty_heavy_table():
    """A partitioner with no heavy keys (the cold-start uniform table) still
    routes through the kernel's fixed heavy-tile block shape."""
    part = uniform_partitioner(8)
    assert part.tables().heavy_keys.shape[0] == 0
    rng = np.random.default_rng(5)
    keys = jnp.asarray(rng.integers(0, 2**30, 512).astype(np.int32))
    valid = np.asarray(rng.random(512) < 0.9)
    vals = jnp.asarray(rng.normal(size=(512, 1)).astype(np.float32))
    t = part.tables()
    got = ops.route_bucketize(
        keys, jnp.asarray(valid), t, vals, num_hosts=part.num_hosts,
        seed=part.seed, num_lanes=8, capacity=128, key_fill=2**31 - 1,
        interpret=True,
    )
    want = ref.route_bucketize_ref(
        keys, jnp.asarray(valid), vals, t.heavy_keys, t.heavy_parts,
        t.host_to_part, seed=part.seed, num_hosts=part.num_hosts,
        num_lanes=8, capacity=128, key_fill=2**31 - 1,
    )
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(got[0]), 0), np.where(valid, np.asarray(want[0]), 0)
    )
    for g, w in zip(got[1:], want[1:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_route_bucketize_plane_paths_agree():
    """The exchange plane's kernel path (use_pallas, interpreted on CPU) and
    its route_dispatch + bucketize path build the same send buffers — the
    contract that lets the TPU path swap in without a behavior change."""
    from repro.exchange import ExchangeSpec, make_exchange
    from repro.exchange import route_bucketize as plane_route_bucketize

    kip, stream = _kip(4)
    rng = np.random.default_rng(11)
    n = 768
    keys = jnp.asarray(stream[:n].astype(np.int32))
    valid = np.asarray(rng.random(n) < 0.85)
    vals = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    ex = make_exchange(ExchangeSpec(num_lanes=4, capacity=64, axis=None), "local")
    out = {}
    for use_pallas in (True, False):
        part, buffers = plane_route_bucketize(
            ex, kip.tables(), keys, jnp.asarray(valid), vals,
            num_hosts=kip.num_hosts, seed=kip.seed, use_pallas=use_pallas,
        )
        out[use_pallas] = (part, buffers)
    p_k, b_k = out[True]
    p_j, b_j = out[False]
    np.testing.assert_array_equal(np.where(valid, np.asarray(p_k), 0),
                                  np.where(valid, np.asarray(p_j), 0))
    np.testing.assert_array_equal(np.asarray(b_k.valid), np.asarray(b_j.valid))
    for pk, pj in zip(b_k.payloads, b_j.payloads):
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pj))
    np.testing.assert_array_equal(np.asarray(b_k.lane_counts),
                                  np.asarray(b_j.lane_counts))
    assert int(b_k.send.overflow) == int(b_j.send.overflow)
    np.testing.assert_array_equal(np.asarray(b_k.send.lane_overflow),
                                  np.asarray(b_j.send.lane_overflow))
