"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp ref oracles,
plus cross-checks against the host (numpy) implementations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Histogram, kip_update, uniform_partitioner
from repro.data.generators import zipf_keys
from repro.kernels import ops, ref
from repro.kernels.dispatch_count import dispatch_count
from repro.kernels.partition_apply import partition_apply
from repro.kernels.sketch_update import sketch_update


# ---------------------------------------------------------------------------
# partition_apply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("b", [128, 512])
@pytest.mark.parametrize("num_hosts", [1024, 4096])
def test_partition_apply_sweep(n, b, num_hosts):
    rng = np.random.default_rng(n + b)
    keys = rng.integers(0, 2**30, n).astype(np.int32)
    heavy = np.sort(rng.choice(2**30, b // 2, replace=False)).astype(np.int32)
    hk = np.concatenate([heavy, np.full(b - len(heavy), 2**31 - 1, np.int32)])
    hp = np.concatenate(
        [rng.integers(0, 16, len(heavy)), np.zeros(b - len(heavy))]
    ).astype(np.int32)
    table = rng.integers(0, 16, num_hosts).astype(np.int32)
    # route some keys through the heavy path
    keys[: b // 4] = heavy[: b // 4]

    got = partition_apply(
        jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp), jnp.asarray(table),
        seed=0, num_hosts=num_hosts, interpret=True,
    )
    want = ref.partition_apply_ref(
        jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp), jnp.asarray(table),
        seed=0, num_hosts=num_hosts,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_partition_apply_matches_host_partitioner():
    """Kernel == Partitioner.lookup_np == lookup_device on a real KIP."""
    stream = zipf_keys(8192, num_keys=2_000, exponent=1.2, seed=0)
    hist = Histogram.exact(stream).top(64)
    kip = kip_update(uniform_partitioner(16), hist)
    keys = stream[:4096].astype(np.int32)
    got = ops.apply_partitioner(jnp.asarray(keys), kip.tables(), num_hosts=kip.num_hosts, seed=kip.seed)
    want = kip.lookup_np(keys)
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), n_pow=st.integers(1, 4))
def test_prop_partition_apply_range(seed, n_pow):
    n = 256 * n_pow
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2**30, n).astype(np.int32)
    table = rng.integers(0, 8, 1024).astype(np.int32)
    hk = np.full(128, 2**31 - 1, np.int32)
    hp = np.zeros(128, np.int32)
    got = np.asarray(
        partition_apply(jnp.asarray(keys), jnp.asarray(hk), jnp.asarray(hp),
                        jnp.asarray(table), seed=seed, num_hosts=1024, interpret=True)
    )
    assert got.min() >= 0 and got.max() < 8


# ---------------------------------------------------------------------------
# sketch_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 2048])
@pytest.mark.parametrize("depth,width", [(2, 512), (4, 2048), (8, 1024)])
def test_sketch_update_sweep(n, depth, width):
    rng = np.random.default_rng(n + depth)
    keys = rng.integers(0, 10_000, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    got = sketch_update(jnp.asarray(keys), jnp.asarray(valid), depth=depth, width=width, interpret=True)
    want = ref.sketch_update_ref(jnp.asarray(keys), jnp.asarray(valid), depth=depth, width=width)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


def test_sketch_matches_host_cms():
    """Kernel rows == host CountMinSketch table (bit-identical hashing)."""
    from repro.core import CountMinSketch

    rng = np.random.default_rng(3)
    keys = rng.integers(0, 5_000, 2048).astype(np.int32)
    cms = CountMinSketch(depth=4, width=512)
    cms.update(keys)
    got = np.asarray(ops.count_sketch(jnp.asarray(keys), depth=4, width=512))
    np.testing.assert_allclose(got, cms.table, atol=0)


def test_sketch_total_mass():
    keys = jnp.arange(1024, dtype=jnp.int32)
    sk = np.asarray(ops.count_sketch(keys, depth=3, width=256))
    np.testing.assert_allclose(sk.sum(axis=1), 1024.0)


# ---------------------------------------------------------------------------
# dispatch_count
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [512, 2048])
@pytest.mark.parametrize("num_parts", [4, 16, 256])
def test_dispatch_count_sweep(n, num_parts):
    rng = np.random.default_rng(n + num_parts)
    dest = rng.integers(0, num_parts, n).astype(np.int32)
    valid = rng.random(n) < 0.85
    got_slot, got_counts = dispatch_count(
        jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts, interpret=True
    )
    want_slot, want_counts = ref.dispatch_count_ref(
        jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts
    )
    np.testing.assert_array_equal(np.asarray(got_slot), np.asarray(want_slot))
    np.testing.assert_array_equal(np.asarray(got_counts.astype(jnp.int32)), np.asarray(want_counts))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), num_parts=st.sampled_from([2, 8, 64]))
def test_prop_dispatch_slots_bijective(seed, num_parts):
    """slots within one destination are exactly 0..count-1 (a bijection) —
    the invariant that makes the scatter into [N, capacity] collision-free."""
    rng = np.random.default_rng(seed)
    n = 1024
    dest = rng.integers(0, num_parts, n).astype(np.int32)
    valid = rng.random(n) < 0.7
    slot, counts = ops.dispatch_slots(jnp.asarray(dest), jnp.asarray(valid), num_parts=num_parts)
    slot, counts = np.asarray(slot), np.asarray(counts)
    for p in range(num_parts):
        s = np.sort(slot[(dest == p) & valid])
        assert len(s) == counts[p]
        np.testing.assert_array_equal(s, np.arange(len(s)))
    assert np.all(slot[~valid] == -1)


def test_dispatch_order_stable():
    dest = jnp.asarray([0, 1, 0, 1, 0], jnp.int32)
    valid = jnp.ones(5, bool)
    slot, counts = ops.dispatch_slots(dest, valid, num_parts=2)
    np.testing.assert_array_equal(np.asarray(slot), [0, 0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(counts), [3, 2])
