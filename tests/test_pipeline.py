"""Pipeline parallelism: PP loss == plain loss (exactness), on 2 fake pods."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs.base import reduce_for_smoke
    from repro.configs.registry import get_config
    from repro.models import model
    from repro.models.modules import Policy
    from repro.launch.pipeline import make_pp_loss, stack_stage_params
    from repro.compat import set_mesh
    import dataclasses

    cfg = reduce_for_smoke(get_config("stablelm-1.6b"))
    cfg = dataclasses.replace(cfg, num_layers=4)   # 2 stages x 2 periods
    pol = Policy(attn_q_chunk=32, attn_kv_chunk=32)
    params = model.init_params(cfg, jax.random.PRNGKey(0), pol)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    want, _ = model.loss_fn(params, batch, cfg, pol)

    mesh = jax.make_mesh((2,), ("pod",))
    stacked = stack_stage_params(cfg, params, 2)
    with set_mesh(mesh):
        pp_loss = make_pp_loss(cfg, pol, mesh, microbatches=2)
        got = jax.jit(pp_loss)(stacked, batch)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-4)
    # gradients flow through the pipeline (ppermute transpose)
    g = jax.grad(lambda p: pp_loss(p, batch))(stacked)
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32)))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("PP-OK", float(got), float(want))
""")


@pytest.mark.slow
def test_pp_loss_matches_plain():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "PP-OK" in out.stdout, out.stdout + "\n" + out.stderr
