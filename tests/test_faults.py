"""Failure domains: fault injection, lane health, zero-loss recovery.

Everything here runs on the in-process single-device mesh (the shard_map
path is fully exercised at W=1); the multi-worker eviction proof lives in
``tests/test_distributed.py`` behind the 8-device subprocess harness.
"""
import numpy as np
import pytest

from repro.control import Evict, Quarantine, Recover, Signals, Telemetry
from repro.core.drm import DRConfig, DRMaster
from repro.core.partitioner import uniform_partitioner
from repro.core.streaming import StreamingJob
from repro.exchange import (
    ExchangeStats,
    FaultPlan,
    FaultyBackend,
    LaneFault,
    WorkerLostError,
)

pytestmark = pytest.mark.chaos


def _batches(n=8, keys=50, rows=64, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, keys, rows).astype(np.int64) for _ in range(n)]


def _mesh1():
    """Explicit single-device mesh: the restart-in-place recovery tests
    must see W=1 even when another test module forced a multi-device host
    platform (e.g. test_split sets XLA_FLAGS at import time)."""
    import jax
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def _counts(job, keys=50):
    return {k: job.state_count(k) for k in range(keys)}


def _trajectory(metrics):
    return [(m.action, m.reason, m.overflow, m.shipped_rows) for m in metrics]


# ---------------------------------------------------------------------------
# FaultPlan: schedule, serialization, determinism
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrip():
    plan = FaultPlan(
        faults=(
            LaneFault(3, 1, "latency", delay_s=0.01, span=2),
            LaneFault(5, 0, "transient", failures=2),
            LaneFault(9, 2, "kill"),
        ),
        max_retries=4,
        backoff_s=0.001,
        seed=7,
    )
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    assert not plan.never_fires
    assert FaultPlan().never_fires


def test_fault_plan_generate_deterministic():
    a = FaultPlan.generate(11, num_lanes=4, ticks=32, kill_at=(20, 3))
    b = FaultPlan.generate(11, num_lanes=4, ticks=32, kill_at=(20, 3))
    c = FaultPlan.generate(12, num_lanes=4, ticks=32, kill_at=(20, 3))
    assert a == b
    assert a != c
    assert any(f.kind == "kill" and f.tick == 20 and f.lane == 3
               for f in a.faults)


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        LaneFault(0, 0, "meteor")
    with pytest.raises(ValueError):
        LaneFault(-1, 0, "kill")
    with pytest.raises(ValueError):
        LaneFault(0, 0, "transient", failures=0)
    with pytest.raises(ValueError):
        FaultPlan(max_retries=-1)


# ---------------------------------------------------------------------------
# the seam: never-firing identity, retries, escalation
# ---------------------------------------------------------------------------


def test_never_firing_plan_is_bit_identical():
    """An installed FaultPlan that never fires must leave the decision
    trajectory AND the keyed state bit-identical to a run with no seam at
    all — the acceptance contract for the host-boundary injection design."""
    batches = _batches()
    ref = StreamingJob(dr=DRConfig())
    ms_ref = ref.run(batches)
    seamed = StreamingJob(dr=DRConfig(),
                          exchange_backend=FaultyBackend("dense", FaultPlan()))
    ms_seam = seamed.run(batches)
    assert _trajectory(ms_ref) == _trajectory(ms_seam)
    assert _counts(ref) == _counts(seamed)
    assert seamed.exchange_backend.kills == 0
    assert seamed.exchange_backend.retries == 0


@pytest.mark.parametrize("depth", [1, 2])
def test_never_firing_identity_pipelined(depth):
    batches = _batches()
    cfg = DRConfig(pipeline_depth=depth)
    ref = StreamingJob(dr=cfg)
    ms_ref = ref.run(batches)
    seamed = StreamingJob(dr=cfg,
                          exchange_backend=FaultyBackend("dense", FaultPlan()))
    ms_seam = seamed.run(batches)
    assert _trajectory(ms_ref) == _trajectory(ms_seam)
    assert _counts(ref) == _counts(seamed)


def test_transient_faults_retry_to_zero_loss():
    batches = _batches()
    ref = StreamingJob(dr=DRConfig(imbalance_trigger=1e9))
    ref.run(batches)
    plan = FaultPlan(
        faults=(LaneFault(2, 0, "transient", failures=2),
                LaneFault(5, 0, "transient", failures=1)),
        max_retries=3,
    )
    job = StreamingJob(dr=DRConfig(imbalance_trigger=1e9),
                       exchange_backend=FaultyBackend("dense", plan))
    job.run(batches)
    backend = job.exchange_backend
    assert backend.transients == 2
    assert backend.retries == 3  # 2 + 1 failed attempts, all retried
    assert _counts(job) == _counts(ref)
    assert not job.recoveries  # retries absorbed everything


def test_transient_past_budget_escalates_to_loss():
    plan = FaultPlan(faults=(LaneFault(2, 0, "transient", failures=5),),
                     max_retries=2)
    job = StreamingJob(dr=DRConfig(imbalance_trigger=1e9),
                       exchange_backend=FaultyBackend("dense", plan))
    with pytest.raises(WorkerLostError):
        job.run(_batches())  # snapshot_interval=0: loss propagates


def test_latency_fault_reports_straggle():
    plan = FaultPlan(faults=(LaneFault(1, 0, "latency",
                                       delay_s=0.002, span=3),))
    job = StreamingJob(dr=DRConfig(imbalance_trigger=1e9),
                       exchange_backend=FaultyBackend("dense", plan))
    job.run(_batches(6))
    assert job.exchange_backend.injected_sleep_s >= 0.005
    # the seam's report drained into telemetry each safe point
    assert job.exchange_backend.drain_report() == {}


# ---------------------------------------------------------------------------
# zero-loss recovery (W=1: restore + replay in place)
# ---------------------------------------------------------------------------


def test_kill_without_snapshots_propagates():
    plan = FaultPlan(faults=(LaneFault(3, 0, "kill"),))
    job = StreamingJob(dr=DRConfig(imbalance_trigger=1e9),
                       exchange_backend=FaultyBackend("dense", plan))
    with pytest.raises(WorkerLostError):
        job.run(_batches())


@pytest.mark.parametrize("kill_tick,interval", [(4, 3), (2, 1), (6, 5)])
def test_kill_recovery_is_zero_loss(kill_tick, interval):
    batches = _batches()
    ref = StreamingJob(dr=DRConfig(imbalance_trigger=1e9), mesh=_mesh1())
    ref.run(batches)
    plan = FaultPlan(faults=(LaneFault(kill_tick, 0, "kill"),))
    job = StreamingJob(
        dr=DRConfig(imbalance_trigger=1e9, snapshot_interval=interval),
        exchange_backend=FaultyBackend("dense", plan), mesh=_mesh1())
    job.run(batches)
    assert len(job.recoveries) == 1
    rec = job.recoveries[0]
    assert rec.kind == "restart"  # single worker: restore+replay in place
    assert rec.wall_s > 0.0
    assert _counts(job) == _counts(ref), "recovery lost or duplicated rows"


def test_double_kill_during_replay_still_zero_loss():
    """A second loss while replaying the gap re-enters recovery with the
    same snapshot and buffer — the protocol is idempotent under repeated
    failure until the retry budget runs out."""
    batches = _batches()
    ref = StreamingJob(dr=DRConfig(imbalance_trigger=1e9), mesh=_mesh1())
    ref.run(batches)
    plan = FaultPlan(faults=(LaneFault(4, 0, "kill"),
                             LaneFault(6, 0, "kill")))
    job = StreamingJob(
        dr=DRConfig(imbalance_trigger=1e9, snapshot_interval=3),
        exchange_backend=FaultyBackend("dense", plan), mesh=_mesh1())
    job.run(batches)
    assert len(job.recoveries) == 2
    assert _counts(job) == _counts(ref)


def test_seed_determinism_same_plan_same_trajectory():
    """Same FaultPlan seed -> same decision trajectory and same recovery
    record, run to run — the chaos tests' reproducibility contract."""
    batches = _batches()
    plan = FaultPlan.generate(21, num_lanes=1, ticks=10,
                              latency_rate=0.3, transient_rate=0.2,
                              delay_s=0.001, kill_at=(6, 0))
    runs = []
    for _ in range(2):
        job = StreamingJob(
            dr=DRConfig(imbalance_trigger=1e9, snapshot_interval=2),
            exchange_backend=FaultyBackend("dense", plan))
        ms = job.run(batches)
        runs.append((_trajectory(ms), _counts(job),
                     [(r.lane, r.kind, r.replayed) for r in job.recoveries]))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# lane health -> typed actions (DRMaster.evaluate, synthetic signals)
# ---------------------------------------------------------------------------


def _health_cfg(**kw):
    kw.setdefault("health_enabled", True)
    kw.setdefault("health_straggler_ms", 50.0)
    kw.setdefault("health_failure_threshold", 3)
    kw.setdefault("health_patience", 2)
    kw.setdefault("imbalance_trigger", 1e9)
    return DRConfig(**kw)


def _sig(w=4, straggle=None, retries=None):
    return Signals(loads=np.ones(w), num_workers=w, at_safe_point=True,
                   lane_straggle_s=straggle, lane_retries=retries)


def test_health_quarantine_after_patience():
    drm = DRMaster(uniform_partitioner(4, 64, 0), _health_cfg())
    s = np.zeros(4)
    s[2] = 0.2  # 200ms straggle per window on lane 2
    first = drm.evaluate(_sig(straggle=s))
    assert not isinstance(first, Quarantine)  # patience holds one window
    second = drm.evaluate(_sig(straggle=s))
    assert isinstance(second, Quarantine)
    assert second.lane == 2
    assert second.straggle_ms >= 50.0
    assert second.est_migration > 0.0  # the fold is priced, not free
    assert drm.quarantined and drm.quarantined[0][0] == 2


def test_health_evict_on_consecutive_failures():
    drm = DRMaster(uniform_partitioner(4, 64, 0), _health_cfg())
    r = np.zeros(4, np.int64)
    r[1] = 2
    acts = [drm.evaluate(_sig(retries=r)) for _ in range(4)]
    evicts = [a for a in acts if isinstance(a, Evict)]
    assert len(evicts) == 1 and evicts[0].lane == 1
    assert evicts[0].failures >= 3
    assert not drm.quarantined  # evict is permanent, nothing parked


def test_health_failure_streak_resets_on_clean_window():
    drm = DRMaster(uniform_partitioner(4, 64, 0), _health_cfg())
    r = np.zeros(4, np.int64)
    r[1] = 1
    drm.evaluate(_sig(retries=r))
    drm.evaluate(_sig(retries=r))
    drm.evaluate(_sig())  # clean window: failures must be *consecutive*
    acts = [drm.evaluate(_sig(retries=r)) for _ in range(2)]
    assert not any(isinstance(a, Evict) for a in acts)


def test_health_recover_probe_after_timer():
    drm = DRMaster(uniform_partitioner(4, 64, 0),
                   _health_cfg(health_recover_after=2))
    s = np.zeros(4)
    s[0] = 0.2
    drm.evaluate(_sig(straggle=s))
    q = drm.evaluate(_sig(straggle=s))
    assert isinstance(q, Quarantine)
    acts = [drm.evaluate(_sig(w=3)) for _ in range(3)]
    recs = [a for a in acts if isinstance(a, Recover)]
    assert len(recs) == 1 and recs[0].lane == 0
    assert not drm.quarantined


def test_health_no_recover_without_timer():
    drm = DRMaster(uniform_partitioner(4, 64, 0),
                   _health_cfg(health_recover_after=0))
    s = np.zeros(4)
    s[0] = 0.2
    drm.evaluate(_sig(straggle=s))
    assert isinstance(drm.evaluate(_sig(straggle=s)), Quarantine)
    acts = [drm.evaluate(_sig(w=3)) for _ in range(4)]
    assert not any(isinstance(a, Recover) for a in acts)
    assert drm.quarantined  # parked forever until an explicit policy


def test_health_single_worker_never_folds():
    drm = DRMaster(uniform_partitioner(1, 64, 0), _health_cfg())
    s = np.asarray([0.5])
    for _ in range(4):
        a = drm.evaluate(_sig(w=1, straggle=s))
        assert not isinstance(a, (Quarantine, Evict))


def test_health_state_rides_snapshots():
    drm = DRMaster(uniform_partitioner(4, 64, 0),
                   _health_cfg(health_recover_after=4))
    s = np.zeros(4)
    s[3] = 0.2
    drm.evaluate(_sig(straggle=s))
    drm.evaluate(_sig(straggle=s))
    assert drm.quarantined
    restored = DRMaster.restore(drm.snapshot(), drm.config)
    assert restored.lane_health is not None
    assert restored.lane_health.num_lanes == drm.lane_health.num_lanes
    np.testing.assert_allclose(restored.lane_health.wall_ewma,
                               drm.lane_health.wall_ewma)
    assert restored.quarantined == drm.quarantined
    assert restored.last_health_action == drm.last_health_action


def test_legacy_snapshot_without_health_keys_restores():
    drm = DRMaster(uniform_partitioner(4, 64, 0), _health_cfg())
    snap = drm.snapshot()  # health layer never observed: no health keys
    assert not any(k.startswith("health_") for k in snap)
    restored = DRMaster.restore(snap, drm.config)
    assert restored.lane_health is None
    assert restored.quarantined == []


def test_note_lost_records_forced_eviction():
    drm = DRMaster(uniform_partitioner(4, 64, 0), _health_cfg())
    drm.evaluate(_sig())
    before = drm.batches_seen
    drm.note_lost(2, reason="worker lost on lane 2")
    assert drm.batches_seen == before + 1
    assert drm.lane_health is None  # stale labels dropped; rebuilt next window
    assert any(h.get("health", (None,))[0] == "evict"
               for h in drm.history if "health" in h)


# ---------------------------------------------------------------------------
# satellites: DRConfig validation, telemetry wall hardening
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(grow_trigger=1.2, shrink_trigger=1.3),
    dict(grow_trigger=1.2, shrink_trigger=1.2),
    dict(backend_ragged_below=0.9, backend_dense_above=0.5),
    dict(split_trigger=0.1, unsplit_trigger=0.2),
    dict(resize_cooldown=-1),
    dict(health_patience=-1),
    dict(health_cooldown=-2),
    dict(health_recover_after=-1),
    dict(snapshot_interval=-3),
    dict(health_failure_threshold=0),
    dict(health_straggler_ms=-5.0),
    dict(target_throughput=-1.0),
])
def test_drconfig_rejects_misconfiguration(kw):
    with pytest.raises(ValueError):
        DRConfig(**kw)


def test_drconfig_valid_defaults_construct():
    DRConfig()
    DRConfig(health_enabled=True, snapshot_interval=5)


def test_telemetry_clamps_degenerate_walls():
    t = Telemetry("test")
    t.record_exchange(ExchangeStats(rows=10, wall_s=float("nan"),
                                    backend="dense"))
    t.record_exchange(ExchangeStats(rows=10, wall_s=-0.5, backend="dense"))
    t.record_exchange(ExchangeStats(rows=10, wall_s=float("inf"),
                                    backend="dense"))
    t.record_exchange(ExchangeStats(rows=10, wall_s=0.25, backend="dense"))
    sig = t.snapshot(loads=np.ones(2))
    assert sig.degenerate_walls == 3
    assert sig.exchange_wall_s == 0.25  # poison clamped, clean sample kept
    assert t.wall_ewma["dense"] == 0.25  # EWMA fed only the clean sample
    assert t.degenerate_walls_total == 3
    # counter survives window resets
    t.record_exchange(ExchangeStats(rows=1, wall_s=float("nan")))
    assert t.snapshot(loads=np.ones(2)).degenerate_walls == 1
    assert t.degenerate_walls_total == 4


def test_telemetry_record_fault_grows_vectors():
    t = Telemetry("test")
    t.record_fault(2, straggle_s=0.1, retries=1)
    t.record_fault(0, straggle_s=0.05)
    t.record_fault(2, retries=2)
    sig = t.snapshot(loads=np.ones(3))
    np.testing.assert_allclose(sig.lane_straggle_s, [0.05, 0.0, 0.1])
    np.testing.assert_array_equal(sig.lane_retries, [0, 0, 3])
    # next window starts clean
    assert t.snapshot(loads=np.ones(3)).lane_straggle_s is None
