"""Exchange vocabulary: the *what* of a routed exchange, backend-free.

``ExchangeSpec`` describes the static shape of one exchange (lanes x
capacity over an optional mesh axis); ``Payload``/``SendInfo``/
``ExchangeResult`` describe what travels through it.  The *how* — which
transport moves the buffers — lives in :mod:`repro.exchange.backends`;
nothing in this module touches a collective.

Vocabulary:

* **lane** — one destination of the exchange: a worker shard for an
  all-to-all, or a local bucket (e.g. an expert) for a pure dispatch.
* **slot** — a record's stable rank within its lane (``dispatch_count``),
  which makes the scatter into the ``[L, capacity]`` send buffer
  collision-free.
* **capacity** — static rows per lane.  XLA collectives need static shapes,
  so lanes are padded to ``capacity`` and anything beyond it is *counted*
  (never silently lost) in ``SendInfo.overflow`` — per lane in
  ``SendInfo.lane_overflow``, summed in ``SendInfo.overflow``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DISTANCE_CLASSES",
    "ExchangeSpec",
    "ExchangeStats",
    "ExchangeTopology",
    "Payload",
    "SendInfo",
    "ExchangeResult",
    "take_from",
]

# distance classes a lane can sit at, relative to the sending worker:
# 0 = the worker itself (nothing crosses a link), 1 = another lane on the
# same host (fast interconnect), 2 = a lane on another host (slow tier)
DISTANCE_CLASSES = 3


@functools.lru_cache(maxsize=64)
def _class_tables(num_lanes: int, lanes_per_host: int):
    """Static numpy lookups for one (L, G) topology, computed once and
    cached — jitted steps close over these instead of rebuilding them per
    batch.  Returns ``(class_matrix, class_lane_counts, class_onehot)``:

    * ``class_matrix`` — int8[L, L]: distance class of lane ``j`` as seen
      from worker ``i`` (0 self, 1 same host ``i // G == j // G``, 2 other
      host),
    * ``class_lane_counts`` — int32[L, C]: how many lanes of each class
      worker ``i`` sees,
    * ``class_onehot`` — int32[L, C, L]: per-worker one-hot masks, so a
      per-class reduction of a per-lane vector is one matmul.
    """
    lanes = np.arange(num_lanes)
    host = lanes // max(lanes_per_host, 1)
    cm = np.where(host[:, None] == host[None, :], 1, 2).astype(np.int8)
    np.fill_diagonal(cm, 0)
    onehot = np.stack(
        [(cm == c).astype(np.int32) for c in range(DISTANCE_CLASSES)], axis=1
    )  # [L, C, L]
    counts = onehot.sum(axis=2).astype(np.int32)  # [L, C]
    for a in (cm, onehot, counts):
        a.setflags(write=False)
    return cm, counts, onehot


@dataclasses.dataclass(frozen=True)
class ExchangeTopology:
    """Lane -> distance-class map for one exchange: which lanes share the
    sender's host and what each distance class costs.

    Lanes are host-major (lane ``j`` lives on host ``j // lanes_per_host``)
    — the mesh builders' device order, see
    :func:`repro.launch.mesh.exchange_topology_of`.  ``class_weights`` price
    one row crossing each distance class (self, intra-host, inter-host) and
    feed :func:`repro.core.migration.exchange_lane_cost`; the default makes
    an inter-host row 10x an intra-host one (the usual DCN vs. ICI gap) and
    a same-worker row free.

    Hashable (only ints and a tuple), so it rides ``ExchangeSpec`` through
    jit closures; the per-lane class tables are cached numpy constants
    (:func:`_class_tables`) computed once per (L, G), not per batch.
    """

    num_lanes: int
    lanes_per_host: int
    class_weights: tuple[float, ...] = (0.0, 1.0, 10.0)

    def __post_init__(self):
        object.__setattr__(
            self, "class_weights", tuple(float(w) for w in self.class_weights)
        )
        assert self.num_lanes >= 1 and self.lanes_per_host >= 1, self
        assert len(self.class_weights) == DISTANCE_CLASSES, self.class_weights

    @property
    def num_hosts(self) -> int:
        return -(-self.num_lanes // self.lanes_per_host)

    @property
    def class_matrix(self) -> np.ndarray:
        """int8[L, L] — distance class of lane ``j`` seen from worker ``i``."""
        return _class_tables(self.num_lanes, self.lanes_per_host)[0]

    @property
    def class_lane_counts(self) -> np.ndarray:
        """int32[L, C] — lanes of each class seen from worker ``i``."""
        return _class_tables(self.num_lanes, self.lanes_per_host)[1]

    @property
    def class_onehot(self) -> np.ndarray:
        """int32[L, C, L] — per-worker one-hot class masks."""
        return _class_tables(self.num_lanes, self.lanes_per_host)[2]

    def weight_matrix(self, num_lanes: int | None = None) -> np.ndarray:
        """float64[n, n] per-(src, dst) row weights — ``class_weights``
        broadcast through the class matrix.  ``num_lanes`` re-derives for a
        different lane count (a worker-folded transfer matrix narrower than
        the partition count) keeping ``lanes_per_host``."""
        topo = self if num_lanes is None else self.resized(num_lanes)
        return np.asarray(topo.class_weights, np.float64)[topo.class_matrix]

    def resized(self, num_lanes: int) -> "ExchangeTopology":
        """Re-derive for a grown/shrunk lane count: hosts keep their width
        (``lanes_per_host``), so an 8-lane/4-per-host topology shrunk to 4
        lanes is one host, grown to 16 is four."""
        return dataclasses.replace(self, num_lanes=int(num_lanes))


@dataclasses.dataclass(frozen=True)
class ExchangeStats:
    """Everything the control plane learns from one exchange, in one record.

    Constructed *by the plane* (:meth:`ExchangeResult.stats`, the shuffle's
    ``shuffle_stats`` / ``migrate_stats`` helpers) and handed whole to
    ``Telemetry.record_exchange(stats)`` — consumers never assemble the
    fields themselves, so a new measurement (``replica_rows`` here) does not
    ripple through every call site.

    * ``rows`` — rows the active transport measured moving (shipped).
    * ``padded_rows`` — rows the exchange *provisioned* (``spec.rows``);
      ``None`` means unpadded (= ``rows``).
    * ``occupied_rows`` — rows actually live in the shipped lanes; ``None``
      means fully occupied (= ``rows``).
    * ``lane_overflow`` — per-lane capacity drops (int array) or ``None``.
    * ``count_wall_s`` / ``ship_wall_s`` / ``hidden_wall_s`` — split-phase
      wall breakdown (blocking count, blocking ship, ship wall hidden
      behind host work).
    * ``backend`` — transport name the measurements belong to.
    * ``replica_rows`` — rows landed per partition from *split* hot keys
      (int array) or ``None`` when no key is split.
    * ``rows_by_class`` — ``rows`` split by lane distance class
      (int array of length :data:`DISTANCE_CLASSES`: self / intra-host /
      inter-host) or ``None`` when the exchange carried no topology.
    """

    rows: int
    wall_s: float = 0.0
    padded_rows: int | None = None
    occupied_rows: int | None = None
    lane_overflow: np.ndarray | None = None
    count_wall_s: float | None = None
    ship_wall_s: float | None = None
    hidden_wall_s: float | None = None
    backend: str | None = None
    replica_rows: np.ndarray | None = None
    rows_by_class: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """Static shape of one exchange: ``num_lanes`` destinations of
    ``capacity`` rows each, optionally crossed over mesh ``axis``.

    ``axis=None`` is a *local* exchange: records are bucketized into
    ``[num_lanes, capacity]`` buffers with no collective (MoE's second
    dispatch hop — per-expert batching on the receiving shard).

    ``topology`` localizes the lanes (:class:`ExchangeTopology`): which
    lanes share the sender's host and what each distance class costs.
    ``None`` (the default) is the flat pre-topology world — every backend
    behaves exactly as before and no per-class accounting is produced.
    """

    num_lanes: int
    capacity: int
    axis: str | None = None
    topology: ExchangeTopology | None = None

    def __post_init__(self):
        if self.topology is not None and self.topology.num_lanes != self.num_lanes:
            object.__setattr__(
                self, "topology", self.topology.resized(self.num_lanes)
            )

    @property
    def rows(self) -> int:
        """Rows one exchange call *provisions* per worker
        (``num_lanes * capacity``) — the static accounting unit the control
        plane's telemetry records per call as the padded side of
        ``Telemetry.record_exchange``; the active backend's measured
        ``shipped_rows`` is the other side."""
        return self.num_lanes * self.capacity

    def resized(
        self, *, num_lanes: int | None = None, capacity: int | None = None
    ) -> "ExchangeSpec":
        """Re-derive the spec for a resized topology.

        Elastic resize (changing the lane count after a worker grow/shrink)
        and re-capacitating (a migration whose planned peak transfer differs
        from the last one) are both one-spec changes: everything downstream —
        bucketize buffers, the collective, unpack — follows from the spec.
        A carried :class:`ExchangeTopology` survives the resize: it is
        re-derived for the new lane count keeping ``lanes_per_host`` (see
        :meth:`ExchangeTopology.resized` — ``__post_init__`` snaps it).
        """
        return dataclasses.replace(
            self,
            num_lanes=self.num_lanes if num_lanes is None else int(num_lanes),
            capacity=self.capacity if capacity is None else int(capacity),
        )


class Payload(NamedTuple):
    """One array travelling through the exchange; ``fill`` pads empty slots."""

    data: jax.Array  # [n, ...] one row per record
    fill: int | float = 0


class SendInfo(NamedTuple):
    """Send-side bookkeeping — enough to reverse the exchange.

    ``take_from(buffers, send)`` gathers each record's row back out of
    lane-major buffers (the MoE combine / any request-response pattern).
    ``lane_overflow`` localizes capacity drops to the lane that filled up;
    records whose lane fell outside ``[0, num_lanes)`` have no lane to
    charge, so they appear in the summed ``overflow`` only.
    """

    lane: jax.Array           # int32[n] destination lane per record
    slot: jax.Array           # int32[n] rank within lane, -1 for invalid
    ok: jax.Array             # bool[n]  accepted into the send buffer
    overflow: jax.Array       # int32[]  local records dropped (all causes)
    lane_overflow: jax.Array = None  # int32[L] capacity drops per lane


class ExchangeResult(NamedTuple):
    valid: jax.Array     # bool[L, capacity] occupancy of the (received) buffer
    payloads: tuple      # each [L, capacity, ...], same order as the inputs
    send: SendInfo
    # rows the transport actually moved for this worker: the dense backend
    # ships the whole padded buffer (L * capacity), the ragged backend its
    # measured occupancy, a local exchange nothing.  0 until the collective
    # has run (a bare bucketize ships nothing).
    shipped_rows: jax.Array = None  # int32[]
    # count bookkeeping a request-response pattern reuses: ``lane_counts``
    # is the buffer occupancy this worker *sent* per lane (min(count, cap)),
    # ``recv_counts`` what each peer sent it — the ragged transport's
    # phase-1 exchange.  A response hop riding the same lanes backward
    # (``backhaul``) needs no second count phase: its send occupancy is
    # ``recv_counts`` and its receive sizes are ``lane_counts``.
    lane_counts: jax.Array = None  # int32[L] rows sent per lane
    recv_counts: jax.Array = None  # int32[L] rows received per peer
    # static per-payload pad values (the Payload.fill each buffer was built
    # with) so a ragged transport can initialize its receive buffers
    # bit-identically to what the dense collective would have shipped
    fills: tuple = ()
    # ``shipped_rows`` split by lane distance class (int32[DISTANCE_CLASSES]:
    # self / intra-host / inter-host), stamped by the backend's start phase
    # when the spec carries an ExchangeTopology; None on a flat spec
    shipped_rows_by_class: jax.Array = None

    def unpack(self):
        """Flatten lane-major buffers to record-major ``[L*capacity, ...]``."""
        l, c = self.valid.shape
        flat = tuple(p.reshape((l * c,) + p.shape[2:]) for p in self.payloads)
        return self.valid.reshape(-1), flat

    def stats(
        self,
        spec: ExchangeSpec | None = None,
        *,
        wall_s: float = 0.0,
        count_wall_s: float | None = None,
        ship_wall_s: float | None = None,
        hidden_wall_s: float | None = None,
        backend: str | None = None,
        replica_rows: np.ndarray | None = None,
    ) -> ExchangeStats:
        """The plane-constructed telemetry record for this exchange.

        Pulls every measurement the result already carries — shipped rows,
        lane occupancy, per-lane overflow — so the consumer only supplies
        what the plane cannot know: wall clocks, the backend name, and the
        host-side split accounting.  Blocks on the device scalars.
        """
        rows = int(self.shipped_rows) if self.shipped_rows is not None else 0
        by_class = (None if self.shipped_rows_by_class is None
                    else np.asarray(self.shipped_rows_by_class, np.int64))
        if self.lane_counts is not None:
            occupied = int(np.sum(np.asarray(self.lane_counts)))
        else:
            occupied = int(np.sum(np.asarray(self.valid)))
        padded = spec.rows if spec is not None else int(self.valid.size)
        lane_ov = self.send.lane_overflow
        if lane_ov is not None:
            lane_ov = np.asarray(lane_ov)
        return ExchangeStats(
            rows=rows,
            wall_s=wall_s,
            padded_rows=padded,
            occupied_rows=occupied,
            lane_overflow=lane_ov,
            count_wall_s=count_wall_s,
            ship_wall_s=ship_wall_s,
            hidden_wall_s=hidden_wall_s,
            backend=backend,
            replica_rows=replica_rows,
            rows_by_class=by_class,
        )


def take_from(buffers: jax.Array, send: SendInfo) -> jax.Array:
    """Gather each record's row from ``[L, capacity, ...]`` buffers, zeroing
    records that never made it into a slot (the reverse of ``bucketize``)."""
    rows = buffers[send.lane, jnp.where(send.ok, send.slot, 0)]
    mask = send.ok.reshape(send.ok.shape + (1,) * (rows.ndim - 1))
    return jnp.where(mask, rows, 0)
