"""MoE: routing, KIP placement, and dispatch-vs-oracle equivalence."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoESpec
from repro.models.modules import Policy
from repro.moe.kip_placement import (
    ExpertPlacement,
    PlacementController,
    apply_placement_to_weights,
    placement_from_assignment,
)
from repro.moe.layer import init_moe, moe_ref


def test_moe_ref_shapes_and_counts():
    spec = MoESpec(num_experts=8, top_k=2, d_ff_expert=32, shared_expert=True)
    p = init_moe(jax.random.PRNGKey(0), 16, spec, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out = moe_ref(p, x, spec, "swiglu", Policy())
    assert out.y.shape == x.shape
    assert float(out.counts.sum()) == 2 * 8 * 2  # T * top_k
    assert np.isfinite(float(out.aux_loss))


class TestPlacement:
    def test_identity(self):
        pl = ExpertPlacement.identity(8, 4)
        np.testing.assert_array_equal(pl.place, np.arange(8))
        np.testing.assert_array_equal(pl.shard_of(np.arange(8)), np.arange(8) // 2)

    def test_controller_balances_skewed_loads(self):
        ctl = PlacementController(16, 4, trigger=1.05)
        loads = np.ones(16)
        loads[0], loads[1] = 20.0, 15.0  # two hot experts on shard 0
        for _ in range(3):
            ctl.observe(loads)
        before = ctl.shard_loads(ctl.loads_ewma)
        changed, placement, perm = ctl.maybe_update()
        after = ctl.shard_loads(ctl.loads_ewma)
        assert changed
        assert after.max() / after.mean() < before.max() / before.mean()
        # placement is a proper permutation with exactly E/N slots per shard
        assert sorted(placement.place.tolist()) == list(range(16))
        shards = placement.inv_place // 4
        assert np.bincount(shards, minlength=4).tolist() == [4, 4, 4, 4]

    def test_migration_minimal_when_balanced(self):
        ctl = PlacementController(16, 4, trigger=1.15)
        ctl.observe(np.ones(16))
        changed, _, perm = ctl.maybe_update()
        assert not changed
        np.testing.assert_array_equal(perm, np.arange(16))

    def test_weight_permutation_follows_placement(self):
        spec = MoESpec(num_experts=8, top_k=1, d_ff_expert=8, shared_expert=False)
        p = init_moe(jax.random.PRNGKey(0), 4, spec, "swiglu", jnp.float32)
        perm = np.array([3, 1, 2, 0, 4, 5, 6, 7], np.int32)
        p2 = apply_placement_to_weights(p, perm)
        np.testing.assert_allclose(np.asarray(p2["wi"][0]), np.asarray(p["wi"][3]))
        np.testing.assert_allclose(np.asarray(p2["wo"][3]), np.asarray(p["wo"][0]))
        np.testing.assert_allclose(np.asarray(p2["router"]), np.asarray(p["router"]))

    def test_repeated_updates_converge(self):
        rng = np.random.default_rng(0)
        ctl = PlacementController(32, 8, trigger=1.1)
        loads = rng.zipf(1.5, 32).astype(float)
        total_moved = 0
        for _ in range(6):
            ctl.observe(loads)
            changed, _, perm = ctl.maybe_update()
            total_moved += int((perm != np.arange(32)).sum())
        # after converging, further updates move nothing
        ctl.observe(loads)
        changed, _, perm = ctl.maybe_update()
        assert int((perm != np.arange(32)).sum()) == 0


DISPATCH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.base import MoESpec
    from repro.models.modules import Policy
    from repro.moe.layer import init_moe, moe_ref, moe_apply
    from repro.compat import set_mesh

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = MoESpec(num_experts=8, top_k=2, d_ff_expert=32, shared_expert=True,
                   capacity_factor=8.0)  # generous: nothing drops
    d = 16
    p = init_moe(jax.random.PRNGKey(0), d, spec, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))
    inv = jnp.arange(8, dtype=jnp.int32)

    pol_ref = Policy()
    want = moe_ref(p, x, spec, "swiglu", pol_ref, inv)

    pol = Policy(mesh=mesh, dp_axes=("data",), tp_axis="model")
    with set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
        ps = jax.device_put(p, NamedSharding(mesh, P()))
        ps["wi"] = jax.device_put(p["wi"], NamedSharding(mesh, P("model")))
        ps["wo"] = jax.device_put(p["wo"], NamedSharding(mesh, P("model")))
        got = jax.jit(lambda pp, xx: moe_apply(pp, xx, spec, "swiglu", pol, inv))(ps, xs)

    np.testing.assert_allclose(np.asarray(got.y), np.asarray(want.y), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got.counts), np.asarray(want.counts))
    assert float(got.overflow) == 0.0
    # skewed placement: put the two hottest experts on the same shard, then
    # verify a permuted placement still matches the oracle exactly
    perm = jnp.asarray([7, 1, 2, 3, 4, 5, 6, 0], jnp.int32)
    inv2 = jnp.zeros(8, jnp.int32).at[perm].set(jnp.arange(8, dtype=jnp.int32))
    from repro.moe.kip_placement import apply_placement_to_weights
    with set_mesh(mesh):
        p3 = dict(ps)
        p3["wi"] = jnp.take(ps["wi"], perm, axis=0)
        p3["wo"] = jnp.take(ps["wo"], perm, axis=0)
        got2 = jax.jit(lambda pp, xx: moe_apply(pp, xx, spec, "swiglu", pol, inv2))(p3, xs)
    np.testing.assert_allclose(np.asarray(got2.y), np.asarray(want.y), rtol=2e-5, atol=2e-5)
    print("MOE-DISPATCH-OK")
    """
)


@pytest.mark.slow
def test_dispatch_matches_oracle_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", DISPATCH_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "MOE-DISPATCH-OK" in out.stdout, out.stdout + "\n" + out.stderr


class TestReplication:
    def test_replicated_assignment_beats_partitioning_floor(self):
        """A 30%-load expert caps pure partitioning at N*f1; replication
        splits it below the floor (the beyond-paper serving feature)."""
        from repro.moe.kip_placement import replicated_assignment

        loads = np.ones(16)
        loads[0] = 8.0  # ~33% of traffic on one expert -> floor ~5.3 @ 16 shards
        owner, shard_of = replicated_assignment(loads, n_shards=8, replicas=8)
        assert len(owner) == 24 and sorted(set(owner.tolist())) == list(range(16))
        counts = np.bincount(owner, minlength=16)
        assert counts[0] >= 3  # the hot expert got extra replicas
        rel = loads / loads.sum()
        eff = (rel / counts)[owner]
        sl = np.zeros(8)
        np.add.at(sl, shard_of, eff)
        floor_unreplicated = 8 * rel.max()
        assert sl.max() / sl.mean() < floor_unreplicated
        # every shard has exactly 3 slots
        assert np.bincount(shard_of, minlength=8).tolist() == [3] * 8
