"""Micro-batch streaming runtime with on-the-fly Dynamic Repartitioning.

The job graph is the paper's canonical stateful pipeline::

    source -> map -> [shuffle by key] -> stateful reduce (keyed state)

Per micro-batch the runtime executes the jitted shuffle step (which also
emits the DRW histograms and global loads), folds received records into the
keyed state, then gives the DRM a safe point.  The job is a thin driver for
the control plane (``repro.control``): telemetry gathered during normal
work (loads, overflow, exchange rows + wall time, throughput) snapshots
into a ``Signals`` record, ``DRMaster.evaluate`` runs the policy stack, and
the returned typed action (``NoOp``/``Repartition``/``Resize``) is executed
here — the jitted migrate step moves the keyed state before the next batch,
the Spark-style integration; setting ``checkpoint_interval > 1`` gates
decisions on checkpoint ticks, the Flink-style integration.

Both the shuffle and the migration ride the unified exchange plane
(``repro.exchange``) on the transport ``exchange_backend`` selects — the
dense capacity-padded all-to-all or the ragged count-first one; results are
bit-identical, only the traffic differs, and the DRM prices candidate
repartitions with the *same* backend's sizing rule.  Migration lanes are
sized from the host-side plan (``plan_migration`` + ``migration_capacity``):
the all-to-all ships the planned peak transfer x slack instead of
``W * state_capacity`` rows.  Lane capacities are rounded up to powers of
two so repeated repartitions reuse a handful of jitted migrate steps
instead of recompiling per plan.

**Elastic resize** is the same mechanism one level up: changing the *number*
of partitions (the job's logical worker count) instead of their contents.
``resize(n)`` requests it explicitly; with ``DRConfig(elastic=True)`` the
DRM's ``decide_resize`` policy requests it on sustained imbalance.  Either
way it fires only at a checkpoint safe point: the partitioner is re-planned
cross-size (``DRMaster.replan_resize`` — shrink folds removed partitions,
grow re-bins hosts onto the new ones), the state ships through a migrate
step whose lanes are sized by the *cross-size* plan, the shuffle step is
rebuilt for the new topology, and the new topology lands in
``BatchMetrics`` and snapshots so a restore resumes resized.

**The transport is an actuator too**: with ``DRConfig(auto_backend=True)``
the ``BackendPolicy`` watches the measured lane occupancy
(``Signals.exchange_padding_fraction``) and flips dense <-> ragged at a
safe point when the padded lanes run empty (or the count phase stops
paying).  The job rebuilds its jitted steps for the new backend exactly
like a resize rebuilds them for a new lane count, the switch lands in the
``DecisionLog``/``BatchMetrics``, and snapshots carry the active backend so
a restore resumes on the switched transport.

**Latency-hiding overlap** (``DRConfig.overlap_exchange``, on by default;
``REPRO_DISABLE_OVERLAP=1`` forces serial): the shuffle step is
split-phase (``repro.core.shuffle``), and every control-plane input —
loads, DRW histograms, overflow, shipped rows — comes out of the *start*
phase (route + bucketize + the transport's count phase).  The driver
therefore enqueues batch N's start, enqueues batch N-1's in-flight row
ship + state merge behind it, and blocks only on batch N's start outputs:
the host-side decision section (telemetry, sketch update, policy stack)
runs while the device ships batch N-1's rows.  Because devices execute
their queue in order and the serial step is literally the two phases
traced back to back, the overlapped trajectory is bit-identical to the
serial one — same actions, same state, same overflow.  State only
materializes at *drains*: before any taken action (a migration must see
the previous batch merged), at ``snapshot``/``state_count``/direct state
reads, all of which complete the in-flight finish first.  A repartition's
own row ship is likewise left in flight across the safe point — only its
count phase blocks.  Per-phase walls land in telemetry
(``Signals.exchange_count_wall_s`` / ``exchange_ship_wall_s`` /
``exchange_hidden_wall_s`` -> ``overlap_fraction``); the hidden wall of a
batch is recorded when the batch ends, so it lands one window late.

**Depth-2 pipeline** (``DRConfig.pipeline_depth = 2``; overlap must be
active): ``run`` gives the driver one batch of lookahead, and
``process_batch`` enqueues the *next* batch's route + bucketize + count
phase right after this batch's count sync — behind the in-flight ship —
so at steady state two stages live on the device queue: batch N's ship +
merge and batch N+1's start.  The send buffers ping-pong between two
persistent sets (``repro.core.shuffle``), so the pipeline re-fills
buffers in place instead of allocating per batch.  The staged start
routes with today's partitioner; when the safe point takes an action
(resize / repartition / split / backend switch) the driver drains both
in-flight stages, discards the staged start, and the pre-routed batch
replays under the new partitioner when it arrives — trajectories stay
bit-identical to the serial driver.  ``REPRO_DISABLE_OVERLAP=1`` forces
serial whatever the configured depth.

**Host-sync discipline**: every device->host read in the driver routes
through :func:`repro.compat.host_fetch` inside a
:func:`repro.compat.safe_point` region — the count-phase sync and the
decision section it feeds.  Between safe points the driver performs no
blocking transfers; ``compat.host_sync_count()`` stays flat across
steady-state batches (the bench gate ``fig6/host_syncs_per_batch``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.compat import host_fetch, overlap_enabled, safe_point
from repro.control import (
    Evict,
    NoOp,
    Quarantine,
    Recover,
    Repartition,
    Resize,
    Split,
    SwitchBackend,
    Telemetry,
    Unsplit,
)
from repro.core.drm import DRConfig, DRMaster
from repro.core.hashing import DEFAULT_NUM_HOSTS, KEY_SENTINEL
from repro.core.migration import migration_capacity, plan_migration
from repro.core.partitioner import (
    Partitioner,
    heavy_capacity_for,
    split_replica_rows,
    uniform_partitioner,
)
from repro.core.shuffle import (
    make_migrate_step,
    make_shuffle_step,
    migrate_stats,
    shuffle_stats,
)
from repro.core.state import empty_state, merge_into
from repro.exchange import (
    ExchangeSpec,
    ExchangeStats,
    ExchangeTopology,
    FaultyBackend,
    WorkerLostError,
    resolve_backend,
)
from repro.exchange.spec import DISTANCE_CLASSES

__all__ = ["StreamingJob", "BatchMetrics", "RecoveryStats"]


@dataclasses.dataclass
class BatchMetrics:
    batch: int
    imbalance: float            # measured per-partition record imbalance
    worker_imbalance: float     # per-worker (straggler view)
    repartitioned: bool
    relative_migration: float
    overflow: int               # shuffle + migration rows dropped for capacity
    state_rows: int
    wall_time_s: float
    reason: str
    migration_rows: int = 0     # rows of all-to-all buffer a repartition exchanged
    resized: bool = False       # an elastic resize fired at this safe point
    num_partitions: int = 0     # topology after this batch (post-resize)
    migration_plan_rows: int = 0  # migration_capacity() of the plan (pre-pow2)
    action: str = "noop"        # control-plane action kind this safe point took
    shipped_rows: int = 0       # rows the backend moved this batch (per worker)
    padded_rows: int = 0        # rows the specs provisioned (per worker)
    backend: str = "dense"      # exchange backend the batch ran on
    exchange_wall_s: float = 0.0  # wall blocking on the shuffle exchange path
                                  # (overlapped batches: the count phase only
                                  # — the ship is hidden behind host work)
    overlapped: bool = False    # the batch ran the split-phase pipeline
    pipelined: bool = False     # the batch consumed a depth-2 staged start
                                # (its route ran behind the previous ship)
    overlap_fraction: float = 0.0  # hidden / (hidden + ship) wall this
                                # window (lags one batch: the hidden wall is
                                # only known at batch end); 0.0 when serial
    split_keys: int = 0         # hot keys replicated after this safe point
    shipped_rows_by_class: tuple = (0, 0, 0)  # shipped_rows split by lane
                                # distance class (self / intra-host /
                                # inter-host, per worker); zeros on flat jobs
    lanes: int = 0              # live workers after this batch (a health
                                # action or a loss shrinks this mid-stream)


@dataclasses.dataclass
class RecoveryStats:
    """One zero-loss recovery: the lane lost, how the job survived it
    (``evict`` = shrunk onto the survivors; ``restart`` = restored in place
    — the single-worker fallback), how many gap batches the replay buffer
    re-ran, the worker count after, and the end-to-end recovery wall
    (drain + restore + replay, up to the lost batch's successful retry)."""

    lane: int
    kind: str                   # "evict" | "restart"
    replayed: int
    workers: int
    wall_s: float = 0.0


def _default_mesh(axis: str = "data") -> Mesh:
    n = len(jax.devices())
    return jax.make_mesh((n,), (axis,))


class StreamingJob:
    """Long-running stateful streaming job with DR.

    ``payload_dim`` is the record payload width (the reduce below is a
    per-key vector sum — the word-count family of stateful operators).
    """

    def __init__(
        self,
        *,
        num_partitions: int | None = None,
        mesh: Mesh | None = None,
        capacity_factor: float = 2.0,
        state_capacity: int = 4096,
        payload_dim: int = 1,
        dr: DRConfig | None = None,
        dr_enabled: bool = True,
        checkpoint_interval: int = 1,
        initial: Partitioner | None = None,
        hist_k: int = 64,
        seed: int = 0,
        exchange_backend: str | object | None = None,
        topology: ExchangeTopology | None = None,
    ):
        self.mesh = mesh or _default_mesh()
        self.num_workers = self.mesh.shape["data"]
        self.num_partitions = num_partitions or self.num_workers
        assert self.num_partitions >= self.num_workers
        self.capacity_factor = capacity_factor
        self.state_capacity = state_capacity
        self.payload_dim = payload_dim
        self.dr_enabled = dr_enabled
        self.checkpoint_interval = checkpoint_interval
        self.hist_k = hist_k
        self.seed = seed
        # the exchange transport both jitted steps ride (dense / ragged);
        # the DRM gets the same backend so policy costing prices the plan
        # by what this job's transport would actually move
        self.exchange_backend = resolve_backend(exchange_backend or "dense")
        # lane locality (``exchange_topology_of(mesh)``): rides every
        # ExchangeSpec the job builds, splits shipped-row telemetry by
        # distance class, and makes the DRM's plan pricing locality-aware.
        # ``None`` keeps the flat world — everything behaves as before.
        self.exchange_topology = topology
        cfg = dr or DRConfig()
        heavy_cap = heavy_capacity_for(cfg.lam, self.num_partitions)
        part = initial or uniform_partitioner(
            self.num_partitions, DEFAULT_NUM_HOSTS, seed, heavy_capacity=heavy_cap
        )
        self.drm = DRMaster(part, cfg, exchange_backend=self.exchange_backend,
                            exchange_topology=topology)
        self.telemetry = Telemetry("stream")
        self._shuffle = None
        self._shuffle_sig = None  # (capacity, num_partitions) the step was built for
        self._shuffle_spec: ExchangeSpec | None = None  # for exchange-row accounting
        self._migrate_steps: dict[int, object] = {}  # lane capacity -> jitted step
        self._pending_resize: int | None = None
        # per-worker keyed state, stacked [W, S] / [W, S, D]
        sk, sv = empty_state(state_capacity, payload_dim)
        self._sk = jnp.tile(sk[None], (self.num_workers, 1))
        self._sv = jnp.tile(sv[None], (self.num_workers, 1, 1))
        # split-phase overlap: the previous batch's in-flight finish+merge
        # (a callable that enqueues it), the host wall start of the section
        # a pending ship is hiding behind, and the state-row count as of the
        # last drain (reading it live would sync the in-flight merge chain)
        self._inflight = None
        self._hidden_since: float | None = None
        self._last_state_rows = 0
        # depth-2 pipeline (``DRConfig.pipeline_depth == 2``): ``run`` parks
        # the lookahead batch here, ``process_batch`` stages its start behind
        # the current ship, and a taken action discards the staged route so
        # the batch replays under the new partitioner
        self._next_batch: np.ndarray | None = None
        self._staged: tuple | None = None  # (src, partitioner, step, pending, ShuffleStart)
        # least-load split routing (``DRConfig.split_least_load``): the
        # previous batch's measured per-partition loads, fed to the route at
        # safe points; None until the first batch lands (and after a resize
        # changes the vector's width)
        self._part_loads: jax.Array | None = None
        # failure domains: current -> original lane map (plan lanes are
        # original ids), quarantined (original id, device) pairs oldest
        # first, the auto-snapshot + bounded replay buffer
        # (``DRConfig.snapshot_interval``), and the recovery record
        self._lane_ids: list[int] = list(range(self.num_workers))
        self._parked: list[tuple[int, object]] = []
        self._auto_snap: dict | None = None
        self._replay: list[tuple[np.ndarray, np.ndarray | None]] = []
        self.recoveries: list[RecoveryStats] = []
        self.metrics: list[BatchMetrics] = []
        self._merge = jax.jit(jax.vmap(lambda sk, sv, bk, bv, bva: merge_into(sk, sv, bk, bv, bva)))

    # -- keyed state access (drains any in-flight exchange first) ----------
    @property
    def state_keys(self):
        self._drain_inflight()
        return self._sk

    @state_keys.setter
    def state_keys(self, v):
        self._sk = v

    @property
    def state_vals(self):
        self._drain_inflight()
        return self._sv

    @state_vals.setter
    def state_vals(self, v):
        self._sv = v

    def _overlap_active(self) -> bool:
        return self.drm.config.overlap_exchange and overlap_enabled()

    def _depth2_active(self) -> bool:
        # the env kill switch wins over the configured depth too: serial
        # means serial, whatever the pipeline was asked to do
        return self._overlap_active() and self.drm.config.pipeline_depth >= 2

    def _discard_staged(self) -> None:
        """Drop the staged lookahead start (its device work completes in the
        background; the outputs are never read).  The popped send-buffer set
        is lost to the ping-pong pool — the next start allocates fresh and
        the pool refills from drained pendings."""
        self._staged = None

    def _take_staged(self, raw_keys, has_values: bool):
        """Claim the staged start if it still routes ``raw_keys`` correctly.

        Valid only when it was staged for this exact batch (object identity
        — ``run`` hands the same array back), no caller-supplied values
        (staging assumes the implicit all-ones payload), and the partitioner
        *and* jitted step are the very objects the staged route used — a
        taken action swaps the partitioner, a resize / backend switch
        rebuilds the step, so staleness cannot slip through.  An invalid
        stage is discarded; the caller re-routes fresh (the replay)."""
        st, self._staged = self._staged, None
        if st is None:
            return None
        src, part, step, pending, res = st
        if (not has_values and src is raw_keys
                and part is self.drm.partitioner and step is self._shuffle):
            return pending, res
        return None

    def _stage_next(self, raw: np.ndarray) -> None:
        """Enqueue the lookahead batch's route + bucketize + count phase
        behind the current in-flight ship (pipeline depth 2).

        Routes with *today's* partitioner: if the safe point this overlaps
        takes an action, :meth:`_take_staged` rejects the stage and the
        batch re-routes under the new partitioner.  Skipped when the
        lookahead's capacity signature differs from the live step's — the
        rebuild must not race the batch still using it (that boundary runs
        at depth 1)."""
        n = len(raw)
        w = self.num_workers
        total = int(np.ceil(n / w)) * w
        cap = int(np.ceil(self.capacity_factor * total / w / 8.0) * 8)
        if (cap, self.num_partitions) != self._shuffle_sig:
            return
        k = np.concatenate(
            [raw, np.full(total - n, KEY_SENTINEL, np.int64)]).astype(np.int32)
        v = np.ones((len(k), self.payload_dim), np.float32)
        shuffle = self._shuffle
        pending, res = shuffle.start(
            self.drm.partitioner.tables(), jnp.asarray(k),
            jnp.asarray(v, jnp.float32), jnp.asarray(k != KEY_SENTINEL),
            self._part_loads,
        )
        self._staged = (raw, self.drm.partitioner, shuffle, pending, res)

    def _consume_inflight(self) -> None:
        """Enqueue the pending finish + merge (no sync)."""
        fin, self._inflight = self._inflight, None
        if fin is not None:
            fin()

    def _drain_inflight(self) -> None:
        """Complete the in-flight finish + merge, blocking, and account the
        un-hidden ship wall (plus whatever host wall it did hide)."""
        if self._inflight is None:
            return
        t = time.perf_counter()
        hidden = None if self._hidden_since is None else t - self._hidden_since
        self._hidden_since = None
        self._consume_inflight()
        jax.block_until_ready(self._sk)
        self.telemetry.record_exchange(ExchangeStats(
            rows=0,
            ship_wall_s=time.perf_counter() - t,
            hidden_wall_s=hidden,
        ))
        with safe_point():  # a drain IS a safe point: the fetch is sanctioned
            self._last_state_rows = int(host_fetch(
                jax.vmap(lambda k: jnp.sum(k != KEY_SENTINEL))(self._sk)
            ).sum())

    # ------------------------------------------------------------------
    def _build(self, local_n: int):
        """(Re)build the jitted shuffle step when capacity *or topology*
        changed — an elastic resize invalidates the step because the loads
        vector and heavy-table shapes follow ``num_partitions``."""
        cap = int(np.ceil(self.capacity_factor * local_n / self.num_workers / 8.0) * 8)
        sig = (cap, self.num_partitions)
        if self._shuffle is not None and sig == self._shuffle_sig:
            return
        self._shuffle_sig = sig
        self._shuffle_spec = ExchangeSpec(
            num_lanes=self.num_workers, capacity=cap, axis="data",
            topology=self.exchange_topology,
        )
        self._shuffle = make_shuffle_step(
            self.mesh,
            num_partitions=self.num_partitions,
            capacity=cap,
            hist_k=self.hist_k,
            num_hosts=self.drm.partitioner.num_hosts,
            seed=self.seed,
            backend=self.exchange_backend,
            topology=self.exchange_topology,
        )

    def _migrate_step(self, lane_capacity: int):
        """Jitted migrate step with lanes >= ``lane_capacity`` rows.

        Capacities are rounded up to the next power of two (capped at the
        full state table) so the jit cache stays small across repartitions.
        The step routes at worker granularity, so the same cache serves
        plain repartitions *and* cross-size resize migrations.
        """
        cap = 8
        while cap < min(lane_capacity, self.state_capacity):
            cap *= 2
        cap = min(cap, self.state_capacity)
        if cap not in self._migrate_steps:
            self._migrate_steps[cap] = make_migrate_step(
                self.mesh,
                state_capacity=self.state_capacity,
                num_hosts=self.drm.partitioner.num_hosts,
                seed=self.seed,
                spec=ExchangeSpec(num_lanes=self.num_workers, capacity=cap,
                                  axis="data", topology=self.exchange_topology),
                backend=self.exchange_backend,
            )
        return self._migrate_steps[cap], cap

    # ------------------------------------------------------------------
    def process_batch(self, keys: np.ndarray, values: np.ndarray | None = None) -> BatchMetrics:
        """Run one micro-batch through shuffle + stateful reduce + DR.

        With ``DRConfig.snapshot_interval > 0`` this is also the zero-loss
        recovery protocol's outer loop: an initial auto-snapshot is taken
        lazily, every processed batch lands in the bounded replay buffer,
        and a :class:`~repro.exchange.WorkerLostError` surfacing from the
        exchange seam triggers recovery — quiesce the surviving in-flight
        stages, evict the lost lane (shrinking the mesh; a single-worker
        job restarts in place), restore the last snapshot, replay the gap
        batches, then retry this batch on the surviving topology.  No row
        is lost: every batch since the snapshot either replays or retries.
        With ``snapshot_interval == 0`` a loss propagates (failure stays an
        abort, the pre-PR-10 behavior).
        """
        cfg = self.drm.config
        if cfg.snapshot_interval > 0 and self._auto_snap is None:
            # lazy initial snapshot: the zero state is trivially consistent
            self._auto_snap = self.snapshot()
            self._replay = []
        pending_rec: tuple[RecoveryStats, float] | None = None
        replaying: list = []  # gap batches still to re-run before this one
        budget = self.num_workers + 1
        while True:
            try:
                while replaying:
                    rk, rv = replaying[0]
                    self._process_batch_inner(rk, rv)
                    replaying.pop(0)
                    # a completed batch is progress: the backstop budget
                    # guards against recovery that can't advance, not
                    # against a stream that keeps losing (distinct) workers
                    budget = self.num_workers + 1
                m = self._process_batch_inner(keys, values)
                break
            except WorkerLostError as loss:
                budget -= 1
                if budget <= 0 or cfg.snapshot_interval <= 0:
                    raise
                t_rec = time.perf_counter()
                kind = self._recover_from_loss(loss)
                replaying = list(self._replay)
                rec = RecoveryStats(lane=loss.lane, kind=kind,
                                    replayed=len(replaying),
                                    workers=self.num_workers)
                self.recoveries.append(rec)
                pending_rec = (rec, t_rec)
        if pending_rec is not None:
            rec, t_rec = pending_rec
            rec.wall_s = time.perf_counter() - t_rec
            rec.workers = self.num_workers
        if cfg.snapshot_interval > 0:
            if m.action in ("quarantine", "evict", "recover"):
                # the topology changed under the snapshot: re-snapshot now
                # so a later restore lands on the live worker layout
                self._auto_snap = self.snapshot()
                self._replay = []
            else:
                self._replay.append((keys, values))
                if len(self._replay) >= cfg.snapshot_interval:
                    self._auto_snap = self.snapshot()
                    self._replay = []
        return m

    def _process_batch_inner(self, keys: np.ndarray,
                             values: np.ndarray | None = None) -> BatchMetrics:
        t0 = time.perf_counter()
        raw_keys = keys
        has_values = values is not None
        n = len(keys)
        w = self.num_workers
        local_n = int(np.ceil(n / w))
        pad = local_n * w - n
        keys = np.concatenate([keys, np.full(pad, KEY_SENTINEL, np.int64)]).astype(np.int32)
        if values is None:
            values = np.ones((len(keys), self.payload_dim), np.float32)
        else:
            values = np.concatenate([values, np.zeros((pad,) + values.shape[1:], np.float32)])
        valid = keys != KEY_SENTINEL
        self._build(local_n * w)
        batch_backend = self.exchange_backend.name  # the transport this batch rode
        overlap = self._overlap_active()
        pipelined = False

        t_ex = time.perf_counter()
        if overlap:
            # split-phase pipeline: enqueue this batch's start (unless the
            # depth-2 lookahead already staged it last batch), then the
            # previous batch's ship + merge behind it, and block only on the
            # start outputs — devices drain their queue in order, so the
            # loads sync below waits for the count phase, not the ship,
            # which runs while the host works through the decision section
            shuffle = self._shuffle
            staged = self._take_staged(raw_keys, has_values)
            if staged is not None:
                pending, res = staged
                pipelined = True
            else:
                pending, res = shuffle.start(
                    self.drm.partitioner.tables(), jnp.asarray(keys),
                    jnp.asarray(values, jnp.float32), jnp.asarray(valid),
                    self._part_loads,
                )
            self._consume_inflight()

            def _fin_shuffle(fin=shuffle.finish, pending=pending):
                rk, rv, rva, _rp = fin(pending)
                self._sk, self._sv, _ = self._merge(self._sk, self._sv, rk, rv, rva)

            self._inflight = _fin_shuffle
            with safe_point():
                loads = host_fetch(res.loads)  # forces the start phase only
            exchange_wall = time.perf_counter() - t_ex
            count_wall = exchange_wall
        else:
            self._discard_staged()  # overlap turned off mid-stream: re-route
            if self._inflight is not None:
                self._drain_inflight()
            res = self._shuffle(
                self.drm.partitioner.tables(), jnp.asarray(keys),
                jnp.asarray(values, jnp.float32), jnp.asarray(valid),
                self._part_loads,
            )
            # stateful reduce: fold received records into per-worker state
            self._sk, self._sv, _ = self._merge(
                self._sk, self._sv, res.keys, res.values, res.valid
            )
            with safe_point():
                loads = host_fetch(res.loads)  # forces the batch's device work
            exchange_wall = time.perf_counter() - t_ex
            count_wall = None
        # the route reads the *previous* batch's measured loads (identical
        # in serial / depth-1 / depth-2: all route batch N+1 on batch N's
        # vector, set here before any lookahead stages)
        if self.drm.config.split_least_load:
            self._part_loads = jnp.asarray(loads, jnp.float32)
        # depth-2: enqueue the lookahead batch's start now, behind this
        # batch's in-flight ship — its route + bucketize + count phase run
        # on the device while the host works through the decision section
        if self._next_batch is not None and self._depth2_active():
            self._stage_next(self._next_batch)
        # everything the decision section reads below comes out of the
        # start phase (res is ShuffleStart when overlapped, ShuffleResult
        # serially — the control fields are shared)
        self._hidden_since = time.perf_counter() if overlap else None

        # telemetry: signals gathered during normal work (no extra passes).
        # shipped is the backend's measured traffic (per worker, averaged),
        # padded what the spec provisioned, occupied the rows actually live
        # in the lanes (backend-independent — the BackendPolicy's signal;
        # under dense shipped == padded while occupied tracks the real load).
        with safe_point():
            stats = shuffle_stats(
                res, self._shuffle_spec, w,
                wall_s=exchange_wall,
                count_wall_s=count_wall,
                backend=batch_backend,
                # per-replica routing of the split keys (host twin of the
                # fused kernels' pick — exact, no extra device pass); only
                # computed while splits are installed, and only for the
                # stateless pick — the least-load tiebreak reads a load
                # vector the host twin doesn't see
                replica_rows=(split_replica_rows(self.drm.partitioner, keys, w, valid)
                              if self.drm.split_keys
                              and not self.drm.config.split_least_load else None),
            )
            # every fetch below reads a start-phase output the loads sync
            # already forced — no new device work blocks here
            shuffle_shipped = int(host_fetch(stats.rows))
            overflow_i = int(host_fetch(res.overflow))
            self.telemetry.record_exchange(stats)
            self.telemetry.record_overflow(shuffle=overflow_i)
            self.telemetry.record_batch(float(loads.sum()))
            # fault evidence: drain the seam's per-lane report (straggle
            # seconds, retries) into ordinary telemetry — the lane-health
            # layer's input.  Plans are keyed by original lane id; the
            # report re-maps onto current positions.  A plain transport has
            # no report; a never-firing plan drains empty — both leave the
            # telemetry bit-identical to a no-faults run.
            drain = getattr(self.exchange_backend, "drain_report", None)
            if drain is not None:
                for orig, rec in drain().items():
                    if orig in self._lane_ids:
                        self.telemetry.record_fault(
                            self._lane_ids.index(orig),
                            straggle_s=rec.get("straggle_s", 0.0),
                            retries=rec.get("retries", 0))

            # DRM: ingest DRW histograms + run the policy stack at the safe point
            self.drm.observe(host_fetch(res.hist_keys), host_fetch(res.hist_counts),
                             total_records=float(loads.sum()))
        at_checkpoint = (len(self.metrics) + 1) % self.checkpoint_interval == 0
        requested = None
        if at_checkpoint and self._pending_resize is not None:
            requested = self._pending_resize
            self._pending_resize = None
        signals = self.telemetry.snapshot(
            loads=loads,
            num_workers=w,
            # reading the live count would sync the in-flight merge chain —
            # overlapped batches report the count as of the last drain (no
            # policy keys on exact state rows; the migration planner reads
            # the real keys after the pre-action drain below)
            state_rows=self._last_state_rows if overlap else self._state_rows(),
            at_safe_point=at_checkpoint,
        )
        action = self.drm.evaluate(signals, requested_resize=requested,
                                   policies_enabled=self.dr_enabled)

        # execute the action (state only moves here, at the safe point).
        # Any taken action drains *both* in-flight stages first: the
        # pending finish completes — a migration must see this batch's rows
        # merged (bit-identical to the serial trajectory), and a backend
        # switch rebuilds the steps the in-flight finish came from — and
        # the depth-2 staged start is discarded, because its route used the
        # partitioner this action replaces: the pre-routed batch replays
        # under the new one when it arrives, exactly as serial would run it.
        if action.taken:
            self._drain_inflight()
            self._discard_staged()
        (rel_mig, mig_overflow, mig_rows, plan_rows, mig_shipped, mig_moved,
         mig_by_class) = 0.0, 0, 0, 0, 0, 0, None
        if isinstance(action, Resize):
            (rel_mig, mig_overflow, mig_rows, plan_rows, mig_shipped,
             mig_moved, mig_by_class) = self._apply_resize(action.target)
        elif isinstance(action, Repartition):
            (rel_mig, mig_overflow, mig_rows, plan_rows, mig_shipped,
             mig_moved, mig_by_class) = self._migrate_state(action.prev)
        elif isinstance(action, Unsplit):
            # combiner-side merge: the DRM already removed the key from the
            # replica table; a home-routed migration off the still-split
            # partitioner pulls every replica's partial aggregate back to
            # the key's home, where merge_into sums them.  The home diff is
            # empty (homes never changed) so the plan can't size the lanes —
            # full_lanes provisions for the off-home partials it can't see.
            (rel_mig, mig_overflow, mig_rows, plan_rows, mig_shipped,
             mig_moved, mig_by_class) = self._migrate_state(
                action.prev, full_lanes=True)
        elif isinstance(action, SwitchBackend):
            # the DRM already installed the new transport (note_backend_switch);
            # the job adopts it and rebuilds its jitted steps, exactly like a
            # resize rebuilds them for a new lane count.  No state moves.
            self._apply_backend_switch()
        elif isinstance(action, Quarantine):
            # circuit breaker open: the sick lane leaves the collective, its
            # device parks for a possible Recover, and the survivors adopt
            # its state (the modulo placement re-folds the partitions)
            self._apply_lane_removal(action.lane, park=True)
        elif isinstance(action, Evict):
            self._apply_lane_removal(action.lane, park=False)
        elif isinstance(action, Recover):
            # half-open probe: re-admit the oldest parked lane
            self._apply_recover()
        # a taken Split needs no execution here: the DRM stamped the replica
        # table and the very next batch's route kernels fan the key out
        with safe_point():  # migrations only fire at safe points
            if mig_rows:
                self.telemetry.record_exchange(migrate_stats(
                    shipped_rows=mig_shipped * w,  # helper re-divides per worker
                    buffer_rows=mig_rows,
                    moved_rows=mig_moved,
                    overflow=mig_overflow,
                    num_workers=w,
                    shipped_rows_by_class=mig_by_class,
                ))
                self.telemetry.record_overflow(migration=mig_overflow)

            # per-class shipped rows (shuffle + migration, per worker) for
            # the locality benches; zeros when the job carries no topology
            by_class = np.zeros(DISTANCE_CLASSES, np.int64)
            if stats.rows_by_class is not None:
                by_class += np.asarray(host_fetch(stats.rows_by_class), np.int64)
            if mig_by_class is not None:
                by_class += np.asarray(mig_by_class, np.int64) // w

        m = BatchMetrics(
            batch=len(self.metrics),
            imbalance=signals.imbalance,
            worker_imbalance=signals.worker_imbalance,
            # a backend switch is taken but moves no state — it must not
            # count as a repartition (consumers divide migration rows by
            # this flag's sum)
            repartitioned=action.taken and action.moves_state,
            relative_migration=rel_mig,
            overflow=overflow_i + mig_overflow,
            # overlapped: the count as of the last drain (exact state rows
            # would sync the in-flight merge; serial keeps today's numbers)
            state_rows=(self._last_state_rows if overlap else
                        (signals.state_rows if isinstance(action, NoOp)
                         else self._state_rows())),
            wall_time_s=time.perf_counter() - t0,
            reason=action.reason,
            migration_rows=mig_rows,
            resized=isinstance(action, Resize),
            num_partitions=self.num_partitions,
            migration_plan_rows=plan_rows,
            action=action.kind,
            shipped_rows=shuffle_shipped + mig_shipped,
            padded_rows=self._shuffle_spec.rows + mig_rows,
            backend=batch_backend,
            exchange_wall_s=exchange_wall,
            overlapped=overlap,
            pipelined=pipelined,
            overlap_fraction=signals.overlap_fraction,
            split_keys=len(self.drm.split_keys),
            shipped_rows_by_class=tuple(int(x) for x in by_class),
            lanes=self.num_workers,
        )
        # the host wall since the count sync ran under this batch's (or the
        # migration's) in-flight ship — that's the latency the overlap hid.
        # Recorded at batch end, so it lands in the *next* telemetry window.
        if self._inflight is not None and self._hidden_since is not None:
            self.telemetry.record_exchange(ExchangeStats(
                rows=0,
                hidden_wall_s=time.perf_counter() - self._hidden_since,
            ))
        self._hidden_since = None
        self.metrics.append(m)
        return m

    def _state_rows(self) -> int:
        """Live keyed-state rows across all workers (the migration scale).
        Drains any in-flight exchange (via the ``state_keys`` property)."""
        with safe_point():
            self._last_state_rows = int(host_fetch(
                jax.vmap(lambda k: jnp.sum(k != KEY_SENTINEL))(self.state_keys)
            ).sum())
        return self._last_state_rows

    # -- elastic resize -------------------------------------------------
    def resize(self, num_partitions: int) -> None:
        """Request an elastic grow/shrink to ``num_partitions``.

        The request is applied at the next checkpoint safe point (the same
        protocol as a repartition — state only moves when a consistent
        snapshot boundary exists).  Explicit requests work even with
        ``dr_enabled=False``.
        """
        n = int(num_partitions)
        if n < self.num_workers:
            raise ValueError(
                f"cannot resize to {n} partitions: mesh has {self.num_workers} workers"
            )
        self._pending_resize = n

    def _apply_backend_switch(self) -> None:
        """Adopt the DRM's newly installed transport at a safe point.

        The jitted shuffle/migrate steps were built for the old backend, so
        both caches drop — the next batch rebuilds them for the new
        transport (the same rebuild contract as an elastic resize).  A
        fault seam stays armed across the switch: the wrapper re-points
        its inner transport instead of being replaced."""
        new = self.drm.exchange_backend
        if (isinstance(self.exchange_backend, FaultyBackend)
                and not isinstance(new, FaultyBackend)):
            self.exchange_backend.inner = resolve_backend(new)
            self.drm.exchange_backend = self.exchange_backend
        else:
            self.exchange_backend = new
        self._shuffle = None
        self._shuffle_sig = None
        self._migrate_steps.clear()

    # -- failure domains: lane removal / re-admission / recovery ---------
    def _set_workers(self, devices: list) -> None:
        """Rebuild the mesh over ``devices`` and drop everything keyed to
        the old topology: jitted step caches (their shard_maps bound the old
        mesh), the in-flight/staged pipeline stages, and the least-load
        vector.  The partitioner is untouched — partitions re-fold onto the
        new worker count through the modulo placement."""
        self.mesh = Mesh(np.asarray(devices), ("data",))
        self.num_workers = len(devices)
        self._shuffle = None
        self._shuffle_sig = None
        self._migrate_steps.clear()
        self._part_loads = None
        self._inflight = None
        self._hidden_since = None
        self._staged = None

    def _apply_lane_removal(self, lane: int, *, park: bool) -> None:
        """Execute a Quarantine (``park=True``) or Evict at a safe point:
        fetch the state (the pre-action drain already completed), remove
        the lane from the collective, and fold its rows onto the
        survivors."""
        with safe_point():
            sk = np.asarray(host_fetch(self._sk))
            sv = np.asarray(host_fetch(self._sv))
        devices = list(self.mesh.devices.flat)
        device = devices.pop(lane)
        orig = self._lane_ids.pop(lane)
        if park:
            self._parked.append((orig, device))
        backend = self.exchange_backend
        if isinstance(backend, FaultyBackend):
            (backend.note_quarantined if park else backend.note_evicted)(orig)
        self._set_workers(devices)
        self._adopt_state(sk, sv)

    def _apply_recover(self) -> None:
        """Execute a Recover at a safe point: re-admit the oldest parked
        device and spread the state back over the grown collective."""
        if not self._parked:
            # a restored ledger can outlive the physical parked list (the
            # snapshot predated the quarantine): reconcile and decline
            self.drm.quarantined.clear()
            return
        with safe_point():
            sk = np.asarray(host_fetch(self._sk))
            sv = np.asarray(host_fetch(self._sv))
        orig, device = self._parked.pop(0)
        self._lane_ids.append(orig)
        backend = self.exchange_backend
        if isinstance(backend, FaultyBackend):
            backend.note_recovered(orig)
        self._set_workers(list(self.mesh.devices.flat) + [device])
        self._adopt_state(sk, sv)

    def _adopt_state(self, sk: np.ndarray, sv: np.ndarray) -> None:
        """Redistribute host-side state tables onto the *current* worker
        count: merge duplicate keys (split partial aggregates from
        different source workers co-land here — the keyed reduce is a sum,
        so merging early is the combiner-side merge), route every key to
        its home partition's worker, and rebuild the stacked tables.
        Capacity overflow is surfaced through telemetry, never silent."""
        w, cap = self.num_workers, self.state_capacity
        keys = np.asarray(sk).reshape(-1)
        vals = np.asarray(sv).reshape(-1, np.asarray(sv).shape[-1])
        live = keys != KEY_SENTINEL
        keys, vals = keys[live], vals[live]
        uniq, inv = np.unique(keys, return_inverse=True)
        acc = np.zeros((len(uniq),) + vals.shape[1:], vals.dtype)
        np.add.at(acc, inv, vals)
        dest = self.drm.partitioner.lookup_np(uniq.astype(np.int32)) % w
        new_k = np.full((w, cap), KEY_SENTINEL, np.int32)
        new_v = np.zeros((w, cap) + vals.shape[1:], np.float32)
        overflow = 0
        for worker in range(w):
            rows = np.nonzero(dest == worker)[0]
            if len(rows) > cap:
                overflow += len(rows) - cap
                rows = rows[:cap]
            new_k[worker, : len(rows)] = uniq[rows]
            new_v[worker, : len(rows)] = acc[rows]
        self._sk = jnp.asarray(new_k)
        self._sv = jnp.asarray(new_v)
        self._last_state_rows = int((new_k != KEY_SENTINEL).sum())
        if overflow:
            self.telemetry.record_overflow(migration=overflow)

    def _recover_from_loss(self, loss: WorkerLostError) -> str:
        """Zero-loss recovery from a hard worker loss (the safe-point
        protocol's failure branch).  Quiesce the surviving in-flight
        stages, evict the lost lane (shrinking the mesh; the last worker
        restarts in place instead), restore the last auto-snapshot onto
        the surviving topology, and record the forced eviction.  The
        caller replays the gap and retries the lost batch."""
        try:
            self._drain_inflight()  # quiesce survivors (state is discarded
        except Exception:           # below, but the device queue must empty)
            self._inflight = None
            self._hidden_since = None
        self._discard_staged()
        backend = self.exchange_backend
        kind = "evict"
        if self.num_workers > 1 and loss.lane in self._lane_ids:
            lane = self._lane_ids.index(loss.lane)
            devices = list(self.mesh.devices.flat)
            devices.pop(lane)
            self._lane_ids.pop(lane)
            self._set_workers(devices)
            if isinstance(backend, FaultyBackend):
                backend.note_evicted(loss.lane)
        else:
            kind = "restart"  # single worker (or already-removed lane):
            #                   restore + replay in place.  The restarted
            #                   lane stays fault-eligible — only the
            #                   standing death clears
            if isinstance(backend, FaultyBackend):
                backend.note_restarted(loss.lane)
        snap = self._auto_snap
        assert snap is not None, "recovery requires snapshot_interval > 0"
        self.restore(snap, _keep_recovery_log=True)
        # the restored DRM predates the loss: log the forced eviction so
        # the decision trail carries the failure, and reconcile its
        # quarantine ledger with the physically parked devices
        self.drm.note_lost(loss.lane, reason=str(loss))
        while len(self.drm.quarantined) > len(self._parked):
            self.drm.quarantined.pop()
        while len(self.drm.quarantined) < len(self._parked):
            self.drm.quarantined.append((-1, self.drm.batches_seen))
        return kind

    def _apply_resize(self, n: int):
        """Execute a resize at a safe point: re-plan cross-size, migrate
        state through freshly sized exchange lanes, rebuild the step cache."""
        old = self.drm.partitioner
        self.drm.replan_resize(n)
        stats = self._migrate_state(old)
        self.num_partitions = n
        # the shuffle step's lane count / loads vector followed the old
        # topology; _build re-derives the spec on the next batch, and the
        # least-load vector is re-seeded at the new width
        self._shuffle = None
        self._shuffle_sig = None
        self._part_loads = None
        return stats

    def _migrate_state(self, old_part: Partitioner, *,
                       full_lanes: bool = False):
        """Ship keyed state to where ``self.drm.partitioner`` now maps it.

        Plans on the driver (``plan_migration`` diffs the partitioners over
        the live keys — cross-size safe), sizes the exchange lanes from the
        plan (``migration_capacity``), and folds received rows back into the
        local state tables.  Returns ``(relative_migration, overflow,
        buffer_rows, planned_lane_rows, shipped_rows, moved_rows,
        shipped_rows_by_class)`` — ``buffer_rows`` is the per-worker
        provision, ``shipped_rows`` what the backend measured moving,
        ``moved_rows`` the rows that actually crossed workers (the occupancy
        side of the telemetry), ``shipped_rows_by_class`` the globally
        summed per-distance-class split (all zeros on a flat spec).

        ``full_lanes`` (and any installed split key) forces full-state
        lane provisioning: split partial aggregates live *off home*, so the
        home-diff plan cannot see them, but the home-routed migrate step
        ships every one of them back to its key's home — undersized lanes
        would silently drop the partials being merged.
        """
        with safe_point():  # migrations are safe points: the plan reads state
            sk = host_fetch(self.state_keys).reshape(-1)
        live = sk[sk != KEY_SENTINEL].astype(np.int64)
        plan = plan_migration(old_part, self.drm.partitioner, live)
        if full_lanes or self.drm.split_keys:
            plan_rows = self.state_capacity
        else:
            plan_rows = migration_capacity(plan, num_workers=self.num_workers)
        migrate, lane_cap = self._migrate_step(plan_rows)
        tables = self.drm.partitioner.tables()
        if self._overlap_active():
            # split migrate: the count phase (and every control output the
            # metrics need) blocks below; the row ship + merge stays in
            # flight across the safe point and drains under the next
            # batch's host work — bit-identical to the fused step, which
            # is the two phases traced back to back
            (pending, kk, vv, kv_valid, moved, total,
             mig_ov, mig_lane_ov, mig_shipped, mig_by) = migrate.start(
                tables, self._sk, self._sv)
            kept_keys = jnp.where(kv_valid, kk, KEY_SENTINEL)
            # interim state = kept rows only; the pending merge adds the
            # received rows (external readers drain first, so they never
            # observe the interim)
            self._sk, self._sv = kept_keys, vv
            self._hidden_since = time.perf_counter()

            def _fin_migrate(fin=migrate.finish, pending=pending):
                rk, rv, rva = fin(pending)
                self._sk, self._sv, _ = self._merge(self._sk, self._sv, rk, rv, rva)

            self._inflight = _fin_migrate
        else:
            out = migrate(tables, self._sk, self._sv)
            (kk, vv, kv_valid, rk, rv, rva, moved, total,
             mig_ov, mig_lane_ov, mig_shipped, mig_by) = out
            kept_keys = jnp.where(kv_valid, kk, KEY_SENTINEL)
            self._sk, self._sv, _ = self._merge(kept_keys, vv, rk, rv, rva)
        # every control output below left the migrate start phase; fetching
        # them at this safe point blocks on work already forced (the ship
        # itself stays in flight on the overlap path)
        with safe_point():
            moved_i = int(host_fetch(moved))
            total_i = int(host_fetch(total))
            mig_by_np = np.asarray(host_fetch(mig_by), np.int64)
            mig_shipped_i = int(host_fetch(mig_shipped))
            mig_ov_i = int(host_fetch(mig_ov))
        rel_mig = float(moved_i) / max(float(total_i), 1e-9)
        mig_rows = self.num_workers * lane_cap  # rows received per worker
        # rows/wall are recorded by process_batch (one call per migration);
        # the hot-lane vector is only available here, so it rides a
        # zero-row record into the same telemetry window (device array —
        # Telemetry folds it at the next snapshot, not here)
        self.telemetry.record_exchange(ExchangeStats(
            rows=0, lane_overflow=mig_lane_ov
        ))
        return (rel_mig, mig_ov_i, mig_rows, plan_rows,
                mig_shipped_i // self.num_workers, moved_i, mig_by_np)

    # ------------------------------------------------------------------
    def run(self, batches: Iterable[np.ndarray]) -> list[BatchMetrics]:
        # depth-2 needs one batch of lookahead: park batch N+1 where
        # process_batch can stage its start behind batch N's ship.  The
        # check re-runs per batch so a mid-stream env/config flip degrades
        # to depth 1 instead of staging work nobody will claim.
        out: list[BatchMetrics] = []
        seq = list(batches)
        for i, b in enumerate(seq):
            self._next_batch = (seq[i + 1]
                                if self._depth2_active() and i + 1 < len(seq)
                                else None)
            out.append(self.process_batch(b))
        self._next_batch = None
        return out

    # -- state inspection ----------------------------------------------
    def state_count(self, key: int) -> float:
        """Total aggregated value for one key across all workers (test hook)."""
        sk = np.asarray(self.state_keys)
        sv = np.asarray(self.state_vals)
        hit = sk == key
        return float(sv[hit].sum())

    # -- checkpoint / restore --------------------------------------------
    def snapshot(self) -> dict:
        return {
            "state_keys": np.asarray(self.state_keys),
            "state_vals": np.asarray(self.state_vals),
            **{f"drm_{k}": v for k, v in self.drm.snapshot().items()},
        }

    def restore(self, snap: dict, *, _keep_recovery_log: bool = False) -> None:
        # any in-flight finish belongs to the state being replaced: discard,
        # along with any staged lookahead start (its route used the replaced
        # partitioner) and the least-load vector (measured pre-restore)
        self._inflight = None
        self._hidden_since = None
        self._staged = None
        self._part_loads = None
        drm_snap = {k[4:]: v for k, v in snap.items() if k.startswith("drm_")}
        self.drm = DRMaster.restore(drm_snap, self.drm.config)
        snap_keys = np.asarray(snap["state_keys"])
        if snap_keys.shape[0] != self.num_workers:
            # cross-topology restore: the snapshot was cut on a different
            # worker count (recovery shrank the mesh since, or the snapshot
            # rode over a quarantine) — re-fold the rows onto the live
            # layout instead of adopting the stale stacking
            self._adopt_state(snap_keys, np.asarray(snap["state_vals"]))
        else:
            self.state_keys = jnp.asarray(snap_keys)
            self.state_vals = jnp.asarray(snap["state_vals"])
        if "exchange_backend" in drm_snap:
            # the snapshot's *active* transport wins: a BackendPolicy switch
            # taken before the snapshot survives the restore, whatever
            # backend this job object was constructed with — but an armed
            # fault seam survives too: the wrapper re-points its inner
            # transport rather than being dropped by the restore
            restored = self.drm.exchange_backend
            if (isinstance(self.exchange_backend, FaultyBackend)
                    and not isinstance(restored, FaultyBackend)):
                self.exchange_backend.inner = resolve_backend(restored)
                self.drm.exchange_backend = self.exchange_backend
            else:
                self.exchange_backend = restored
        else:  # legacy snapshot predating backends: job's transport stands
            self.drm.exchange_backend = self.exchange_backend
        if self.drm.exchange_topology is not None:
            # snapshots carry the lane topology: a restore resumes with the
            # same locality view (by-class telemetry + plan pricing) the
            # snapshotted job had, whatever this object was built with
            self.exchange_topology = self.drm.exchange_topology
        else:  # legacy / flat snapshot: construction-time topology stands
            self.drm.exchange_topology = self.exchange_topology
        # resume the snapshotted topology: the snapshot may have been taken
        # after an elastic resize or a backend switch, in which case this
        # job's construction-time partition count / transport is stale and
        # the step caches must be rebuilt
        n = self.drm.partitioner.num_partitions
        assert n >= self.num_workers, (n, self.num_workers)
        self.num_partitions = n
        self._shuffle = None
        self._shuffle_sig = None
        self._migrate_steps.clear()
        self._pending_resize = None
        if not _keep_recovery_log:
            # an external restore starts a fresh failure epoch: the old
            # auto-snapshot and replay buffer describe a timeline this
            # job just left.  (The recovery protocol itself restores with
            # ``_keep_recovery_log=True`` — the gap batches in the buffer
            # are exactly what it is about to replay.)
            self._auto_snap = None
            self._replay = []
        # the restored quarantine ledger can disagree with the physically
        # parked devices (the snapshot predates a quarantine, or rode over
        # one): the parked list is ground truth for what can re-admit
        while len(self.drm.quarantined) > len(self._parked):
            self.drm.quarantined.pop()
        self._state_rows()  # refresh the drain-time row cache
