"""xlstm-125m [ssm]: 12L, d=768, 4H, vocab=50304, alternating mLSTM/sLSTM
blocks (pre-up-projection blocks, no separate FFN: d_ff=0).
[arXiv:2405.04517]"""
from repro.configs.base import ArchConfig, Block

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    pattern=(Block("mlstm", "none"), Block("slstm", "none")),
    norm_kind="layernorm",
    rope_kind="none",
    tie_embeddings=True,
    subquadratic=True,  # recurrent state, O(1) per decoded token
    notes="attention-free; long_500k runs with O(1) recurrent state",
)
