"""stablelm-1.6b [dense]: 24L, d=2048, 32H (kv=32, i.e. MHA), d_ff=5632,
vocab=100352, LayerNorm, partial rotary 25%.  [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ArchConfig, Block

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    pattern=(Block("attn", "dense"),),
    ffn_kind="swiglu",
    norm_kind="layernorm",
    rope_pct=0.25,
    tie_embeddings=False,
    subquadratic=False,
    notes="long_500k skipped: pure full-attention decoder",
)
