"""Pallas TPU flash attention (GQA-grouped, causal/sliding-window).

The jnp flash path materializes [q_chunk, kv_chunk] score/weight tensors in
HBM every block — the §Roofline tables show attention intermediates
dominating the memory term of the dense train/prefill cells.  This kernel
keeps the online-softmax state (m, l, acc) and the score tile entirely in
VMEM: HBM traffic is exactly q + k + v + o.

Layout: q [G, P, Sq, hd] (G = kv groups, P = q-heads-per-group), k/v
[G, Sk, hd].  Grid (G, nq, nk) with the kv dim innermost (sequential on
TPU); scratch VMEM carries the accumulator across kv steps.

VMEM budget per step (bq=256, bk=512, P<=8, hd<=256, f32):
  q tile P*256*256*4 = 2 MiB; k/v 2*512*256*4 = 1 MiB;
  scores P*256*512*4 = 2 MiB; acc 2 MiB  => ~7 MiB < 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, bq: int, bk: int, nk: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # [P, bq, hd]
    k = k_ref[0].astype(jnp.float32)                # [bk, hd]
    v = v_ref[0].astype(jnp.float32)                # [bk, hd]
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [P, bq, bk]

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, NEG_INF)

    m_prev = m_ref[...]                             # [P, bq]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, bq, hd]
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_tpu(
    q: jax.Array,  # [G, P, Sq, hd]
    k: jax.Array,  # [G, Sk, hd]
    v: jax.Array,  # [G, Sk, hd]
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    g, p, sq, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, "pad sequences to block multiples"
    nq, nk = sq // bq, sk // bk
    scale = hd**-0.5

    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window, bq=bq, bk=bk,
                          nk=nk, scale=scale),
        grid=(g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, p, bq, hd), lambda gg, qq, kk: (gg, 0, qq, 0)),
            pl.BlockSpec((1, bk, hd), lambda gg, qq, kk: (gg, kk, 0)),
            pl.BlockSpec((1, bk, hd), lambda gg, qq, kk: (gg, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, p, bq, hd), lambda gg, qq, kk: (gg, 0, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((g, p, sq, hd), q.dtype),
        scratch_shapes=[
            # online-softmax state lives in VMEM across the sequential kv dim
            pltpu.VMEM((p, bq, hd), jnp.float32),
            pltpu.VMEM((p, bq), jnp.float32),
            pltpu.VMEM((p, bq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
