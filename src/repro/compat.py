"""Version-tolerance shims for jax APIs that moved between releases.

Every module that needs ``shard_map`` imports it from here instead of from
jax directly, so the repo tracks exactly one spelling of each API:

* ``shard_map``  — ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (<= 0.4.x), absorbing the ``check_rep`` -> ``check_vma`` rename and the
  ``auto`` -> ``axis_names`` inversion (old jax names the *auto* axes, new
  jax names the *manual* ones).
* ``set_mesh``   — ``jax.set_mesh`` (new) vs entering the ``Mesh`` context
  manager (old); both forms support ``with set_mesh(mesh): ...``.
* ``ragged_all_to_all`` — ``jax.lax.ragged_all_to_all`` (>= 0.5), the real
  ragged collective: each shard sends ``send_sizes[i]`` rows to shard ``i``
  instead of the full capacity pad.  On jax 0.4.x the fallback rides the
  dense tiled all-to-all with the receive buffer masked to ``recv_sizes`` —
  bit-identical output, dense wall-clock.  The fallback supports the
  *lane-major regular layout only* (``input_offsets[i] == i * capacity``,
  ``output_offsets[i] == axis_index * capacity``), which is the one layout
  the exchange plane uses: ``bucketize`` packs each lane's rows
  contiguously from slot 0, so lane ``i``'s live rows start at row
  ``i * capacity`` of the flattened send buffer.

Call sites use the modern spellings (``check_vma=``, ``axis_names=``); the
shim rewrites them for whatever jax is installed.

Runtime escape hatches (environment variables) also live here, next to the
version shims they mirror:

* ``REPRO_DISABLE_NATIVE_RAGGED=1`` — force the masked-dense ragged
  fallback even on jax >= 0.5 (see :func:`has_ragged_all_to_all`).
* ``REPRO_DISABLE_OVERLAP=1`` — force the streaming driver's serial
  exchange path even when ``DRConfig.overlap_exchange`` is on (see
  :func:`overlap_enabled`): batch N+1's route/count phase no longer issues
  before batch N's row ship drains.  The two paths are bit-identical — the
  serial step *is* the split-phase pipeline run back to back — so this is a
  debugging/benching lever, not a correctness switch.

Host-sync instrumentation (``host_fetch`` / ``safe_point`` /
``host_sync_count``) also lives here: the streaming driver routes its
device->host conversions through :func:`host_fetch`, which counts fetches
of device arrays performed outside a ``with safe_point():`` region.  The
counter is how benches prove the depth-2 pipeline's "zero blocking
transfers between safe points" contract.
"""
from __future__ import annotations

import contextlib
import inspect
import os

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)

_NATIVE_RAGGED = hasattr(jax.lax, "ragged_all_to_all")

__all__ = [
    "shard_map",
    "set_mesh",
    "ragged_all_to_all",
    "has_ragged_all_to_all",
    "overlap_enabled",
    "host_fetch",
    "host_sync_count",
    "reset_host_sync_count",
    "safe_point",
]

# --- host-sync instrumentation -------------------------------------------
#
# The streaming driver's sync-free contract says device->host transfers
# happen only at *safe points* (the per-batch decision section, where the
# control plane must look at the counts anyway).  Every D2H conversion in
# the steady-state loop goes through :func:`host_fetch`; fetches of device
# arrays outside a ``with safe_point():`` region increment
# ``host_sync_count``.  Benches and tests read the counter to prove the
# depth-2 pipeline performs zero blocking transfers between safe points —
# a nonzero delta on a no-action batch pinpoints a leaked sync.

_sync_state = {"count": 0, "depth": 0}


def host_sync_count() -> int:
    """Device->host fetches observed *outside* safe-point regions."""
    return _sync_state["count"]


def reset_host_sync_count() -> None:
    """Zero the counter (benches call this before a measured segment)."""
    _sync_state["count"] = 0


@contextlib.contextmanager
def safe_point():
    """Mark a region where blocking device->host fetches are sanctioned."""
    _sync_state["depth"] += 1
    try:
        yield
    finally:
        _sync_state["depth"] -= 1


def host_fetch(x):
    """``np.asarray`` that audits device->host transfers.

    Fetching a ``jax.Array`` outside a :func:`safe_point` region counts as a
    blocking sync; host values (ints, floats, numpy) pass through uncounted.
    """
    if isinstance(x, jax.Array) and _sync_state["depth"] == 0:
        _sync_state["count"] += 1
    return np.asarray(x)


def overlap_enabled() -> bool:
    """True unless ``REPRO_DISABLE_OVERLAP`` forces the serial exchange path.

    The streaming driver overlaps batch N+1's start phase with batch N's
    in-flight row ship when this *and* ``DRConfig.overlap_exchange`` hold;
    the env var is the bench/debug escape hatch for A/B-ing the two
    bit-identical paths on one build.  (``0``/``false``/unset leave the
    overlap on.)
    """
    disabled = os.environ.get("REPRO_DISABLE_OVERLAP", "")
    return disabled.lower() in ("", "0", "false")


def has_ragged_all_to_all() -> bool:
    """True when the installed jax provides the native ragged collective.

    ``REPRO_DISABLE_NATIVE_RAGGED=1`` forces the masked-dense fallback even
    on jax >= 0.5 — the escape hatch benches use to measure the fallback,
    and tests use to compare the two paths bit-for-bit on one build.
    (``0``/``false``/unset leave the native path on.)
    """
    disabled = os.environ.get("REPRO_DISABLE_NATIVE_RAGGED", "")
    return _NATIVE_RAGGED and disabled.lower() in ("", "0", "false")


def ragged_all_to_all(
    operand,
    output,
    input_offsets,
    send_sizes,
    output_offsets,
    recv_sizes,
    *,
    axis_name: str,
):
    """``jax.lax.ragged_all_to_all`` with a jax 0.4.x fallback.

    Native (jax >= 0.5): shard ``j`` receives ``send_sizes[j]`` rows read
    from ``operand[input_offsets[j]:]`` and writes them at
    ``output_offsets[j]`` of *its* ``output``; regions of ``output`` that
    receive nothing keep their initial values.  Only the measured rows cross
    the interconnect — the wall-clock follows the row counts.

    Fallback (jax 0.4.x): the dense tiled all-to-all ships the whole padded
    buffer and the receive side is masked to ``recv_sizes``, with unfilled
    rows taken from ``output`` — bit-identical results, padded traffic.
    Requires the lane-major regular layout (see module doc); offsets are
    trusted, not checked, because they are static under that layout.  For
    buffers whose pad rows already equal ``output``'s values (the exchange
    plane's bucketize-packed buffers) the mask selects identical bits — the
    cost of keeping one uniform shim contract is one fused select XLA folds
    into the all-to-all's consumer.
    """
    if has_ragged_all_to_all():
        return jax.lax.ragged_all_to_all(
            operand, output, input_offsets, send_sizes, output_offsets,
            recv_sizes, axis_name=axis_name,
        )
    num_lanes = send_sizes.shape[0]
    capacity = operand.shape[0] // num_lanes
    bufs = operand.reshape((num_lanes, capacity) + operand.shape[1:])
    recvd = jax.lax.all_to_all(bufs, axis_name, 0, 0, tiled=True)
    live = jnp.arange(capacity, dtype=jnp.int32)[None, :] < recv_sizes[:, None]
    live = live.reshape((num_lanes * capacity,) + (1,) * (operand.ndim - 1))
    return jnp.where(live, recvd.reshape(operand.shape), output)


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    check_rep: bool | None = None,
    axis_names=None,
    auto=None,
):
    """``shard_map`` with one signature across jax versions."""
    check = check_vma if check_vma is not None else check_rep
    kwargs = {}
    if "check_vma" in _PARAMS:  # new-style jax
        if check is not None:
            kwargs["check_vma"] = check
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        elif auto is not None:
            kwargs["axis_names"] = set(mesh.axis_names) - set(auto)
    else:  # old-style: check_rep + auto (complement of the manual axes)
        if check is not None:
            kwargs["check_rep"] = check
        if auto is not None:
            kwargs["auto"] = frozenset(auto)
        elif axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # older jax: Mesh is itself a context manager
