"""DR-based request routing across serving replicas.

Serving-side instance of the paper's mapping: requests carry a *session
key* (user / document / host — the paper's §6 partitions crawl output by
web host); replicas are partitions; the per-session KV cache is operator
state.  Session keys are heavy-tailed (hot documents / hot tenants), so
UHP routing makes some replicas stragglers.  The scheduler runs the same
DRM loop: counter-sketch over observed session keys, KIPUPDATE at decision
points, and session (cache) migration costed against the expected balance
gain.

Replicas here are modeled objects (queue depths), keeping the scheduler
testable without spinning 16 engines; ``ServeEngine`` is the per-replica
execution unit.

``checkpoint`` is a thin control-plane driver: it feeds the window's
telemetry (queue depths, routed records) into ``DRMaster.evaluate`` and
executes whatever typed action the shared policy stack returns — replica
scale-out/in (``Resize``) or session re-routing (``Repartition``) — always
returning the same result schema.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.compat import overlap_enabled
from repro.control import Repartition, Resize, SwitchBackend, Telemetry
from repro.core.drm import DRConfig, DRMaster
from repro.core.hashing import DEFAULT_NUM_HOSTS
from repro.core.partitioner import heavy_capacity_for, uniform_partitioner
from repro.exchange import ExchangeStats

__all__ = ["ReplicaState", "DRScheduler"]


@dataclasses.dataclass
class ReplicaState:
    rid: int
    queued_tokens: float = 0.0      # outstanding work
    sessions: set = dataclasses.field(default_factory=set)


class DRScheduler:
    def __init__(self, num_replicas: int, *, dr: DRConfig | None = None, seed: int = 0,
                 migration_token_cost: float = 64.0,
                 exchange_backend: str | None = None,
                 topology=None):
        self.replicas = [ReplicaState(i) for i in range(num_replicas)]
        cfg = dr or DRConfig(lam=4.0, imbalance_trigger=1.25)
        # the same tile-padded sizing rule the kernels' heavy tables use —
        # a bespoke rounding here once drifted from the kernel tile shape
        heavy_cap = heavy_capacity_for(cfg.lam, num_replicas)
        init = uniform_partitioner(num_replicas, DEFAULT_NUM_HOSTS, seed,
                                   heavy_capacity=heavy_cap)
        # the transport KV-cache migrations would ride; its sizing rule
        # prices session-move plans inside the policy stack.  ``topology``
        # (an ExchangeTopology over the replica set) makes that pricing
        # locality-aware: moving a session's KV cache between replicas on
        # one host is cheaper than shipping it across hosts.
        self.drm = DRMaster(init, cfg, consumer="serve",
                            exchange_backend=exchange_backend or "dense",
                            exchange_topology=topology)
        self.telemetry = Telemetry("serve")
        self.migration_token_cost = migration_token_cost
        self.migrations = 0
        self.routed = 0

    # -- hot path ---------------------------------------------------------
    def route(self, session_key: int, cost_tokens: float) -> int:
        """Assign a request to a replica; account its load."""
        r = int(self.drm.partitioner.lookup_np(np.asarray([session_key], np.int32))[0])
        rep = self.replicas[r]
        rep.queued_tokens += cost_tokens
        rep.sessions.add(session_key)
        self.routed += 1
        return r

    def drain(self, tokens_per_replica: float) -> None:
        """Simulate service: each replica completes up to N tokens."""
        for rep in self.replicas:
            rep.queued_tokens = max(0.0, rep.queued_tokens - tokens_per_replica)

    # -- safe point: feed signals, execute the stack's action --------------
    def checkpoint(self, window_keys: np.ndarray) -> dict:
        """One decision point: telemetry in, typed action out, executed.

        Always returns the same schema — ``repartitioned``, ``resized``,
        ``num_replicas``, ``imbalance``, ``moved_sessions``, ``reason``,
        ``backend`` — whatever the decision was (including declines, whose
        reason comes from the decision log's record).
        """
        window_keys = np.asarray(window_keys, np.int64)
        keys, counts = np.unique(window_keys, return_counts=True)
        self.drm.observe(keys.reshape(1, -1), counts.reshape(1, -1))
        loads = np.array([r.queued_tokens for r in self.replicas])
        self.telemetry.record_batch(float(len(window_keys)))
        self.telemetry.record_queues(loads)
        # replicas are *elastic* partitions, not a fixed physical worker set:
        # num_workers=1 keeps the resize floor at min_partitions (scale-in
        # must stay reachable) and session moves costed replica-to-replica
        signals = self.telemetry.snapshot(loads=loads + 1e-9, num_workers=1)
        action = self.drm.evaluate(signals)
        moved_sessions = 0
        if isinstance(action, Resize):
            # elastic scale-out/in — a resize is this decision point's action
            moved_sessions = self.resize(action.target)
        elif isinstance(action, Repartition):
            # migrate each moved session's KV cache
            moved_sessions = self._reroute_sessions(self.drm.partitioner)
            self.migrations += moved_sessions
        elif isinstance(action, SwitchBackend):
            # the DRM installed the new transport in evaluate
            # (note_backend_switch); session-move pricing follows it from the
            # next decision on — nothing to rebuild here, replicas are
            # modeled objects, not jitted steps.  NOTE: session moves are
            # modeled (not bufferized), so the occupancy below is exact
            # rows with no padding — the BackendPolicy sees fraction 1.0
            # and holds dense; real lane accounting would need bufferized
            # KV migration (ROADMAP open item).
            pass
        overlapped = self.overlap_active()
        if moved_sessions:
            # session (KV-cache) moves are this consumer's exchange traffic;
            # modeled 1 row per session, unpadded.  Under effective overlap
            # the move wall counts as hidden behind decision work (the
            # streaming driver's attribution); serial — env kill switch or
            # config — books nothing as hidden.
            self.telemetry.record_exchange(ExchangeStats(
                rows=moved_sessions,
                padded_rows=moved_sessions,
                occupied_rows=moved_sessions,
                backend=self.drm.exchange_backend.name,
                count_wall_s=0.0 if overlapped else None,
            ))
        return {
            # a backend switch moves no sessions: taken, but not a repartition
            "repartitioned": action.taken and action.moves_state,
            "resized": isinstance(action, Resize),
            "num_replicas": len(self.replicas),
            "imbalance": float(signals.imbalance),
            "moved_sessions": moved_sessions,
            "reason": action.reason,
            "backend": self.drm.exchange_backend.name,
            # effective overlap at this decision point: the env kill switch
            # (REPRO_DISABLE_OVERLAP) wins over DRConfig.overlap_exchange
            "overlapped": overlapped,
        }

    def overlap_active(self) -> bool:
        """Whether this scheduler treats exchange traffic as overlapped.

        Same precedence as the streaming driver: ``REPRO_DISABLE_OVERLAP=1``
        wins over ``DRConfig.overlap_exchange`` (and over any configured
        ``pipeline_depth``) — the env kill switch means serial everywhere,
        not just in jobs that happen to own a device pipeline.  Session
        moves here are modeled, so the flag only steers how their exchange
        records are attributed (and lets operators confirm the kill switch
        reached every consumer via the checkpoint schema)."""
        return self.drm.config.overlap_exchange and overlap_enabled()

    def imbalance(self) -> float:
        loads = np.array([r.queued_tokens for r in self.replicas])
        return float(loads.max() / max(loads.mean(), 1e-9))

    # -- elastic scale-out / scale-in -------------------------------------
    def resize(self, num_replicas: int) -> int:
        """Grow or shrink the replica set — the streaming resize one level up.

        The session keyspace is re-planned cross-size with the DRM's sketch
        (``DRMaster.replan_resize``); sessions whose replica changed migrate
        their KV cache (costed like a repartition migration).  Returns the
        number of migrated sessions.  With ``DRConfig(elastic=True)``,
        ``checkpoint`` calls this automatically on sustained queue imbalance.
        """
        n = int(num_replicas)
        if n < 1:
            raise ValueError(f"need at least one replica, got {n}")
        if n == len(self.replicas):
            return 0
        new = self.drm.replan_resize(n)
        if n > len(self.replicas):
            self.replicas += [ReplicaState(i) for i in range(len(self.replicas), n)]
        moved = self._reroute_sessions(new)
        if n < len(self.replicas):
            # scale-in: dying replicas already handed off their sessions;
            # their residual queued work drains onto the folded replica
            for rep in self.replicas[n:]:
                self.replicas[rep.rid % n].queued_tokens += rep.queued_tokens
            self.replicas = self.replicas[:n]
        self.migrations += moved
        return moved

    def _reroute_sessions(self, new) -> int:
        """Move sessions (and their KV-cache cost) to where ``new`` maps them.

        A dying replica (``rid >= new.num_partitions``) can never equal its
        sessions' new destination, so scale-in drains it completely.
        """
        moved = 0
        for rep in self.replicas:
            stay = set()
            for s in rep.sessions:
                dst = int(new.lookup_np(np.asarray([s], np.int32))[0])
                if dst != rep.rid:
                    self.replicas[dst].sessions.add(s)
                    self.replicas[dst].queued_tokens += self.migration_token_cost
                    moved += 1
                else:
                    stay.add(s)
            rep.sessions = stay
        return moved
