"""Stateful streaming word count under concept drift, with DR vs without —
plus a mid-stream crash + checkpoint restore (the paper's long-running
stateful job scenario) and an elastic grow-under-hotspot / shrink-when-idle
phase (the same safe-point mechanism resizing the worker count itself).

    PYTHONPATH=src python examples/streaming_wordcount.py
"""
import numpy as np

from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf, zipf_keys


def make_job(dr_enabled: bool) -> StreamingJob:
    return StreamingJob(
        num_partitions=8,
        state_capacity=32_768,
        dr_enabled=dr_enabled,
        dr=DRConfig(imbalance_trigger=1.15, migration_cost_weight=0.2,
                    ewma_alpha=0.6),
    )


batches = list(drifting_zipf(12, 16_384, num_keys=4_000, exponent=1.4,
                             drift_every=4, drift_fraction=0.4, seed=3))

print("=== without DR (uniform hash) ===")
base = make_job(dr_enabled=False)
for m in base.run(batches):
    print(f"batch {m.batch:2d} imbalance {m.imbalance:.2f}")

print("\n=== with DR (+ crash/restore at batch 6) ===")
job = make_job(dr_enabled=True)
snap = None
for i, b in enumerate(batches):
    m = job.process_batch(b)
    mark = " <-- repartitioned" if m.repartitioned else ""
    print(f"batch {m.batch:2d} imbalance {m.imbalance:.2f}{mark}")
    if i == 5:
        snap = job.snapshot()          # checkpoint
if snap is not None:
    crashed = make_job(dr_enabled=True)
    crashed.restore(snap)              # node failure -> restart from snapshot
    for b in batches[6:]:
        crashed.process_batch(b)
    all_keys = np.concatenate(batches)
    k = int(np.unique(all_keys)[7])
    assert crashed.state_count(k) == float((all_keys == k).sum())
    print(f"\nrestored job recovered exact counts after crash  OK")

imb_dr = np.mean([m.imbalance for m in job.metrics[2:]])
imb_no = np.mean([m.imbalance for m in base.metrics[2:]])
print(f"\nmean imbalance: {imb_no:.2f} (hash) -> {imb_dr:.2f} (DR)")

print("\n=== elastic: grow under hotspot, shrink when idle ===")
elastic = StreamingJob(
    num_partitions=4,
    state_capacity=32_768,
    dr=DRConfig(elastic=True, min_partitions=4, max_partitions=8,
                grow_trigger=1.6, shrink_trigger=1.3, resize_patience=2,
                imbalance_trigger=1.2, migration_cost_weight=0.1),
)
rng = np.random.default_rng(11)
hotspot = [zipf_keys(16_384, num_keys=3_000, exponent=1.5, seed=s) for s in range(4)]
idle = [rng.integers(0, 200_000, 16_384) for _ in range(6)]
for b in hotspot + idle:
    m = elastic.process_batch(b)
    mark = f"  <-- {m.reason}" if m.resized else ""
    print(f"batch {m.batch:2d} imbalance {m.imbalance:.2f} "
          f"partitions {m.num_partitions}{mark}")
all_keys = np.concatenate(hotspot + idle)
k = int(np.unique(all_keys)[3])
assert elastic.state_count(k) == float((all_keys == k).sum())
print("per-key counts exact across both resizes  OK")
