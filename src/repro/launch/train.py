"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama4-scout-17b-a16e \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the full production loop: data pipeline -> jitted train step ->
DR expert-placement safe points -> checkpoints (atomic, resumable).  On a
CPU dev box use ``--smoke`` (reduced config); on a TPU slice the production
mesh + shardings come from repro.launch.sharding automatically.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduce_for_smoke
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.generators import lm_token_stream
from repro.models import model
from repro.models.modules import Policy
from repro.moe.kip_placement import PlacementController, apply_placement_to_weights
from repro.train import checkpoint
from repro.train.optimizer import OptConfig, init_opt
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dr-placement", action="store_true", default=True,
                    help="KIP expert placement at step boundaries (MoE archs)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    pol = Policy(attn_q_chunk=min(1024, args.seq), attn_kv_chunk=min(2048, args.seq))
    opt_cfg = OptConfig(lr=args.lr)

    params = model.init_params(cfg, jax.random.PRNGKey(0), pol)
    opt = init_opt(params, opt_cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M layers={cfg.num_layers}")

    step_fn = jax.jit(make_train_step(cfg, pol, opt_cfg))
    placement = None
    inv_place = None
    if cfg.moe is not None and args.dr_placement:
        placement = PlacementController(cfg.moe.num_experts, max(pol.tp, 1))
        inv_place = jnp.asarray(placement.placement.inv_place)

    start = 0
    if args.ckpt_dir:
        got = checkpoint.restore(args.ckpt_dir, {"params": jax.tree.map(np.asarray, params),
                                                 "opt": jax.tree.map(np.asarray, opt)})
        if got:
            start, tree = got
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt = jax.tree.map(jnp.asarray, tree["opt"])
            print(f"resumed from step {start}")

    stream = lm_token_stream(args.steps + 1, args.batch, args.seq + 1, cfg.vocab_size)
    t0 = time.time()
    for step, toks in enumerate(stream, start=start):
        if step >= args.steps:
            break
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.ones((args.batch, args.seq), jnp.float32),
        }
        if cfg.encdec:
            batch["enc_embeds"] = jnp.zeros((args.batch, cfg.enc_len, cfg.d_model))
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros((args.batch, cfg.vision_tokens, cfg.d_model))
        params, opt, metrics = step_fn(params, opt, batch, inv_place)

        # DR safe point: expert-placement update between steps
        if placement is not None and "expert_counts" in metrics:
            placement.observe(np.asarray(metrics["expert_counts"]))
            changed, _, perm = placement.maybe_update()
            if changed:
                # state migration: permute expert weights + moments
                for j, blk in enumerate(cfg.pattern):
                    key = f"b{j}"
                    if "moe" in params["blocks"].get(key, {}):
                        permute = lambda t: jax.tree.map(
                            lambda a: jnp.take(a, jnp.asarray(perm), axis=1)
                            if a.ndim >= 2 else a, t)
                        params["blocks"][key]["moe"]["wi"] = jnp.take(
                            params["blocks"][key]["moe"]["wi"], jnp.asarray(perm), axis=1)
                        params["blocks"][key]["moe"]["wo"] = jnp.take(
                            params["blocks"][key]["moe"]["wo"], jnp.asarray(perm), axis=1)
                inv_place = jnp.asarray(placement.placement.inv_place)
                print(f"  step {step}: KIP moved "
                      f"{int((perm != np.arange(len(perm))).sum())} experts")

        if step % args.log_every == 0:
            sl = placement.shard_loads(placement.loads_ewma) if placement else None
            extra = (f" expert_imb={sl.max()/max(sl.mean(),1e-9):.2f}" if sl is not None
                     and sl.sum() else "")
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f}{extra}")
        if args.ckpt_dir and step > 0 and step % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step,
                            {"params": jax.tree.map(np.asarray, params),
                             "opt": jax.tree.map(np.asarray, opt)})
    dt = time.time() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps - start) * args.batch * args.seq / dt:.0f} tok/s)")
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps,
                        {"params": jax.tree.map(np.asarray, params),
                         "opt": jax.tree.map(np.asarray, opt)})


if __name__ == "__main__":
    main()
