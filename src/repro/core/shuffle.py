"""Device-side keyed shuffle: the DDPS stage boundary on a JAX mesh.

One shuffle step, executed under ``shard_map`` over the ``data`` axis, built
entirely on the unified exchange plane (``repro.exchange``):

1. every worker routes its local keys with the fused lookup+dispatch path
   (Pallas on TPU, jnp twin elsewhere — bit-identical),
2. the exchange primitive bucketizes records into a capacity-padded
   ``[W, cap]`` send buffer (overflow is counted per lane, never silently
   lost), runs the selected backend's collective — dense capacity-padded or
   ragged count-first — and unpacks the received rows,
3. the DRW hook emits the local top-k histogram + global per-partition loads
   (a ``psum`` — reusing normal DDPS communication, as the paper requires).

Partitions may outnumber workers (over-partitioning, paper Fig. 5);
``worker = partition % W``.

State migration (``make_migrate_step``) is the *same* exchange with lanes
sized by the planner: ``repro.core.migration.migration_capacity`` bounds the
per-lane rows to the planned peak transfer x slack, so a repartition ships a
buffer proportional to what actually moves instead of ``W * state_capacity``
rows.  Both steps report the backend's measured ``shipped_rows`` (globally
summed) next to the spec's padded provision, so the control plane sees what
the transport moved, not just what it reserved.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.hashing import KEY_SENTINEL
from repro.core.histogram import local_topk_histogram
from repro.core.partitioner import PartitionerTables, lookup_device
from repro.exchange import (
    ExchangeBackend,
    ExchangeSpec,
    Payload,
    make_exchange,
    route_dispatch,
)

__all__ = ["ShuffleResult", "make_shuffle_step", "make_migrate_step"]


class ShuffleResult(NamedTuple):
    keys: jax.Array       # int32[W, W*cap]   received keys per worker (sentinel padded)
    values: jax.Array     # f32[W, W*cap, D]  received payloads
    valid: jax.Array      # bool[W, W*cap]
    part: jax.Array       # int32[W, W*cap]   destination partition of each record
    loads: jax.Array      # int32[N]          global per-partition record counts
    hist_keys: jax.Array  # int32[W, K]       DRW local top-k keys
    hist_counts: jax.Array  # int32[W, K]
    overflow: jax.Array   # int32[]           records dropped for capacity globally
    lane_overflow: jax.Array  # int32[W]      global per-lane capacity drops
    shipped_rows: jax.Array   # int32[]       rows the backend moved, all workers


def make_shuffle_step(
    mesh: Mesh,
    *,
    num_partitions: int,
    capacity: int,
    hist_k: int = 64,
    num_hosts: int,
    seed: int = 0,
    axis: str = "data",
    backend: str | ExchangeBackend | None = None,
):
    """Build the jitted shuffle step for a fixed mesh/capacity/topology.

    An elastic resize rebuilds the step: ``num_partitions`` fixes the loads
    vector width, so the new topology needs a new closure (the migrate step
    does *not* — it routes at worker granularity, see
    :func:`make_migrate_step`).  ``backend`` selects the exchange transport
    (dense / ragged / an :class:`ExchangeBackend` instance).
    """
    num_workers = mesh.shape[axis]
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_workers, capacity=capacity, axis=axis), backend
    )

    def _local(tables, keys, vals, valid):
        # keys [n] local records of this worker
        tables = PartitionerTables(*tables)
        dest, slot, counts = route_dispatch(
            tables, keys, valid, num_hosts=num_hosts, seed=seed, num_lanes=num_workers
        )
        dest = jnp.where(valid, dest, 0)
        # the fused route pass already produced slots *and* per-lane counts:
        # bucketize derives neither again (no dispatch_count, no overflow
        # scatter), and the ragged backend's count phase reuses the counts
        res = ex(
            dest % num_workers,
            valid,
            [Payload(keys, KEY_SENTINEL), Payload(vals, 0), Payload(dest, 0)],
            slot=slot,
            counts=counts,
        )
        rva, (rk, rv, rp) = res.unpack()
        # DRW: sample local keys during normal work (no extra pass)
        hk, hc, _ = local_topk_histogram(keys, valid, hist_k)
        # global per-partition loads (normal DDPS comms: one psum)
        my_loads = jnp.zeros(num_partitions, jnp.int32).at[dest].add(valid.astype(jnp.int32))
        loads = jax.lax.psum(my_loads, axis)
        overflow = jax.lax.psum(res.send.overflow, axis)
        lane_overflow = jax.lax.psum(res.send.lane_overflow, axis)
        shipped = jax.lax.psum(res.shipped_rows, axis)
        return (
            rk[None],
            rv[None],
            rva[None],
            rp[None],
            loads,
            hk[None],
            hc[None],
            overflow,
            lane_overflow,
            shipped,
        )

    mapped = shard_map(
        _local,
        mesh=mesh,
        in_specs=(
            (P(), P(), P()),  # partitioner tables replicated
            P(axis),  # keys sharded over workers
            P(axis),
            P(axis),
        ),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(axis), P(axis), P(), P(), P()),
        check_vma=False,
    )

    # donate the per-batch buffers so the exchange compaction reuses them
    # instead of double-allocating (CPU has no donation — skip the warning)
    donate = () if jax.default_backend() == "cpu" else (1, 2, 3)

    @functools.partial(jax.jit, donate_argnums=donate)
    def step(tables: PartitionerTables, keys, vals, valid) -> ShuffleResult:
        rk, rv, rva, rp, loads, hk, hc, ov, lov, shipped = mapped(
            tuple(tables), keys, vals, valid
        )
        return ShuffleResult(rk, rv, rva, rp, loads, hk, hc, ov, lov, shipped)

    return step


def make_migrate_step(
    mesh: Mesh,
    *,
    state_capacity: int,
    num_hosts: int,
    lane_capacity: int | None = None,
    seed: int = 0,
    axis: str = "data",
    spec: ExchangeSpec | None = None,
    backend: str | ExchangeBackend | None = None,
):
    """Jitted operator-state migration for a partitioner swap.

    Each worker re-evaluates the new partitioner on its stored keys and
    ships rows whose worker changed through the exchange plane.
    ``lane_capacity`` bounds the per-(src, dst) rows of the all-to-all —
    pass ``migration_capacity(plan, num_workers=W)`` to size the exchange to
    the planned peak transfer x slack instead of the full state table
    (defaults to ``state_capacity``, the correctness-first upper bound).
    ``spec`` overrides the derived :class:`ExchangeSpec` entirely (the
    elastic-resize path re-derives the shuffle's spec); ``backend`` selects
    the transport.  The migrate step routes at *worker* granularity
    (``lookup % W``), so one step serves any partition count — a resize
    migration reuses the same jit cache.
    Returns the kept state + received rows + relative-migration metric +
    overflow + per-lane overflow + globally shipped rows.
    """
    num_workers = mesh.shape[axis]
    if spec is None:
        cap = state_capacity if lane_capacity is None else min(lane_capacity, state_capacity)
        spec = ExchangeSpec(num_lanes=num_workers, capacity=cap, axis=axis)
    ex = make_exchange(spec, backend)
    cap = spec.capacity

    def _local(new_tables, state_keys, state_vals):
        # state tables arrive stacked [1, S] / [1, S, D] per shard
        state_keys, state_vals = state_keys[0], state_vals[0]
        new_tables = PartitionerTables(*new_tables)
        me = jax.lax.axis_index(axis)
        valid = state_keys != KEY_SENTINEL
        dest = lookup_device(new_tables, state_keys, num_hosts, seed) % num_workers
        dest = jnp.where(valid, dest, me)  # padding stays put
        moving = valid & (dest != me)
        moved_w = jnp.sum(moving)
        total_w = jax.lax.psum(jnp.sum(valid), axis)

        res = ex(
            jnp.where(moving, dest, me),
            moving,
            [
                Payload(jnp.where(moving, state_keys, KEY_SENTINEL), KEY_SENTINEL),
                Payload(state_vals, 0),
            ],
        )
        rva, (rk, rv) = res.unpack()

        kept_keys = jnp.where(moving, KEY_SENTINEL, state_keys)
        kept_valid = valid & ~moving
        moved_total = jax.lax.psum(moved_w, axis)
        overflow = jax.lax.psum(res.send.overflow, axis)
        lane_overflow = jax.lax.psum(res.send.lane_overflow, axis)
        shipped = jax.lax.psum(res.shipped_rows, axis)
        return (
            kept_keys[None],
            state_vals[None],
            kept_valid[None],
            rk[None],
            rv[None],
            rva[None],
            moved_total,
            total_w,
            overflow,
            lane_overflow,
            shipped,
        )

    mapped = shard_map(
        _local,
        mesh=mesh,
        in_specs=((P(), P(), P()), P(axis), P(axis)),
        out_specs=(P(axis),) * 6 + (P(), P(), P(), P(), P()),
        check_vma=False,
    )

    # donate the state tables: the kept/received outputs alias them, so the
    # exchange compaction doesn't double-allocate the state (CPU: no-op)
    donate = () if jax.default_backend() == "cpu" else (1, 2)

    @functools.partial(jax.jit, donate_argnums=donate)
    def migrate(new_tables, state_keys, state_vals):
        return mapped(tuple(new_tables), state_keys, state_vals)

    return migrate
