"""Unified exchange plane — one routed all-to-all subsystem for shuffle,
state migration, and MoE dispatch, split spec + backend.  See
:mod:`repro.exchange.plane` (binding), :mod:`repro.exchange.spec` (shapes),
and :mod:`repro.exchange.backends` (transports)."""
from repro.exchange.backends import (
    DenseBackend,
    ExchangeBackend,
    HierarchicalBackend,
    LocalBackend,
    RaggedBackend,
    backend_name,
    resolve_backend,
)
from repro.exchange.plane import (
    Exchange,
    ExchangeResult,
    ExchangeSpec,
    ExchangeStats,
    ExchangeTopology,
    Payload,
    PendingExchange,
    SendInfo,
    make_exchange,
    route_bucketize,
    route_dispatch,
    take_from,
)

__all__ = [
    "DenseBackend",
    "Exchange",
    "ExchangeBackend",
    "ExchangeResult",
    "ExchangeSpec",
    "ExchangeStats",
    "ExchangeTopology",
    "HierarchicalBackend",
    "LocalBackend",
    "Payload",
    "PendingExchange",
    "RaggedBackend",
    "SendInfo",
    "backend_name",
    "make_exchange",
    "resolve_backend",
    "route_bucketize",
    "route_dispatch",
    "take_from",
]
