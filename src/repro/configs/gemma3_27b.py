"""gemma3-27b [dense]: 62L, d=5376, 32H (kv=16), d_ff=21504, vocab=262144.
5:1 local:global attention, 128k context, GeGLU, qk-norm, scaled embeddings.
62 layers = 10 periods of [5 local + 1 global] + 2 local tail.
[hf:google/gemma-3 family]"""
from repro.configs.base import ArchConfig, Block

_L = Block("local_attn", "dense")
_G = Block("attn", "dense")

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=(_L, _L, _L, _L, _L, _G),
    tail=(_L, _L),
    window=1024,
    ffn_kind="geglu",
    norm_kind="rmsnorm",
    rope_theta=1_000_000.0,
    rope_local_theta=10_000.0,
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=True,  # only 1/6 of layers keep a full-length KV cache
    notes="long_500k runs: local layers cache a 1024 window; global layers seq-shard KV",
)
