"""Architecture + shape configuration system.

Every assigned architecture is a :class:`ArchConfig` built from a repeating
``block pattern`` (the unit the runtime scans over), e.g. gemma3's
``5 local + 1 global`` or jamba's 8-layer Mamba/attention period.  Each block
entry names its mixer (attention / mamba / mlstm / slstm) and its FFN kind
(dense / moe / none).

``ShapeConfig`` encodes the four assigned input shapes; ``Cell`` = one
(arch x shape) dry-run unit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Mixer = Literal["attn", "local_attn", "mamba", "mlstm", "slstm"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class Block:
    mixer: Mixer
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_expert: bool = True
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[Block, ...]
    tail: tuple[Block, ...] = ()     # non-repeating final blocks (gemma3: 62 = 6*10 + 2)
    window: int = 1024               # for local_attn blocks
    moe: MoESpec | None = None
    ffn_kind: str = "swiglu"         # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    rope_kind: str = "rope"          # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # partial rotary (stablelm: 0.25)
    rope_local_theta: float = 0.0    # separate theta for local_attn (gemma3)
    qk_norm: bool = False
    embed_scale: bool = False        # gemma: embeddings scaled by sqrt(d)
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    # enc-dec (whisper)
    encdec: bool = False
    enc_layers: int = 0
    enc_len: int = 0                 # stub frontend sequence length
    # vlm stub
    vision_tokens: int = 0
    # ssm
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    # capability flags
    subquadratic: bool = False       # may run long_500k
    notes: str = ""

    def __post_init__(self):
        assert (self.num_layers - len(self.tail)) % len(self.pattern) == 0, (
            f"{self.name}: {self.num_layers} - tail {len(self.tail)} not a "
            f"multiple of pattern length {len(self.pattern)}"
        )
        assert self.num_heads % self.num_kv_heads == 0

    @property
    def num_periods(self) -> int:
        return (self.num_layers - len(self.tail)) // len(self.pattern)

    # ---- parameter count (for MODEL_FLOPS = 6*N*D) -----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        n += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        blocks = [(b, self.num_periods) for b in self.pattern] + [(b, 1) for b in self.tail]
        for blk, per in blocks:
            if blk.mixer in ("attn", "local_attn"):
                n += per * d * (self.num_heads + 2 * self.num_kv_heads) * hd
                n += per * self.num_heads * hd * d  # wo
            elif blk.mixer == "mamba":
                di = self.mamba_expand * d
                n += per * (2 * d * di + di * self.mamba_conv + di * (2 * self.mamba_d_state + 2) + di * d)
            elif blk.mixer in ("mlstm", "slstm"):
                di = 2 * d
                n += per * (2 * d * di + 3 * di * di // max(self.num_heads, 1) + di * d + d * di)
            if blk.ffn == "dense":
                gate = 2 if self.ffn_kind in ("swiglu", "geglu") else 1
                n += per * (gate + 1) * d * self.d_ff
            elif blk.ffn == "moe":
                m = self.moe
                gate = 2 if self.ffn_kind in ("swiglu", "geglu") else 1
                e = m.top_k if active_only else m.num_experts
                n += per * e * (gate + 1) * d * m.d_ff_expert
                if m.shared_expert:
                    n += per * (gate + 1) * d * m.d_ff_expert
                n += per * d * m.num_experts  # router
        if self.encdec:
            # encoder self-attn + ffn
            n += self.enc_layers * (d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d)
            n += self.enc_layers * 2 * d * self.d_ff
            # decoder cross-attn
            n += self.num_layers * (d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[str]:
    """Shape cells this arch runs (long_500k only for sub-quadratic archs)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one period, thin dims)."""
    pat = cfg.pattern
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, num_experts=min(moe.num_experts, 4), d_ff_expert=64)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=len(pat) + len(cfg.tail),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        moe=moe,
        enc_layers=min(cfg.enc_layers, 2),
        enc_len=min(cfg.enc_len, 32) if cfg.enc_len else 0,
        vision_tokens=min(cfg.vision_tokens, 8) if cfg.vision_tokens else 0,
        window=min(cfg.window, 16),
    )
