"""Beyond-paper — KIP expert placement for MoE (the in-model DR).

Simulates skewed routing (Zipf expert popularity, drifting) and measures
EP-shard load imbalance + expert migrations for: static placement, greedy
rebuild (Redist-analog), and KIP placement."""
from __future__ import annotations

import numpy as np

from repro.moe.kip_placement import PlacementController

E, SHARDS, STEPS = 128, 16, 40


def _loads(rng, step):
    ranks = rng.zipf(1.4, size=20_000)
    ranks = ranks[ranks <= E] - 1
    # drift: rotate expert popularity every 10 steps
    shift = (step // 10) * 17
    return np.bincount((ranks + shift) % E, minlength=E).astype(float)


def run():
    rows = []
    rng = np.random.default_rng(0)
    series = [_loads(rng, s) for s in range(STEPS)]

    # static identity placement
    ctl = PlacementController(E, SHARDS, trigger=10**9)  # never updates
    static_imb = [
        (lambda sl: sl.max() / sl.mean())(ctl.shard_loads(l / l.sum())) for l in series
    ]

    # KIP placement
    ctl = PlacementController(E, SHARDS, trigger=1.1)
    kip_imb, moved = [], 0
    for l in series:
        ctl.observe(l)
        changed, _, perm = ctl.maybe_update()
        moved += int((perm != np.arange(E)).sum())
        sl = ctl.shard_loads(l / l.sum())
        kip_imb.append(sl.max() / sl.mean())

    rows.append(("moe/imbalance_static", float(np.mean(static_imb)), "128e/16shards"))
    rows.append(("moe/imbalance_kip", float(np.mean(kip_imb)), ""))
    rows.append(("moe/imbalance_reduction", float(1 - np.mean(kip_imb) / np.mean(static_imb)),
                 "capacity-factor/ICI saving at fixed drop rate"))
    rows.append(("moe/experts_moved_total", float(moved),
                 f"over {STEPS} steps (migration = expert-weight all-to-all)"))
    assert np.mean(kip_imb) < np.mean(static_imb)

    # beyond paper^2: heavy-expert replication (16 extra physical slots)
    from repro.moe.kip_placement import replicated_assignment

    rep_imb = []
    for l in series:
        owner, shard_of = replicated_assignment(l, SHARDS, replicas=16)
        rel = l / max(l.sum(), 1e-12)
        counts = np.bincount(owner, minlength=E)
        eff = (rel / counts)[owner]
        sl = np.zeros(SHARDS)
        np.add.at(sl, shard_of, eff)
        rep_imb.append(sl.max() / sl.mean())
    rows.append(("moe/imbalance_kip_replicated", float(np.mean(rep_imb)),
                 "+16 replica slots: beats the single-expert floor"))
    assert np.mean(rep_imb) < np.mean(kip_imb)

    # dispatch through the real exchange plane: token drop rate at a fixed
    # capacity factor, static vs KIP placement (the ICI/VMEM currency the
    # placement buys back)
    import jax.numpy as jnp

    from repro.exchange import ExchangeSpec, Payload, make_exchange

    rng2 = np.random.default_rng(1)
    tokens = 16_384
    cf = 1.25
    cap = max(8, int(np.ceil(cf * tokens / SHARDS / 8.0) * 8))
    ex = make_exchange(ExchangeSpec(num_lanes=SHARDS, capacity=cap))
    ranks = rng2.zipf(1.4, size=4 * tokens)
    expert = (ranks[ranks <= E] - 1)[:tokens].astype(np.int32)

    ctl = PlacementController(E, SHARDS, trigger=1.1)
    ctl.observe(np.bincount(expert, minlength=E).astype(float))
    _, placement, _ = ctl.maybe_update()
    drops = {}
    for name, shard_of in [
        ("static", np.arange(E) // (E // SHARDS)),
        ("kip", placement.inv_place // (E // SHARDS)),
    ]:
        lane = jnp.asarray(shard_of[expert], jnp.int32)
        res = ex.bucketize(lane, jnp.ones(tokens, bool),
                           [Payload(jnp.asarray(expert), -1)])
        drops[name] = float(res.send.overflow) / tokens
    rows.append(("moe/dispatch_drop_static", drops["static"],
                 f"exchange-plane drop rate, cf={cf}"))
    rows.append(("moe/dispatch_drop_kip", drops["kip"], f"cf={cf}"))
    assert drops["kip"] <= drops["static"]
    return rows
