"""jit'd public wrappers around the Pallas kernels.

The wrappers pad inputs to kernel block multiples, pick interpret mode
automatically (Pallas interprets on CPU; compiled on TPU), and expose
numpy-friendly signatures used by the shuffle/runtime layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch_count import BLK as DISPATCH_BLK, dispatch_count
from repro.kernels.lookup_dispatch import BLK as ROUTE_BLK, lookup_dispatch
from repro.kernels.partition_apply import KEY_LANES, KEY_ROWS, partition_apply
from repro.kernels.route_bucketize import route_bucketize as _route_bucketize_kernel
from repro.kernels.sketch_update import sketch_update

_PART_BLK = KEY_LANES * KEY_ROWS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
    return x, n


def apply_partitioner(keys: jax.Array, tables, *, num_hosts: int, seed: int = 0) -> jax.Array:
    """Partition ids for ``keys`` using PartitionerTables (Pallas hot path)."""
    padded, n = _pad_to(keys.astype(jnp.int32), _PART_BLK)
    b = tables.heavy_keys.shape[0]
    bpad = (-b) % KEY_LANES
    hk = jnp.concatenate([tables.heavy_keys, jnp.full(bpad, 2**31 - 1, jnp.int32)]) if bpad else tables.heavy_keys
    hp = jnp.concatenate([tables.heavy_parts, jnp.zeros(bpad, jnp.int32)]) if bpad else tables.heavy_parts
    out = partition_apply(
        padded, hk, hp, tables.host_to_part,
        seed=seed, num_hosts=num_hosts, interpret=_interpret(),
    )
    return out[:n]


def count_sketch(keys: jax.Array, valid: jax.Array | None = None, *, depth: int = 4, width: int = 2048) -> jax.Array:
    """float32[depth, width] CMS of the batch (Pallas hot path)."""
    if valid is None:
        valid = jnp.ones(keys.shape[0], bool)
    k, n = _pad_to(keys.astype(jnp.int32), _PART_BLK)
    v, _ = _pad_to(valid.astype(jnp.int32), _PART_BLK)
    return sketch_update(k, v.astype(bool), depth=depth, width=width, interpret=_interpret())


def route_slots(keys: jax.Array, valid: jax.Array, tables, *, num_hosts: int,
                seed: int = 0, num_lanes: int, num_partitions: int = 0):
    """Fused partition lookup + lane slot (the exchange-plane hot path).

    Returns ``(part[n], slot[n], counts[num_lanes])`` — the slot ranks each
    valid record within its ``part % num_lanes`` lane.  ``num_partitions >
    0`` activates the split-key replica pick from ``tables.heavy_repl``.

    The kernel's replica pick is the stateless fmix32 offset; the jnp twin
    additionally supports the load-aware two-choice pick (``part_loads`` in
    ``kernels.ref``) — drivers that enable it must gate the Pallas path off
    statically (``use_pallas=False`` in the exchange plane), never per
    batch, so kernel and twin cannot diverge at runtime.
    """
    k, n = _pad_to(keys.astype(jnp.int32), ROUTE_BLK)
    v, _ = _pad_to(valid.astype(jnp.int32), ROUTE_BLK)
    b = tables.heavy_keys.shape[0]
    bpad = (-b) % KEY_LANES
    hk = jnp.concatenate([tables.heavy_keys, jnp.full(bpad, 2**31 - 1, jnp.int32)]) if bpad else tables.heavy_keys
    hp = jnp.concatenate([tables.heavy_parts, jnp.zeros(bpad, jnp.int32)]) if bpad else tables.heavy_parts
    hr = None
    if num_partitions > 0:
        # pad replica rows with 0: sentinel matches sum to 0 -> clamp to 1
        hr = jnp.concatenate([tables.heavy_repl, jnp.zeros(bpad, jnp.int32)]) if bpad else tables.heavy_repl
    part, slot, counts = lookup_dispatch(
        k, v.astype(bool), hk, hp, tables.host_to_part, hr,
        seed=seed, num_hosts=num_hosts, num_lanes=num_lanes,
        num_partitions=num_partitions, interpret=_interpret(),
    )
    return part[:n], slot[:n], counts


def route_bucketize(keys: jax.Array, valid: jax.Array, tables, vals: jax.Array, *,
                    num_hosts: int, seed: int = 0, num_lanes: int, capacity: int,
                    key_fill: int, num_partitions: int = 0,
                    interpret: bool | None = None):
    """Fused route + slot + bucketize (the split-phase exchange's start path).

    Returns ``(part[n], slot[n], counts[L], buf_valid[L, cap] bool,
    buf_keys[L, cap] int32, buf_vals[L, cap, D] f32, buf_part[L, cap]
    int32)`` — the shuffle's three send buffers built in one kernel pass,
    bit-identical to ``route_slots`` + the plane's scatter.  The kernel
    emits raw f32 channels (int32 split into 16-bit halves for f32-matmul
    exactness); this wrapper recombines them and applies the fills.
    """
    if interpret is None:
        interpret = _interpret()
    k, n = _pad_to(keys.astype(jnp.int32), ROUTE_BLK)
    v, _ = _pad_to(valid.astype(jnp.int32), ROUTE_BLK)
    w, _ = _pad_to(vals.astype(jnp.float32), ROUTE_BLK)
    b = tables.heavy_keys.shape[0]
    # an empty heavy table still needs one tile of (sentinel) rows for the
    # kernel's fixed block shape; sentinel keys only match invalid records,
    # whose part is masked by every consumer
    bpad = KEY_LANES if b == 0 else (-b) % KEY_LANES
    hk = jnp.concatenate([tables.heavy_keys, jnp.full(bpad, 2**31 - 1, jnp.int32)]) if bpad else tables.heavy_keys
    hp = jnp.concatenate([tables.heavy_parts, jnp.zeros(bpad, jnp.int32)]) if bpad else tables.heavy_parts
    hr = None
    if num_partitions > 0:
        # pad replica rows with 0: sentinel matches sum to 0 -> clamp to 1
        hr = jnp.concatenate([tables.heavy_repl, jnp.zeros(bpad, jnp.int32)]) if bpad else tables.heavy_repl
    # scatter into a lane-tile-aligned buffer; the overflow columns the ref
    # drops land in the pad and are sliced away below
    cap_p = int(-(-capacity // 128) * 128)
    part, slot, counts, bvalid, bkhi, bklo, bphi, bplo, bvals = _route_bucketize_kernel(
        k, v.astype(bool), w, hk, hp, tables.host_to_part, hr,
        seed=seed, num_hosts=num_hosts, num_lanes=num_lanes, capacity=cap_p,
        num_partitions=num_partitions, interpret=interpret,
    )
    buf_valid = bvalid[:, :capacity] > 0.0

    def _combine(hi, lo):
        u = (hi[:, :capacity].astype(jnp.uint32) << jnp.uint32(16)) | \
            lo[:, :capacity].astype(jnp.uint32)
        return u.astype(jnp.int32)

    buf_keys = jnp.where(buf_valid, _combine(bkhi, bklo), key_fill)
    buf_part = jnp.where(buf_valid, _combine(bphi, bplo), 0)
    buf_vals = jnp.where(buf_valid[:, :, None],
                         jnp.moveaxis(bvals, 0, -1)[:, :capacity], 0.0)
    return part[:n], slot[:n], counts, buf_valid, buf_keys, buf_vals, buf_part


def dispatch_slots(dest: jax.Array, valid: jax.Array | None = None, *, num_parts: int):
    """(slot[n], counts[num_parts]) for building the all-to-all send buffer."""
    if valid is None:
        valid = jnp.ones(dest.shape[0], bool)
    d, n = _pad_to(dest.astype(jnp.int32), DISPATCH_BLK)
    v, _ = _pad_to(valid.astype(jnp.int32), DISPATCH_BLK)
    slot, counts = dispatch_count(d, v.astype(bool), num_parts=num_parts, interpret=_interpret())
    return slot[:n], counts
