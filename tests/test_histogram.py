"""Tests for the DRW/DRM histogram machinery and sketch baselines."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CounterSketch,
    CountMinSketch,
    Histogram,
    LossyCounting,
    SpaceSaving,
    local_topk_histogram,
)
from repro.data.generators import drifting_zipf, zipf_keys


def test_exact_histogram():
    h = Histogram.exact(np.array([1, 1, 1, 2, 2, 3]))
    assert h.keys[0] == 1 and abs(h.freqs[0] - 0.5) < 1e-12
    assert abs(h.freqs.sum() - 1.0) < 1e-12 and h.tail_mass < 1e-12


def test_top_b_tail_mass():
    h = Histogram.exact(np.arange(100).repeat(2)).top(10)
    assert len(h) == 10
    assert abs(h.tail_mass - 0.9) < 1e-12


def test_ewma_drift():
    old = Histogram.from_counts(np.array([1, 2]), np.array([9.0, 1.0]))
    new = Histogram.from_counts(np.array([3, 2]), np.array([9.0, 1.0]))
    mixed = old.ewma(new, alpha=0.5)
    d = dict(zip(mixed.keys.tolist(), mixed.freqs.tolist()))
    assert abs(d[1] - 0.45) < 1e-12  # decayed
    assert abs(d[3] - 0.45) < 1e-12  # arriving
    assert abs(d[2] - 0.10) < 1e-12  # persistent


class TestCounterSketch:
    def test_finds_heavy_hitters(self):
        cs = CounterSketch(capacity=64)
        stream = zipf_keys(100_000, num_keys=10_000, exponent=1.2, seed=0)
        for i in range(0, len(stream), 10_000):
            cs.update(stream[i : i + 10_000])
        est = cs.histogram(top_b=10)
        exact = Histogram.exact(stream).top(10)
        overlap = len(set(est.keys.tolist()) & set(exact.keys.tolist()))
        assert overlap >= 8
        assert cs.memory_items <= 64

    def test_overestimates_only(self):
        """SpaceSaving-style merge keeps estimates >= true counts."""
        cs = CounterSketch(capacity=8)
        rng = np.random.default_rng(1)
        stream = rng.integers(0, 50, 5_000)
        for i in range(0, len(stream), 500):
            cs.update(stream[i : i + 500])
        h = cs.histogram()
        true = Histogram.exact(stream)
        td = dict(zip(true.keys.tolist(), (true.freqs * true.total_weight).tolist()))
        for k, f in zip(h.keys.tolist(), h.freqs.tolist()):
            assert f * cs.total >= td.get(k, 0) - 1e-6

    def test_decay_forgets(self):
        cs = CounterSketch(capacity=32, decay=0.5)
        cs.update(np.full(1000, 7))
        for _ in range(12):
            cs.update(np.arange(100) + 1000)
        h = cs.histogram(top_b=5)
        assert 7 not in h.keys[:3].tolist()

    def test_rescale_drops_stale_tail(self):
        """Resize-aware re-warm: after evictions raised the floor, entries
        with no evidence beyond the inherited floor are dropped, so a grown
        ``top_b`` window cannot surface them as heavy keys."""
        cs = CounterSketch(capacity=8)
        heavy = np.repeat(np.arange(4), 500)  # keys 0..3, 500 each
        cs.update(heavy)
        # parade of one-off keys: forces evictions, raises the floor, and
        # leaves the last arrivals sitting at ~floor + 1 (stale tail)
        for k in range(100, 140):
            cs.update(np.array([k]))
        assert cs._floor > 0
        before = set(cs.histogram(top_b=16).keys.tolist())
        assert before - {0, 1, 2, 3}, "parade keys should pollute the window"
        dropped = cs.rescale()
        assert dropped > 0
        after = cs.histogram(top_b=16)
        assert set(after.keys.tolist()) == {0, 1, 2, 3}
        # a fresh sketch that never evicted is untouched
        cs2 = CounterSketch(capacity=64)
        cs2.update(heavy)
        assert cs2.rescale() == 0 and cs2.memory_items == 4


def test_spacesaving_error_bound():
    """|est - true| <= total/capacity (classic SpaceSaving guarantee)."""
    ss = SpaceSaving(capacity=50)
    stream = zipf_keys(20_000, num_keys=1_000, exponent=1.3, seed=2)
    ss.update(stream)
    h = ss.histogram()
    true = Histogram.exact(stream)
    td = dict(zip(true.keys.tolist(), (true.freqs * true.total_weight).tolist()))
    bound = len(stream) / 50
    for k, f in zip(h.keys.tolist(), h.freqs.tolist()):
        assert abs(f * ss.total - td.get(k, 0)) <= bound + 1e-6


def test_lossy_counting_bound():
    eps = 0.001
    lc = LossyCounting(epsilon=eps)
    stream = zipf_keys(50_000, num_keys=5_000, exponent=1.2, seed=3)
    lc.update(stream)
    true = Histogram.exact(stream)
    td = dict(zip(true.keys.tolist(), (true.freqs * true.total_weight).tolist()))
    for k, f in zip(lc.histogram().keys.tolist(), lc.histogram().freqs.tolist()):
        c = f * lc.total
        assert c <= td.get(k, 0) + 1e-6  # lossy counting under-estimates
        assert c >= td.get(k, 0) - eps * len(stream) - 1e-6


def test_cms_overestimates():
    cms = CountMinSketch(depth=4, width=512)
    stream = zipf_keys(30_000, num_keys=3_000, exponent=1.1, seed=4)
    cms.update(stream)
    true = Histogram.exact(stream)
    keys = true.keys[:20]
    est = cms.estimate(keys)
    tc = true.freqs[:20] * true.total_weight
    assert np.all(est >= tc - 1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 500), cap=st.integers(4, 64))
def test_prop_countersketch_total_conserved(seed, cap):
    rng = np.random.default_rng(seed)
    stream = rng.integers(0, 100, size=2_000)
    cs = CounterSketch(capacity=cap)
    for i in range(0, 2000, 250):
        cs.update(stream[i : i + 250])
    assert abs(cs.total - 2000) < 1e-6


def test_local_topk_device_matches_exact():
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 40, size=512).astype(np.int32)
    valid = np.ones(512, bool)
    valid[500:] = False
    tk, tc, total = local_topk_histogram(jnp.asarray(keys), jnp.asarray(valid), k=8)
    exact = Histogram.exact(keys[:500]).top(8)
    assert int(total) == 500
    got = dict(zip(np.asarray(tk).tolist(), np.asarray(tc).tolist()))
    want = dict(zip(exact.keys.tolist(), (exact.freqs * 500).round().astype(int).tolist()))
    for k, c in want.items():
        assert got.get(k) == c


def test_local_topk_all_invalid():
    tk, tc, total = local_topk_histogram(
        jnp.zeros(64, jnp.int32), jnp.zeros(64, bool), k=4
    )
    assert int(total) == 0
    assert np.all(np.asarray(tc) == 0)
