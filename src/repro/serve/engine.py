"""Batched serving engine: continuous batching over prefill/decode steps.

Slots hold active sequences; each engine tick decodes one token for every
active slot (one jitted ``decode_step``), admits new requests into free
slots via ``prefill``, and retires finished sequences.  The KV cache is the
operator state of the paper's mapping — the DR scheduler
(``repro.serve.scheduler``) decides which *replica* owns which session key,
and session migration moves this cache.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model
from repro.models.modules import Policy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32[prompt_len]
    max_new_tokens: int
    session_key: int = 0        # partitioning key for the DR scheduler
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-replica engine with a fixed slot count (= max batch)."""

    def __init__(self, cfg: ArchConfig, params, pol: Policy, *, slots: int = 4,
                 max_len: int = 256, eos_id: int | None = None):
        self.cfg, self.params, self.pol = cfg, params, pol
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.active: list[Request | None] = [None] * slots
        self._caches: list = [None] * slots
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, cfg, pol)
        )
        self.steps = 0
        self.tokens_out = 0

    # -- admission --------------------------------------------------------
    def admit(self, req: Request) -> bool:
        for i in range(self.slots):
            if self.active[i] is None:
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = model.prefill(
                    self.params, {"tokens": toks}, self.cfg, self.pol,
                    max_len=self.max_len,
                )
                nxt = int(jnp.argmax(logits[0, -1, : self.cfg.vocab_size]))
                req.out_tokens.append(nxt)
                self.active[i] = req
                self._caches[i] = (cache, nxt)
                return True
        return False

    # -- one decode tick over all active slots ---------------------------
    def tick(self) -> int:
        produced = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            cache, last = self._caches[i]
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([[last]], jnp.int32)
            )
            nxt = int(jnp.argmax(logits[0, -1, : self.cfg.vocab_size]))
            req.out_tokens.append(nxt)
            self._caches[i] = (cache, nxt)
            produced += 1
            self.tokens_out += 1
            if len(req.out_tokens) >= req.max_new_tokens or (
                self.eos_id is not None and nxt == self.eos_id
            ):
                req.done = True
                self.active[i] = None
                self._caches[i] = None
        self.steps += 1
        return produced

    @property
    def free_slots(self) -> int:
        return sum(1 for a in self.active if a is None)

    def run(self, requests: list[Request], max_ticks: int = 1000) -> list[Request]:
        pending = list(requests)
        done: list[Request] = []
        for _ in range(max_ticks):
            while pending and self.free_slots:
                self.admit(pending.pop(0))
            if not pending and all(a is None for a in self.active):
                break
            self.tick()
            done.extend(r for r in [a for a in self.active] if r and r.done)
        return requests
