"""Integration tests: shuffle, keyed state, migration, streaming DR loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Histogram, kip_update, uniform_partitioner
from repro.core.drm import DRConfig, DRMaster
from repro.core.hashing import KEY_SENTINEL
from repro.core.replay import BatchJob
from repro.core.shuffle import make_shuffle_step
from repro.core.state import empty_state, merge_into
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf, zipf_keys


# ---------------------------------------------------------------------------
# state store
# ---------------------------------------------------------------------------


def test_merge_into_sums():
    sk, sv = empty_state(16, 1)
    bk = jnp.asarray([3, 5, 3, 9], jnp.int32)
    bv = jnp.ones((4, 1), jnp.float32)
    valid = jnp.ones(4, bool)
    sk, sv, ov = merge_into(sk, sv, bk, bv, valid)
    sk2, sv2, ov2 = merge_into(sk, sv, bk, bv, valid)
    d = dict(zip(np.asarray(sk2).tolist(), np.asarray(sv2)[:, 0].tolist()))
    assert d[3] == 4.0 and d[5] == 2.0 and d[9] == 2.0
    assert int(ov) == 0 and int(ov2) == 0


def test_merge_overflow_reported():
    sk, sv = empty_state(4, 1)
    bk = jnp.arange(8, dtype=jnp.int32)
    sk, sv, ov = merge_into(sk, sv, bk, jnp.ones((8, 1)), jnp.ones(8, bool))
    assert int(ov) == 4


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 500))
def test_prop_merge_conserves_mass(seed):
    rng = np.random.default_rng(seed)
    sk, sv = empty_state(256, 1)
    total = 0.0
    for _ in range(3):
        bk = rng.integers(0, 100, 64).astype(np.int32)
        bv = rng.random((64, 1)).astype(np.float32)
        valid = rng.random(64) < 0.8
        total += float(bv[valid].sum())
        sk, sv, ov = merge_into(sk, sv, jnp.asarray(bk), jnp.asarray(bv), jnp.asarray(valid))
        assert int(ov) == 0
    np.testing.assert_allclose(float(jnp.sum(sv)), total, rtol=1e-5)


# ---------------------------------------------------------------------------
# shuffle step (single device mesh exercises the full shard_map path)
# ---------------------------------------------------------------------------


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_shuffle_routes_by_partitioner():
    mesh = _mesh1()
    part = uniform_partitioner(1)
    step = make_shuffle_step(mesh, num_partitions=1, capacity=64, num_hosts=part.num_hosts)
    keys = jnp.asarray(np.arange(10), jnp.int32)
    vals = jnp.ones((10, 1), jnp.float32)
    valid = jnp.ones(10, bool)
    res = step(part.tables(), keys, vals, valid)
    got = np.sort(np.asarray(res.keys[0])[np.asarray(res.valid[0])])
    np.testing.assert_array_equal(got, np.arange(10))
    assert int(res.overflow) == 0
    assert int(res.loads.sum()) == 10


def test_shuffle_overflow_counted():
    mesh = _mesh1()
    part = uniform_partitioner(1)
    step = make_shuffle_step(mesh, num_partitions=1, capacity=8, num_hosts=part.num_hosts)
    keys = jnp.asarray(np.arange(20), jnp.int32)
    res = step(part.tables(), keys, jnp.ones((20, 1)), jnp.ones(20, bool))
    assert int(res.overflow) == 12
    assert int(np.asarray(res.valid).sum()) == 8


def test_shuffle_hist_matches_batch():
    mesh = _mesh1()
    part = uniform_partitioner(1)
    step = make_shuffle_step(mesh, num_partitions=1, capacity=512, num_hosts=part.num_hosts, hist_k=8)
    keys = np.array([7] * 30 + [11] * 20 + [13] * 10, np.int32)
    res = step(part.tables(), jnp.asarray(keys), jnp.ones((60, 1)), jnp.ones(60, bool))
    hk = np.asarray(res.hist_keys)[0]
    hc = np.asarray(res.hist_counts)[0]
    top = dict(zip(hk.tolist(), hc.tolist()))
    assert top[7] == 30 and top[11] == 20 and top[13] == 10


# ---------------------------------------------------------------------------
# streaming job end-to-end
# ---------------------------------------------------------------------------


def test_wordcount_exact():
    """Stateful word count through shuffle+DR is exactly correct."""
    job = StreamingJob(state_capacity=2048, dr_enabled=True)
    rng = np.random.default_rng(0)
    stream = rng.integers(0, 200, size=3 * 1024)
    for i in range(3):
        job.process_batch(stream[i * 1024 : (i + 1) * 1024])
    for key in [0, 17, 199]:
        assert job.state_count(int(key)) == float((stream == key).sum())


def test_dr_triggers_and_improves_on_skew():
    job = StreamingJob(
        num_partitions=8,
        state_capacity=8192,
        dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1),
    )
    batches = list(drifting_zipf(6, 8192, num_keys=2_000, exponent=1.4, drift_every=100, seed=1))
    ms = job.run(batches)
    assert any(m.repartitioned for m in ms)
    first, last = ms[0].imbalance, ms[-1].imbalance
    assert last < first  # DR improved partition balance
    # state must survive migration intact
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:5]:
        assert job.state_count(int(key)) == float((all_keys == key).sum())


def test_dr_idle_on_uniform_stream():
    job = StreamingJob(num_partitions=4, dr=DRConfig(imbalance_trigger=1.5))
    rng = np.random.default_rng(2)
    ms = job.run([rng.integers(0, 100_000, 4096) for _ in range(3)])
    assert not any(m.repartitioned for m in ms)


def test_checkpoint_restore_resumes():
    job = StreamingJob(num_partitions=4, state_capacity=4096,
                       dr=DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0))
    batches = [zipf_keys(4096, num_keys=500, exponent=1.3, seed=s) for s in range(4)]
    job.process_batch(batches[0])
    job.process_batch(batches[1])
    snap = job.snapshot()
    # simulate crash: brand-new job, restore snapshot, continue
    job2 = StreamingJob(num_partitions=4, state_capacity=4096,
                        dr=DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0))
    job2.restore(snap)
    job.process_batch(batches[2])
    job2.process_batch(batches[2])
    all_keys = np.concatenate(batches[:3])
    for key in np.unique(all_keys)[:5]:
        assert job2.state_count(int(key)) == pytest.approx(float((all_keys == key).sum()))
        assert job2.state_count(int(key)) == pytest.approx(job.state_count(int(key)))


def test_flink_mode_checkpoint_gating():
    job = StreamingJob(
        num_partitions=4,
        checkpoint_interval=3,
        dr=DRConfig(imbalance_trigger=1.0, migration_cost_weight=0.0),
    )
    batches = [zipf_keys(4096, num_keys=500, exponent=1.5, seed=s) for s in range(6)]
    ms = job.run(batches)
    for i, m in enumerate(ms):
        if (i + 1) % 3 != 0:
            assert not m.repartitioned


# ---------------------------------------------------------------------------
# batch replay
# ---------------------------------------------------------------------------


def test_batch_replay_improves():
    keys = zipf_keys(100_000, num_keys=20_000, exponent=1.2, seed=3)
    res = BatchJob(num_partitions=8, sample_fraction=0.1).run(keys)
    assert res.imbalance_after <= res.imbalance_before
    assert res.assignments.min() >= 0 and res.assignments.max() < 8


def test_batch_replay_noop_when_uniform():
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 10**6, 50_000)
    res = BatchJob(num_partitions=8).run(keys)
    assert res.imbalance_after <= res.imbalance_before + 1e-9


# ---------------------------------------------------------------------------
# legacy snapshot restore (forward compatibility with older checkpoints)
# ---------------------------------------------------------------------------


def _legacy_roundtrip(strip_prefixes):
    """Cut a snapshot, delete newer key families, restore into a fresh job
    and continue — per-key totals must still be conserved."""
    mk = lambda: StreamingJob(
        num_partitions=4, state_capacity=4096,
        dr=DRConfig(imbalance_trigger=1e9))
    batches = [zipf_keys(2048, num_keys=300, exponent=1.3, seed=s)
               for s in range(3)]
    job = mk()
    job.process_batch(batches[0])
    job.process_batch(batches[1])
    snap = job.snapshot()
    stripped = {k: v for k, v in snap.items()
                if not any(k.startswith(p) for p in strip_prefixes)}
    job2 = mk()
    job2.restore(stripped)
    job2.process_batch(batches[2])
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:5]:
        assert job2.state_count(int(key)) == pytest.approx(
            float((all_keys == key).sum()))
    return job2


def test_restore_legacy_snapshot_without_backend_key():
    job = _legacy_roundtrip(["drm_exchange_backend"])
    # pre-backend snapshot: the job's construction-time transport stands
    assert job.exchange_backend.name == "dense"
    assert job.drm.exchange_backend is job.exchange_backend


def test_restore_legacy_snapshot_without_topology_keys():
    job = _legacy_roundtrip(["drm_topology"])
    assert job.exchange_topology is None  # flat world stands


def test_restore_legacy_snapshot_without_split_keys():
    job = _legacy_roundtrip(["drm_split"])
    assert job.drm.split_keys == {}  # nothing splits until re-evidenced


def test_restore_legacy_snapshot_without_health_keys():
    job = _legacy_roundtrip(["drm_health", "drm_quarantined",
                             "drm_last_health_action"])
    assert job.drm.lane_health is None
    assert job.drm.quarantined == []


def test_restore_legacy_snapshot_minimal():
    # the original PR-5 era snapshot: state + partitioner/sketch only
    job = _legacy_roundtrip(["drm_exchange_backend", "drm_topology",
                             "drm_split", "drm_health", "drm_quarantined",
                             "drm_last_health_action", "drm_backend_streak",
                             "drm_last_backend_switch"])
    assert job.drm.lane_health is None
