"""Train-step factory: loss + grad + AdamW update, DR expert stats out.

``make_train_step`` closes over (cfg, policy, opt config) and returns a
jittable ``step(params, opt_state, batch, inv_place) -> (params, opt_state,
metrics)``.  The MoE expert-load counts ride along in ``metrics`` — they are
the DRW histogram the PlacementController consumes between steps (safe
points = step boundaries, exactly the paper's micro-batch integration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model
from repro.models.modules import Policy
from repro.train.optimizer import OptConfig, OptState, apply_updates, init_opt


def make_train_step(cfg: ArchConfig, pol: Policy, opt: OptConfig):
    def step(params, opt_state: OptState, batch: dict, inv_place=None):
        def lf(p):
            return model.loss_fn(p, batch, cfg, pol, inv_place)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, opt_metrics = apply_updates(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return step


def make_eval_step(cfg: ArchConfig, pol: Policy):
    def step(params, batch: dict, inv_place=None):
        loss, metrics = model.loss_fn(params, batch, cfg, pol, inv_place)
        return {"loss": loss, **metrics}

    return step
