"""Dynamic Repartitioning Master — the central DR authority.

Lives in the launcher ("Driver") process.  Per micro-batch it:

1. merges the DRW local histograms into the global counter sketch
   (EWMA over past histograms — drift-respecting),
2. evaluates the trigger: planned-imbalance improvement vs. migration cost
   ("the gains for repartitioning should exceed state migration costs"),
3. on trigger, runs KIPUPDATE and hands the new partitioner tables to the
   runtime to swap at the safe point (micro-batch boundary / checkpoint).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.histogram import CounterSketch, Histogram
from repro.core.partitioner import Partitioner, expected_loads, kip_update, resize_partitioner

__all__ = ["DRConfig", "DRMaster", "DRDecision"]


@dataclasses.dataclass(frozen=True)
class DRConfig:
    lam: float = 2.0                 # histogram scale factor: B = lam * N
    eps: float = 0.01                # KIP load slack
    ewma_alpha: float = 0.5          # weight of the newest histogram
    sketch_capacity: int = 512       # DRM counter sketch size
    sketch_decay: float = 0.9
    imbalance_trigger: float = 1.2   # repartition when measured imb exceeds
    migration_cost_weight: float = 1.0  # batches of gain a migration must pay for
    min_batches_between: int = 1     # safe-point spacing (1 = every boundary)
    mode: str = "stream"             # "stream" | "batch" (replay-once)
    tight: bool = True               # waterfilled host re-binning (beyond-paper;
                                     # False = faithful Algorithm 1 packing)
    # -- elastic resize: grow/shrink the partition (logical worker) count --
    elastic: bool = False            # let the DRM decide to resize
    min_partitions: int = 1          # shrink floor (also floored at num_workers)
    max_partitions: int = 256        # grow ceiling
    grow_trigger: float = 1.5        # sustained imbalance above this => grow
    shrink_trigger: float = 1.05     # sustained imbalance below this => shrink
    resize_patience: int = 2         # consecutive safe points before acting
    resize_factor: int = 2           # grow/shrink multiplies/divides by this


@dataclasses.dataclass(frozen=True)
class DRDecision:
    repartition: bool
    partitioner: Partitioner
    planned_imbalance: float
    measured_imbalance: float
    est_migration: float
    reason: str


class DRMaster:
    def __init__(self, initial: Partitioner, config: DRConfig = DRConfig()):
        self.config = config
        self.partitioner = initial
        self.sketch = CounterSketch(config.sketch_capacity, decay=config.sketch_decay)
        self.batches_seen = 0
        self.last_repartition = -(10**9)
        self.history: list[dict] = []
        # elastic-resize policy state: how many consecutive safe points the
        # grow/shrink condition has held (the "sustained" part of the policy)
        self.grow_streak = 0
        self.shrink_streak = 0

    # -- DRW ingestion ------------------------------------------------------
    def observe(self, hist_keys: np.ndarray, hist_counts: np.ndarray,
                total_records: float | None = None) -> None:
        """Merge stacked worker histograms [W, K] into the DRM sketch.

        ``total_records`` is the true number of records the workers saw
        (top-k summaries undercount the tail mass)."""
        k = np.asarray(hist_keys).reshape(-1)
        c = np.asarray(hist_counts).reshape(-1).astype(np.float64)
        m = (k >= 0) & (c > 0)
        if m.any():
            keys, inv = np.unique(k[m], return_inverse=True)
            counts = np.zeros(len(keys))
            np.add.at(counts, inv, c[m])
            self.sketch.update_counts(keys.astype(np.int64), counts, total=total_records)

    # -- decision -----------------------------------------------------------
    def decide(self, loads: np.ndarray, state_rows: float = 0.0) -> DRDecision:
        """Called at each safe point with measured per-partition loads."""
        cfg = self.config
        self.batches_seen += 1
        n = self.partitioner.num_partitions
        loads = np.asarray(loads, np.float64)
        measured = float(loads.max() / max(loads.mean(), 1e-12)) if loads.sum() else 1.0

        hist = self.sketch.histogram(top_b=int(cfg.lam * n))
        if len(hist) == 0:
            return self._no(measured, "no-histogram")
        if self.batches_seen - self.last_repartition < cfg.min_batches_between:
            return self._no(measured, "safe-point-spacing")
        if cfg.mode == "batch" and self.last_repartition > 0:
            return self._no(measured, "batch-replayed-once")
        if measured < cfg.imbalance_trigger:
            return self._no(measured, "balanced")

        # fixed heavy-table width => stable jit signatures across swaps
        cap = max(self.partitioner.heavy_keys.shape[0], int(np.ceil(cfg.lam * n / 128.0) * 128))
        candidate = kip_update(self.partitioner, hist, eps=cfg.eps, heavy_capacity=cap,
                               tight=cfg.tight)
        planned = expected_loads(candidate, hist)
        planned_imb = float(planned.max() * n)
        gain = measured - planned_imb
        # migration cost estimate: heavy keys that change partition carry
        # state proportional to their frequency
        old_p = self.partitioner.lookup_np(hist.keys.astype(np.int32))
        new_p = candidate.lookup_np(hist.keys.astype(np.int32))
        est_migration = float(hist.freqs[old_p != new_p].sum())
        cost = cfg.migration_cost_weight * est_migration
        if gain <= cost:
            return DRDecision(False, self.partitioner, planned_imb, measured, est_migration,
                              f"gain {gain:.3f} <= cost {cost:.3f}")
        self.partitioner = candidate
        self.last_repartition = self.batches_seen
        d = DRDecision(True, candidate, planned_imb, measured, est_migration, "repartition")
        self.history.append(dataclasses.asdict(d) | {"batch": self.batches_seen})
        return d

    def _no(self, measured: float, reason: str) -> DRDecision:
        return DRDecision(False, self.partitioner, measured, measured, 0.0, reason)

    # -- elastic resize policy ----------------------------------------------
    def decide_resize(self, loads: np.ndarray, *, num_workers: int = 1) -> int | None:
        """Policy hook: should the job change its partition count?

        Called at checkpoint safe points with measured per-partition loads.
        Returns the new partition count, or ``None`` to keep the topology.
        The rule is sustained-imbalance vs. worker count: ``resize_patience``
        consecutive safe points above ``grow_trigger`` grow the topology by
        ``resize_factor`` (a hotspot KIP cannot spread over the current bins
        gets more bins); the same patience below ``shrink_trigger`` shrinks
        it (an idle/uniform stream does not pay for over-partitioning).
        ``num_workers`` floors the shrink — never fewer partitions than
        physical workers.
        """
        cfg = self.config
        if not cfg.elastic:
            return None
        loads = np.asarray(loads, np.float64)
        n = self.partitioner.num_partitions
        imb = float(loads.max() / max(loads.mean(), 1e-12)) if loads.sum() else 1.0
        floor = max(cfg.min_partitions, num_workers)
        if imb >= cfg.grow_trigger and n < cfg.max_partitions:
            self.grow_streak += 1
            self.shrink_streak = 0
            if self.grow_streak >= cfg.resize_patience:
                self.grow_streak = 0
                return min(n * cfg.resize_factor, cfg.max_partitions)
        elif imb <= cfg.shrink_trigger and n > floor:
            self.shrink_streak += 1
            self.grow_streak = 0
            if self.shrink_streak >= cfg.resize_patience:
                self.shrink_streak = 0
                return max(n // cfg.resize_factor, floor)
        else:
            self.grow_streak = self.shrink_streak = 0
        return None

    def replan_resize(self, num_partitions: int) -> Partitioner:
        """Re-plan the partitioner cross-size and install it at a safe point.

        The one resize re-planning path shared by ``StreamingJob`` and
        ``DRScheduler``: heavy keys come from the current sketch (scaled to
        the new ``lam * n`` budget), the heavy-table width follows the new
        topology, and the swap is recorded via :meth:`note_resize`.
        """
        cfg = self.config
        n = int(num_partitions)
        hist = self.sketch.histogram(top_b=int(np.ceil(cfg.lam * n)))
        heavy_cap = int(np.ceil(max(1.0, cfg.lam * n) / 128.0) * 128)
        new = resize_partitioner(self.partitioner, n, hist, eps=cfg.eps,
                                 heavy_capacity=heavy_cap, tight=cfg.tight)
        self.note_resize(new)
        return new

    def note_resize(self, new: Partitioner) -> None:
        """Install a resized partitioner at a safe point (DRM bookkeeping).

        Counts as this safe point's decision: advances ``batches_seen`` and
        ``last_repartition`` so the safe-point spacing applies to resizes
        exactly as to plain repartitions.
        """
        old_n = self.partitioner.num_partitions
        self.batches_seen += 1
        self.partitioner = new
        self.last_repartition = self.batches_seen
        self.grow_streak = self.shrink_streak = 0
        self.history.append({
            "batch": self.batches_seen,
            "resize": (old_n, new.num_partitions),
            "reason": f"resize {old_n}->{new.num_partitions}",
        })

    # -- checkpoint integration ----------------------------------------------
    def snapshot(self) -> dict:
        p = self.partitioner
        return {
            "num_partitions": p.num_partitions,
            "heavy_keys": p.heavy_keys,
            "heavy_parts": p.heavy_parts,
            "host_to_part": p.host_to_part,
            "seed": p.seed,
            "sketch_keys": self.sketch._keys,
            "sketch_counts": self.sketch._counts,
            "sketch_floor": np.float64(self.sketch._floor),
            "sketch_total": np.float64(self.sketch.total),
            "batches_seen": np.int64(self.batches_seen),
            "last_repartition": np.int64(self.last_repartition),
            "grow_streak": np.int64(self.grow_streak),
            "shrink_streak": np.int64(self.shrink_streak),
        }

    @classmethod
    def restore(cls, snap: dict, config: DRConfig = DRConfig()) -> "DRMaster":
        p = Partitioner(
            int(snap["num_partitions"]),
            np.asarray(snap["heavy_keys"]),
            np.asarray(snap["heavy_parts"]),
            np.asarray(snap["host_to_part"]),
            int(snap["seed"]),
        )
        drm = cls(p, config)
        drm.sketch._keys = np.asarray(snap["sketch_keys"])
        drm.sketch._counts = np.asarray(snap["sketch_counts"])
        drm.sketch._floor = float(snap["sketch_floor"])
        drm.sketch.total = float(snap["sketch_total"])
        drm.batches_seen = int(snap["batches_seen"])
        if "last_repartition" in snap:  # older snapshots predate this field
            drm.last_repartition = int(snap["last_repartition"])
        # elastic-policy streaks (older snapshots predate these fields)
        drm.grow_streak = int(snap.get("grow_streak", 0))
        drm.shrink_streak = int(snap.get("shrink_streak", 0))
        return drm
