"""System signals: what the control plane actually observes.

The paper's repartitioning decisions are "system-aware": they key on
measured load, not static assumptions.  :class:`Signals` is the one record
every consumer hands the policy stack at a safe point — per-partition
loads, per-worker throughput against a capacity target, overflow counts,
actual exchange-lane accounting (rows the active backend *shipped* vs. the
rows the spec *provisioned*, wall time, and the per-lane overflow vector
that localizes a hot lane), and serving queue depths.  :class:`Telemetry`
is the accumulator the runtimes feed during normal work (no extra
measurement passes — the DRW principle); a ``snapshot`` at a safe point
turns the window into a ``Signals`` record and opens the next window.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.compat import host_fetch, safe_point
from repro.core.migration import fold_to_workers
from repro.exchange.spec import ExchangeStats

__all__ = ["Signals", "Telemetry"]


@dataclasses.dataclass(frozen=True)
class Signals:
    """One safe point's view of the system, as the policies consume it.

    ``loads`` is the only required field: per-partition work observed over
    the window (record counts for the streaming job, queued tokens for the
    serving scheduler, routed-token shares for MoE shards).  Everything else
    defaults to "unknown" so host-side unit tests and the compat wrappers
    can build a minimal record.
    """

    loads: np.ndarray                      # float64[N] per-partition work
    num_workers: int = 1                   # physical workers under the N partitions
    records: float = 0.0                   # records processed this window
    window_wall_s: float = 0.0             # wall time the window spanned
    shuffle_overflow: int = 0              # shuffle rows dropped for capacity
    migration_overflow: int = 0            # migration rows dropped for capacity
    exchange_rows: int = 0                 # rows the backend shipped through lanes
    exchange_padded_rows: int = 0          # rows the specs provisioned (L * capacity)
    exchange_occupied_rows: int | None = None  # rows actually live in the
                                           # buffers — backend-independent
                                           # occupancy (what a ragged transport
                                           # would ship); None when the window
                                           # recorded no exchange (0 is a real
                                           # measurement: all-empty lanes)
    exchange_wall_s: float = 0.0           # wall time inside the exchange path
    exchange_count_wall_s: float = 0.0     # wall blocking on the start phase
                                           # (route + bucketize + count a2a)
    exchange_ship_wall_s: float = 0.0      # wall blocking on the finish phase
                                           # (row ship) — only drains block, so
                                           # an overlapped window shows the
                                           # *un-hidden* remainder
    exchange_hidden_wall_s: float = 0.0    # host decision-section wall that ran
                                           # while a finish was in flight (the
                                           # latency the overlap hid)
    backend_wall_ewma: dict | None = None  # backend name -> EWMA of exchange
                                           # wall per call; long-lived (not
                                           # window-reset) — the BackendPolicy's
                                           # measured-wall evidence
    lane_overflow: np.ndarray | None = None  # int64[L] capacity drops per lane
    exchange_replica_rows: np.ndarray | None = None  # int64[N] rows landed per
                                           # partition from *split* hot keys
                                           # this window (None: nothing split)
    exchange_rows_by_class: np.ndarray | None = None  # int64[C] shipped rows by
                                           # lane distance class (self /
                                           # intra-host / inter-host); None
                                           # when no exchange carried a
                                           # topology this window
    queue_depths: np.ndarray | None = None # serving replica queue depths
    lane_straggle_s: np.ndarray | None = None  # float64[L] injected/observed
                                           # per-lane straggle seconds this
                                           # window (None: no fault evidence)
    lane_retries: np.ndarray | None = None # int64[L] exchange retries per lane
                                           # this window (transient failures)
    degenerate_walls: int = 0              # NaN/negative wall samples clamped
                                           # this window (a faulted batch's
                                           # clock can run backwards)
    state_rows: int = 0                    # live keyed-state rows (migration scale)
    at_safe_point: bool = True             # decisions may act only when True
    consumer: str = ""                     # which runtime emitted this

    @property
    def imbalance(self) -> float:
        """max/mean per-partition load (1.0 when nothing was observed)."""
        loads = np.asarray(self.loads, np.float64)
        if loads.size == 0 or not loads.sum():
            return 1.0
        return float(loads.max() / max(loads.mean(), 1e-12))

    @property
    def worker_loads(self) -> np.ndarray:
        """Loads folded to worker granularity (partition p on worker p % W)."""
        return fold_to_workers(self.loads, self.num_workers)

    @property
    def worker_imbalance(self) -> float:
        w = self.worker_loads
        if w.size == 0 or not w.sum():
            return 1.0
        return float(w.max() / max(w.mean(), 1e-12))

    @property
    def throughput(self) -> float:
        """Records/s over the window; 0.0 when the window is unmeasured."""
        if self.records <= 0 or self.window_wall_s <= 0:
            return 0.0
        return self.records / self.window_wall_s

    @property
    def per_worker_throughput(self) -> float:
        """Records/s each worker sustained — compared against the capacity
        target (``DRConfig.target_throughput``) to catch idle-but-balanced
        streams the imbalance trigger can never see (ROADMAP: policy signals
        beyond imbalance)."""
        return self.throughput / max(self.num_workers, 1)

    @property
    def exchange_padding_fraction(self) -> float:
        """Occupied / provisioned rows over the window — how full the padded
        lanes actually ran, whatever transport moved them (0.0 when the
        window saw no exchange).  This is the :class:`~repro.control.policy
        .BackendPolicy`'s signal: a dense job whose fraction stays low is
        paying for padding a ragged transport would not ship; a ragged job
        whose fraction nears 1.0 is paying the count phase for nothing.
        Falls back to shipped rows when the consumer recorded no occupancy
        (for a dense job the two then coincide at 1.0); an *explicit*
        occupancy of zero is a real measurement — all-empty lanes — not a
        missing one."""
        if self.exchange_padded_rows <= 0:
            return 0.0
        rows = (self.exchange_rows if self.exchange_occupied_rows is None
                else self.exchange_occupied_rows)
        return rows / self.exchange_padded_rows

    @property
    def inter_host_fraction(self) -> float:
        """Share of the window's shipped rows that crossed a host boundary
        (the slow tier) — the topology layer's headline signal.  0.0 when no
        exchange carried a topology (the flat world: nothing is known to
        cross hosts)."""
        by = self.exchange_rows_by_class
        if by is None:
            return 0.0
        total = float(np.sum(by))
        if total <= 0.0:
            return 0.0
        return float(by[-1]) / total

    @property
    def overlap_fraction(self) -> float:
        """Share of the exchange's ship wall the split-phase pipeline hid
        behind host work this window: ``hidden / (hidden + ship)``.  0.0 for
        a serial window (nothing hidden) and when no phase walls were
        recorded at all — the serial path records only the fused
        ``exchange_wall_s``, so existing consumers are untouched."""
        total = self.exchange_hidden_wall_s + self.exchange_ship_wall_s
        if total <= 0.0:
            return 0.0
        return self.exchange_hidden_wall_s / total

    @property
    def hot_lane(self) -> int:
        """Lane with the most capacity drops this window, or -1 when nothing
        overflowed — the localized view the scalar overflow can't give."""
        if self.lane_overflow is None or not np.any(self.lane_overflow):
            return -1
        return int(np.argmax(self.lane_overflow))


class Telemetry:
    """Windowed accumulator turning runtime counters into ``Signals``.

    The runtimes call the ``record_*`` hooks during normal work (shuffle,
    migration, request routing, router statistics); ``snapshot`` emits the
    window's :class:`Signals` at a safe point and — when the safe point
    consumes the window — resets for the next one.  Peeking at a non-safe
    point leaves the window accumulating, so a decision gated on checkpoint
    ticks sees everything since the previous tick.
    """

    def __init__(self, consumer: str = ""):
        self.consumer = consumer
        # backend -> EWMA of exchange wall per call; survives window resets
        # (evidence accumulated over the job's life, not one window)
        self.wall_ewma: dict[str, float] = {}
        # lifetime count of degenerate (NaN / negative) wall samples clamped
        # to zero; the per-window count rides Signals.degenerate_walls
        self.degenerate_walls_total = 0
        self._reset()

    def _reset(self) -> None:
        self._records = 0.0
        self._shuffle_overflow = 0
        self._migration_overflow = 0
        self._exchange_rows = 0
        self._exchange_padded_rows = 0
        self._exchange_occupied_rows: int | None = None
        self._exchange_wall_s = 0.0
        self._count_wall_s = 0.0
        self._ship_wall_s = 0.0
        self._hidden_wall_s = 0.0
        self._lane_overflow: np.ndarray | None = None
        self._replica_rows: np.ndarray | None = None
        self._rows_by_class: np.ndarray | None = None
        self._queues: np.ndarray | None = None
        self._lane_straggle: np.ndarray | None = None
        self._lane_retries: np.ndarray | None = None
        self._degenerate_walls = 0
        # exchanges recorded this window whose count fields may still live
        # on device — folded (one host fetch each) at the next snapshot, so
        # recording never blocks the pipeline between safe points
        self._pending_stats: list[ExchangeStats] = []
        # the window clock starts at the first recording, not at reset:
        # setup/idle time between construction (or a checkpoint) and the
        # next batch must not read as a throughput collapse
        self._t0: float | None = None

    def _touch(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    # -- recording hooks (called during normal work) -----------------------
    def record_batch(self, records: float) -> None:
        self._touch()
        self._records += float(records)

    @staticmethod
    def _fold_vector(acc: np.ndarray | None, v) -> np.ndarray:
        """Accumulate a per-lane/per-partition vector across the window; a
        width change mid-window (elastic resize) folds both onto the wider
        vector so nothing is lost."""
        v = np.asarray(host_fetch(v), np.int64)
        if acc is None:
            return v.copy()
        if len(v) == len(acc):
            return acc + v
        w = max(len(v), len(acc))
        out = np.zeros(w, np.int64)
        out[: len(acc)] += acc
        out[: len(v)] += v
        return out

    def record_exchange(self, stats: ExchangeStats, *extra, **legacy) -> None:
        """Fold one exchange's :class:`ExchangeStats` into the window.

        ``stats`` is constructed *by the exchange plane* —
        ``ExchangeResult.stats()`` / ``PendingExchange.stats()`` for raw
        exchanges, ``repro.core.shuffle.shuffle_stats`` /
        ``migrate_stats`` for the mapped steps, ``MoEOut.exchange_stats()``
        for expert dispatch — so consumers never assemble measurement
        fields themselves and new fields (``replica_rows``,
        ``rows_by_class``) don't ripple through every call site.

        ``stats.backend`` (with a positive ``wall_s``) feeds the long-lived
        per-backend wall EWMA (``wall_ewma``) the BackendPolicy reads as
        measured evidence.

        Sync-free: the count fields (``rows`` / ``occupied_rows`` /
        ``lane_overflow`` / ...) may be *device* scalars and vectors —
        recording only queues the record; the host fetch happens at the
        next :meth:`snapshot` (the safe point), so the steady-state loop
        never blocks here.  The wall fields are host-measured floats and
        fold eagerly (the EWMA stays observable between snapshots).

        The historical keyword-pile form ``record_exchange(rows,
        wall_s=..., padded_rows=..., ...)`` was removed after its one
        deprecation release (the kwargs mapped 1:1 onto
        :class:`ExchangeStats` fields) — any extra argument is a
        :class:`TypeError` now.
        """
        if not isinstance(stats, ExchangeStats) or extra or legacy:
            raise TypeError(
                "record_exchange takes exactly one plane-constructed "
                "ExchangeStats (ExchangeResult.stats(), shuffle_stats(), "
                "migrate_stats()) — the loose-kwargs form was removed; put "
                "the measurements on the ExchangeStats record"
            )
        self._touch()
        # degenerate wall samples (NaN / negative clock deltas from a
        # faulted batch) clamp to zero and count the incident — they must
        # not poison the windowed sums or the per-backend EWMA the
        # BackendPolicy trusts as measured evidence
        wall = self._clean_wall(stats.wall_s)
        self._exchange_wall_s += wall
        if stats.count_wall_s is not None:
            self._count_wall_s += self._clean_wall(stats.count_wall_s)
        if stats.ship_wall_s is not None:
            self._ship_wall_s += self._clean_wall(stats.ship_wall_s)
        if stats.hidden_wall_s is not None:
            self._hidden_wall_s += self._clean_wall(stats.hidden_wall_s)
        if stats.backend is not None and wall > 0.0:
            prev = self.wall_ewma.get(stats.backend)
            self.wall_ewma[stats.backend] = (
                wall if prev is None else 0.7 * prev + 0.3 * wall
            )
        self._pending_stats.append(stats)

    def _clean_wall(self, wall) -> float:
        w = float(wall)
        if not np.isfinite(w) or w < 0.0:
            self._degenerate_walls += 1
            self.degenerate_walls_total += 1
            return 0.0
        return w

    def _flush_pending(self) -> None:
        """Fold the queued exchange records' count fields — the one place
        device telemetry becomes host ints, inside a sanctioned safe-point
        region."""
        if not self._pending_stats:
            return
        with safe_point():
            for stats in self._pending_stats:
                rows = int(host_fetch(stats.rows))
                self._exchange_rows += rows
                self._exchange_padded_rows += (
                    rows if stats.padded_rows is None
                    else int(host_fetch(stats.padded_rows))
                )
                add = (rows if stats.occupied_rows is None
                       else int(host_fetch(stats.occupied_rows)))
                self._exchange_occupied_rows = (
                    add if self._exchange_occupied_rows is None
                    else self._exchange_occupied_rows + add
                )
                if stats.lane_overflow is not None:
                    self._lane_overflow = self._fold_vector(
                        self._lane_overflow, stats.lane_overflow
                    )
                if stats.replica_rows is not None:
                    self._replica_rows = self._fold_vector(
                        self._replica_rows, stats.replica_rows
                    )
                if stats.rows_by_class is not None:
                    self._rows_by_class = self._fold_vector(
                        self._rows_by_class, stats.rows_by_class
                    )
        self._pending_stats.clear()

    def record_fault(self, lane: int, *, straggle_s: float = 0.0,
                     retries: int = 0) -> None:
        """Fold one lane's fault evidence for this window — injected or
        observed straggle seconds and exchange retry counts.  The driver
        drains its fault seam's report here; the lane-health layer reads
        the folded vectors off the ``Signals`` snapshot."""
        self._touch()
        lane = int(lane)
        width = lane + 1
        if self._lane_straggle is None or len(self._lane_straggle) < width:
            grown = np.zeros(width, np.float64)
            if self._lane_straggle is not None:
                grown[: len(self._lane_straggle)] = self._lane_straggle
            self._lane_straggle = grown
            grown_r = np.zeros(width, np.int64)
            if self._lane_retries is not None:
                grown_r[: len(self._lane_retries)] = self._lane_retries
            self._lane_retries = grown_r
        self._lane_straggle[lane] += max(float(straggle_s), 0.0)
        self._lane_retries[lane] += max(int(retries), 0)

    def record_overflow(self, shuffle: int = 0, migration: int = 0) -> None:
        self._touch()
        self._shuffle_overflow += int(shuffle)
        self._migration_overflow += int(migration)

    def record_queues(self, depths: np.ndarray) -> None:
        self._touch()
        self._queues = np.asarray(depths, np.float64)

    # -- safe point --------------------------------------------------------
    def snapshot(
        self,
        loads: np.ndarray,
        *,
        num_workers: int = 1,
        state_rows: int = 0,
        at_safe_point: bool = True,
    ) -> Signals:
        self._flush_pending()
        sig = Signals(
            loads=np.asarray(loads, np.float64),
            num_workers=int(num_workers),
            records=self._records,
            window_wall_s=(max(time.perf_counter() - self._t0, 0.0)
                           if self._t0 is not None else 0.0),
            shuffle_overflow=self._shuffle_overflow,
            migration_overflow=self._migration_overflow,
            exchange_rows=self._exchange_rows,
            exchange_padded_rows=self._exchange_padded_rows,
            exchange_occupied_rows=self._exchange_occupied_rows,
            exchange_wall_s=self._exchange_wall_s,
            exchange_count_wall_s=self._count_wall_s,
            exchange_ship_wall_s=self._ship_wall_s,
            exchange_hidden_wall_s=self._hidden_wall_s,
            backend_wall_ewma=dict(self.wall_ewma) if self.wall_ewma else None,
            lane_overflow=self._lane_overflow,
            exchange_replica_rows=self._replica_rows,
            exchange_rows_by_class=self._rows_by_class,
            queue_depths=self._queues,
            lane_straggle_s=self._lane_straggle,
            lane_retries=self._lane_retries,
            degenerate_walls=self._degenerate_walls,
            state_rows=int(state_rows),
            at_safe_point=at_safe_point,
            consumer=self.consumer,
        )
        if at_safe_point:
            self._reset()
        return sig
