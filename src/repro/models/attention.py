"""Attention: GQA with TP head padding, RoPE/M-RoPE, chunked flash, caches.

TP head layout
--------------
Sharding heads over a 16-way ``model`` axis requires head counts divisible
by 16, which none of {8, 28, 40, 56} are.  We use an *exact* padded layout
(see DESIGN.md §6):

* q heads are padded to ``Hq_p`` with dead heads (zero wq columns; their
  output hits zero wo rows, so the function value is unchanged),
* kv heads are *replicated at activation level* to ``Hkv_p = max(kv, tp)``
  via a static gather of the real kv projections (parameters stay real and
  tied, so gradients sum over replicas — exactly GQA semantics),
* a per-arch permutation groups each physical kv slot with the q heads of
  its real kv head, making attention fully local along the model axis.

Flash attention is q/kv-chunked with *static* block skipping for causal and
sliding-window masks (python-level chunk loops inside the scanned period
body), so the compiled FLOPs track the true masked workload.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import Array, Policy, apply_norm, init_norm, normal

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# head layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    hq: int          # real q heads
    hkv: int         # real kv heads
    hq_p: int        # physical q heads (multiple of tp)
    hkv_p: int       # physical kv heads (multiple of tp, or real if >= tp)
    q_map: tuple     # [hq_p] -> real q index or -1 (dead)
    kv_map: tuple    # [hkv_p] -> real kv index
    qps: int         # q heads per physical kv slot

    @property
    def dead_q(self) -> int:
        return sum(1 for i in self.q_map if i < 0)


def head_layout(hq: int, hkv: int, tp: int) -> HeadLayout:
    if hkv >= tp:
        assert hkv % tp == 0, f"kv heads {hkv} not a multiple of tp {tp}"
        hkv_p = hkv
    else:
        assert tp % hkv == 0, f"tp {tp} not a multiple of kv heads {hkv}"
        hkv_p = tp
    r = hkv_p // hkv                       # physical slots per real kv head
    qpr = hq // hkv                        # real q heads per real kv head
    qps = int(np.ceil(qpr / r))            # q heads per physical slot
    hq_p = hkv_p * qps
    q_map = [-1] * hq_p
    kv_map = [0] * hkv_p
    for j in range(hkv):
        for c in range(r):
            s = j * r + c                  # physical kv slot
            kv_map[s] = j
            for t in range(qps):
                rq = c * qps + t           # index within this kv head's q set
                if rq < qpr:
                    q_map[s * qps + t] = j * qpr + rq
    return HeadLayout(hq, hkv, hq_p, hkv_p, tuple(q_map), tuple(kv_map), qps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def _rope_freqs(hd_rot: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd_rot, 2, dtype=np.float64) / hd_rot))


def apply_rope(x: Array, pos: Array, *, theta: float, pct: float = 1.0,
               mrope_sections: tuple | None = None) -> Array:
    """x [B, S, H, hd]; pos int32 [B, S] (or [3, B, S] for M-RoPE).

    Angles (position x frequency) are always f32; the rotation itself runs
    in the activation dtype so backward cotangents (and their cross-shard
    psums) stay bf16 — §Perf iteration C3 measured f32 rope upcasts forcing
    f32 activation all-reduces through the whole residual backward."""
    hd = x.shape[-1]
    hd_rot = int(hd * pct) // 2 * 2
    freqs = jnp.asarray(_rope_freqs(hd_rot, theta), jnp.float32)  # [hd_rot/2]
    if mrope_sections is None:
        angles = pos.astype(jnp.float32)[..., None] * freqs  # [B, S, hd_rot/2]
    else:
        # M-RoPE: split the frequency dim into (t, h, w) sections, each
        # rotated by its own position stream (pos [3, B, S]).
        secs = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            secs.append(pos[i].astype(jnp.float32)[..., None] * freqs[off : off + sec])
            off += sec
        angles = jnp.concatenate(secs, axis=-1)
    dt = x.dtype
    sin = jnp.sin(angles).astype(dt)[:, :, None, :]
    cos = jnp.cos(angles).astype(dt)[:, :, None, :]
    x1, x2 = x[..., : hd_rot // 2], x[..., hd_rot // 2 :hd_rot]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot, x[..., hd_rot:]], axis=-1)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_attention(key, d: int, lay: HeadLayout, hd: int, *, qk_norm: bool, norm_kind: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    wq = normal(ks[0], (d, lay.hq_p, hd), d**-0.5, dtype)
    dead = jnp.asarray(np.array(lay.q_map) < 0)
    wq = jnp.where(dead[None, :, None], 0.0, wq)
    p = {
        "wq": wq,
        "wk": normal(ks[1], (d, lay.hkv, hd), d**-0.5, dtype),
        "wv": normal(ks[2], (d, lay.hkv, hd), d**-0.5, dtype),
        "wo": normal(ks[3], (lay.hq_p, hd, d), (lay.hq_p * hd) ** -0.5, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_norm(norm_kind, hd, dtype)
        p["k_norm"] = init_norm(norm_kind, hd, dtype)
    return p


# ---------------------------------------------------------------------------
# chunked flash attention with static block skipping
# ---------------------------------------------------------------------------


def _block_visible(causal: bool, window: int, q0: int, q1: int, k0: int, k1: int) -> bool:
    """May any (q, k) pair in this block attend?  (static, python ints)"""
    if causal and k0 > q1 - 1:
        return False
    if window > 0 and k1 - 1 < q0 - window + 1:
        return False
    return True


def flash_attention(
    q: Array,   # [B, Sq, Hkv_p, qps, hd]
    k: Array,   # [B, Sk, Hkv_p, hd]
    v: Array,   # [B, Sk, Hkv_p, hd]
    *,
    causal: bool,
    window: int = 0,          # 0 = unbounded
    q_offset: int = 0,        # absolute position of q[0] (prefill chunks)
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    block_skip: bool = True,
    p_bf16: bool = False,     # §Perf: bf16 softmax weights for the PV dot
) -> Array:
    b, sq, g, qps, hd = q.shape
    sk = k.shape[1]
    scale = hd**-0.5
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    nq, nk = -(-sq // qc), -(-sk // kc)
    outs = []
    for iq in range(nq):
        q0, q1 = iq * qc, min((iq + 1) * qc, sq)
        qb = q[:, q0:q1].astype(jnp.float32) * scale
        acc = jnp.zeros((b, q1 - q0, g, qps, hd), jnp.float32)
        m = jnp.full((b, q1 - q0, g, qps), NEG_INF, jnp.float32)
        l = jnp.zeros((b, q1 - q0, g, qps), jnp.float32)
        for ik in range(nk):
            k0, k1 = ik * kc, min((ik + 1) * kc, sk)
            if block_skip and not _block_visible(causal, window, q0 + q_offset, q1 + q_offset, k0, k1):
                continue
            kb = k[:, k0:k1].astype(jnp.float32)
            vb = v[:, k0:k1].astype(jnp.float32)
            s = jnp.einsum("bqgph,bkgh->bqgpk", qb, kb)
            qpos = (q_offset + q0 + jnp.arange(q1 - q0))[:, None]
            kpos = (k0 + jnp.arange(k1 - k0))[None, :]
            ok = jnp.ones((q1 - q0, k1 - k0), bool)
            if causal:
                ok &= kpos <= qpos
            if window > 0:
                ok &= kpos > qpos - window
            s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            if p_bf16:
                # p materializes in bf16 (stabilized exponents are <= 0 so
                # values sit in [0, 1]); the row-sum accumulates in f32
                p = jnp.exp((s - m_new[..., None]).astype(jnp.bfloat16))
                l = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
                pv = jnp.einsum("bqgpk,bkgh->bqgph", p,
                                vb.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
            else:
                p = jnp.exp(s - m_new[..., None])
                l = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bqgpk,bkgh->bqgph", p, vb)
            acc = acc * corr[..., None] + pv
            m = m_new
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(
    q: Array,        # [B, 1, Hkv_p, qps, hd]
    k_cache: Array,  # [B, L, Hkv_p, hd]
    v_cache: Array,
    kv_pos: Array,   # int32 [B, L] absolute position held in each cache slot (-1 empty)
    pos: Array,      # int32 [B] current decode position
    *,
    window: int = 0,
) -> Array:
    scale = q.shape[-1] ** -0.5
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bqgph,bkgh->bqgpk", qf, k_cache.astype(jnp.float32))
    ok = (kv_pos >= 0) & (kv_pos[:, :] <= pos[:, None])
    if window > 0:
        ok &= kv_pos > (pos[:, None] - window)
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqgpk,bkgh->bqgph", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# the attention block (train / prefill / decode)
# ---------------------------------------------------------------------------


def attention_block(
    p: dict,
    x: Array,                 # [B, S, d]
    lay: HeadLayout,
    pol: Policy,
    *,
    pos: Array,               # [B, S] (or [3, B, S] for mrope)
    causal: bool = True,
    window: int = 0,
    theta: float = 10_000.0,
    rope_pct: float = 1.0,
    rope_kind: str = "rope",
    mrope_sections: tuple | None = None,
    norm_kind: str = "rmsnorm",
    cache: dict | None = None,   # {"k", "v", "pos", "offset"} for decode/prefill
    xkv: Array | None = None,    # cross-attention source (whisper)
    static_cache: bool = False,  # cache holds fixed K/V (cross-attn): never write
) -> tuple[Array, dict | None]:
    b, s, d = x.shape
    hd = p["wq"].shape[-1]
    cd = pol.compute_dtype

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    if "q_norm" in p:
        q = apply_norm(p["q_norm"], q, norm_kind)
    if rope_kind in ("rope", "mrope") and xkv is None and not static_cache:
        q = apply_rope(q, pos, theta=theta, pct=rope_pct,
                       mrope_sections=mrope_sections if rope_kind == "mrope" else None)
    q = pol.shard(q, "act_q")
    pos1 = pos if pos.ndim <= 2 else pos[0]  # [B, S] scalar positions
    qg = q.reshape(b, s, lay.hkv_p, lay.qps, hd)

    if static_cache:
        # fixed cross-attention K/V (precomputed from the encoder)
        if s > 1:
            out = flash_attention(
                qg, cache["k"], cache["v"], causal=False,
                q_chunk=pol.attn_q_chunk, kv_chunk=pol.attn_kv_chunk,
                block_skip=pol.attn_block_skip, p_bf16=pol.attn_p_bf16,
            )
        else:
            # every (valid) cross position is visible regardless of dec pos
            out = decode_attention(
                qg, cache["k"], cache["v"], cache["pos"],
                jnp.full((b,), 2**30, jnp.int32), window=0,
            )
        out = out.reshape(b, s, lay.hq_p, hd)
        out = pol.shard(out, "act_q")
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
        return y, cache

    src = x if xkv is None else xkv
    k = jnp.einsum("bsd,djk->bsjk", src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,djk->bsjk", src, p["wv"].astype(cd))

    if "q_norm" in p:
        k = apply_norm(p["k_norm"], k, norm_kind)

    if rope_kind in ("rope", "mrope") and xkv is None:
        k = apply_rope(k, pos, theta=theta, pct=rope_pct,
                       mrope_sections=mrope_sections if rope_kind == "mrope" else None)

    # replicate kv to the physical layout (static gather; params stay real)
    kv_map = jnp.asarray(lay.kv_map, jnp.int32)
    k = jnp.take(k, kv_map, axis=2)
    v = jnp.take(v, kv_map, axis=2)
    k = pol.shard(k, "act_kv")
    v = pol.shard(v, "act_kv")

    new_cache = None
    if cache is None:
        out = flash_attention(
            qg, k, v, causal=causal, window=window,
            q_chunk=pol.attn_q_chunk, kv_chunk=pol.attn_kv_chunk,
            block_skip=pol.attn_block_skip, p_bf16=pol.attn_p_bf16,
        )
    elif s > 1:
        # prefill: run flash over the fresh sequence, then store it
        out = flash_attention(
            qg, k, v, causal=causal, window=window,
            q_chunk=pol.attn_q_chunk, kv_chunk=pol.attn_kv_chunk,
            block_skip=pol.attn_block_skip, p_bf16=pol.attn_p_bf16,
        )
        new_cache = _cache_store_prefill(cache, k, v, window)
    else:
        # single-token decode against the cache
        new_cache = _cache_append(cache, k, v, window)
        out = decode_attention(
            qg, new_cache["k"], new_cache["v"], new_cache["pos"],
            pos1[:, 0], window=window,
        )

    out = out.reshape(b, s, lay.hq_p, hd)
    out = pol.shard(out, "act_q")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, new_cache


# ---------------------------------------------------------------------------
# KV caches: full-length and ring-buffer (sliding window)
# ---------------------------------------------------------------------------


def init_kv_cache(b: int, max_len: int, lay: HeadLayout, hd: int, *, window: int = 0, dtype=jnp.bfloat16) -> dict:
    length = min(window, max_len) if window > 0 else max_len
    return {
        "k": jnp.zeros((b, length, lay.hkv_p, hd), dtype),
        "v": jnp.zeros((b, length, lay.hkv_p, hd), dtype),
        "pos": jnp.full((b, length), -1, jnp.int32),
        "offset": jnp.zeros((), jnp.int32),
    }


def _cache_store_prefill(cache: dict, k: Array, v: Array, window: int) -> dict:
    b, s = k.shape[:2]
    length = cache["k"].shape[1]
    if window > 0 and s > length:
        # only the trailing window survives in a ring cache
        k, v = k[:, -length:], v[:, -length:]
        posv = jnp.arange(s - length, s, dtype=jnp.int32)
        # ring layout: slot = pos % window
        slots = posv % length
        order = jnp.argsort(slots)
        k, v, posv = k[:, order], v[:, order], posv[order]
        pos = jnp.broadcast_to(posv[None], (b, length))
        new = {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype),
               "pos": pos, "offset": jnp.asarray(s, jnp.int32)}
    else:
        kpad = jnp.zeros_like(cache["k"]).at[:, :s].set(k.astype(cache["k"].dtype))
        vpad = jnp.zeros_like(cache["v"]).at[:, :s].set(v.astype(cache["v"].dtype))
        pos = jnp.full_like(cache["pos"], -1).at[:, :s].set(jnp.arange(s, dtype=jnp.int32)[None])
        new = {"k": kpad, "v": vpad, "pos": pos, "offset": jnp.asarray(s, jnp.int32)}
    return new


def _cache_append(cache: dict, k: Array, v: Array, window: int) -> dict:
    """Insert one decoded token (k/v [B, 1, H, hd]) at offset."""
    off = cache["offset"]
    length = cache["k"].shape[1]
    slot = off % length if window > 0 else jnp.minimum(off, length - 1)
    kc = cache["k"].at[:, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[:, slot].set(v[:, 0].astype(cache["v"].dtype))
    pos = cache["pos"].at[:, slot].set(off)
    return {"k": kc, "v": vc, "pos": pos, "offset": off + 1}
