import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower the three selected cells with tagged
optimization variants and record the roofline deltas.

    PYTHONPATH=src python -m repro.launch.hillclimb
"""
import dataclasses
import json

import jax.numpy as jnp

from repro.launch.dryrun import run_cell
from repro.launch.sharding import ShardingOptions, default_options
from repro.configs.registry import get_config

# (arch, shape, tag, options-override builder)
VARIANTS = [
    # A. xlstm-125m x train_4k — worst roofline fraction (memory-bound)
    ("xlstm-125m", "train_4k", "_hc_puredp",
     lambda o: dataclasses.replace(o, pure_dp=True)),
    ("xlstm-125m", "train_4k", "_hc_puredp_bf16",
     lambda o: dataclasses.replace(o, pure_dp=True, recurrent_bf16=True)),
    ("xlstm-125m", "train_4k", "_hc_puredp_bf16_unroll",
     lambda o: dataclasses.replace(o, pure_dp=True, recurrent_bf16=True,
                                   slstm_unroll=32)),
    # B. stablelm-1.6b x train_4k — most collective-bound (TP/SP mismatch)
    ("stablelm-1.6b", "train_4k", "_hc_puredp",
     lambda o: dataclasses.replace(o, pure_dp=True)),
    ("stablelm-1.6b", "train_4k", "_hc_puredp_pbf16",
     lambda o: dataclasses.replace(o, pure_dp=True, attn_p_bf16=True)),
    # C. llama4-maverick x train_4k — the paper-representative MoE cell
    ("llama4-maverick-400b-a17b", "train_4k", "_hc_savemoe",
     lambda o: dataclasses.replace(o, remat_policy="save_moe")),
    ("llama4-maverick-400b-a17b", "train_4k", "_hc_savemoe_cf1",
     lambda o: dataclasses.replace(o, remat_policy="save_moe", moe_cf=1.0)),
    ("llama4-maverick-400b-a17b", "train_4k", "_hc_savemoe_cf1_pbf16",
     lambda o: dataclasses.replace(o, remat_policy="save_moe", moe_cf=1.0,
                                   attn_p_bf16=True)),
]


def main() -> None:
    for arch, shape, tag, patch in VARIANTS:
        opts = patch(default_options(get_config(arch)))
        rec = run_cell(arch, shape, multi_pod=False, opts=opts, tag=tag, save_hlo=True)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"[ok] {arch} x {shape} {tag}: c={r['compute_s']:.3f}s "
                  f"m={r['memory_s']:.3f}s x={r['collective_s']:.3f}s "
                  f"-> {r['bottleneck']} frac={r['roofline_fraction']:.3f} "
                  f"useful={rec['useful_ratio']:.2f}", flush=True)
        else:
            print(f"[error] {arch} {tag} :: {rec.get('error')}", flush=True)


if __name__ == "__main__":
    main()
