"""Fig. 2 — load imbalance vs. #partitions for each partitioning method,
and KIP with lambda in {1, 2, 3, 4}.  ZIPF exponent 1, averaged runs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timer
from repro.core import Histogram, kip_update, load_imbalance, make_baseline, uniform_partitioner
from repro.data.generators import zipf_keys

METHODS = ["hash", "readj", "redist", "scan", "mixed", "kip", "kip_tight"]
PARALLELISM = [4, 8, 16, 32, 64]


def _build(method: str, hist: Histogram, n: int, lam: float = 2.0):
    if method == "kip":
        return kip_update(uniform_partitioner(n), hist.top(int(lam * n)))
    if method == "kip_tight":  # beyond-paper waterfilled host re-binning
        return kip_update(uniform_partitioner(n), hist.top(int(lam * n)), tight=True)
    update, prev = make_baseline(method, n)
    return update(prev, hist.top(int(lam * n)), n)


SMOKE = dict(reps=1, n_records=20_000, num_keys=5_000)  # CI bench-smoke profile


def run(reps: int = 5, n_records: int = 200_000, num_keys: int = 100_000):
    rows = []
    for n in PARALLELISM:
        imb: dict[str, list] = {m: [] for m in METHODS}
        for rep in range(reps):
            stream = zipf_keys(n_records, num_keys=num_keys, exponent=1.0, seed=rep)
            hist = Histogram.exact(stream)
            for m in METHODS:
                part = _build(m, hist, n)
                imb[m].append(load_imbalance(part, stream))
        floor = max(1.0, n * Histogram.exact(
            zipf_keys(n_records, num_keys=num_keys, exponent=1.0, seed=0)).freqs[0])
        for m in METHODS:
            rows.append((f"fig2/imbalance/{m}/N={n}", float(np.mean(imb[m])),
                         f"floor={floor:.2f}"))
        # paper's headline ordering: KIP best (paper evaluates N in this
        # range; at N=64 the floor N*f1=5.3 dominates every method and
        # kip_tight is the one that stays nearest it)
        if n <= 32:
            others = min(np.mean(imb[m]) for m in METHODS if not m.startswith("kip"))
            assert np.mean(imb["kip"]) <= others + 0.05
        assert np.mean(imb["kip_tight"]) <= np.mean(imb["kip"]) + 0.02
    # lambda sweep (Fig 2 right)
    for lam in [1.0, 2.0, 3.0, 4.0]:
        vals = []
        for rep in range(reps):
            stream = zipf_keys(n_records, num_keys=num_keys, exponent=1.0, seed=10 + rep)
            part = _build("kip", Histogram.exact(stream), 32, lam)
            vals.append(load_imbalance(part, stream))
        rows.append((f"fig2/kip_lambda/{lam}", float(np.mean(vals)), "N=32"))
    # KIP update cost (paper: cheaper than alternatives)
    stream = zipf_keys(n_records, num_keys=num_keys, exponent=1.0, seed=0)
    hist = Histogram.exact(stream).top(64)
    for m in ["kip", "readj", "redist", "scan", "mixed"]:
        us = timer(lambda m=m: _build(m, hist, 32))
        rows.append((f"fig2/update_cost/{m}", us, "us/update"))
    return rows
