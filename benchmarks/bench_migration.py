"""Fig. 3 — imbalance + relative state migration over a drifting 20-batch
stream (LFM-like), 20 partitions, partitioner update forced per batch.

Also accounts each swap's migration all-to-all under both exchange
backends: the dense transport ships ``W * capacity`` rows per worker
(every lane padded to the planned peak), the ragged count-first transport
ships the rows that actually cross workers (plus one count per lane).
The ragged rows must never exceed the dense provision, and must be
strictly fewer on these power-law profiles — checked here, so a backend
accounting regression fails the bench.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    Histogram,
    kip_update,
    load_imbalance,
    make_baseline,
    plan_migration,
    uniform_partitioner,
)
from repro.core.migration import fold_to_workers, migration_capacity
from repro.data.generators import drifting_zipf

N = 20
BATCHES = 20
BATCH = 100_000
WORKERS = 4  # exchange-plane lane granularity (partition -> worker = p % W)


SMOKE = dict(reps=1)  # CI bench-smoke profile


def _backend_rows(plan) -> tuple[int, int]:
    """(dense padded, ragged shipped) rows for one swap's migration exchange.

    Dense: every worker ships ``W`` lanes of ``migration_capacity`` rows
    each — the static provision.  Ragged: the rows that actually cross
    workers (same-worker moves never ship) plus the count phase priced in
    bytes-normalized row units — these modeled rows are bare 4-byte keys,
    so one 4-byte count per lane is exactly one row-equivalent (the rule
    ``RaggedBackend`` applies on device).
    """
    cap = migration_capacity(plan, num_workers=WORKERS)
    dense = WORKERS * WORKERS * cap  # all workers x all lanes x padded rows
    folded = fold_to_workers(plan.transfer, WORKERS)
    np.fill_diagonal(folded, 0.0)
    ragged = int(np.ceil(folded.sum())) + WORKERS * WORKERS
    return dense, ragged


def run(reps: int = 3):
    rows = []
    results: dict[str, tuple] = {}
    for method in ["hash", "scan", "readj", "kip"]:
        imb_all, mig_all, lane_all = [], [], []
        dense_all, ragged_all = [], []
        for rep in range(reps):
            if method == "kip":
                part = uniform_partitioner(N)
                update = lambda prev, hist, n=N: kip_update(prev, hist.top(2 * N))
            else:
                update, part = make_baseline(method, N)
            imb, mig, lanes = [], [], []
            dense_rows, ragged_rows = [], []
            window: list[np.ndarray] = []  # sliding state window of 5 batches
            for batch in drifting_zipf(BATCHES, BATCH, num_keys=10_000, exponent=1.0,
                                       drift_every=4, drift_fraction=0.3, seed=rep):
                hist = Histogram.exact(batch)
                new = update(part, hist.top(2 * N), N)
                window = (window + [batch])[-5:]
                # states linear in the keygroup size over the window
                live, counts = np.unique(np.concatenate(window), return_counts=True)
                plan = plan_migration(part, new, live, counts.astype(np.float64))
                mig.append(plan.relative_migration)
                # exchange-plane lane rows this swap would ship (vs. the
                # full-state all-to-all of W * len(live) rows)
                lanes.append(migration_capacity(plan, num_workers=WORKERS)
                             / max(len(live), 1))
                d, r = _backend_rows(plan)
                dense_rows.append(d)
                ragged_rows.append(r)
                part = new
                imb.append(load_imbalance(part, batch))
            imb_all.append(np.mean(imb[1:]))
            mig_all.append(np.mean(mig[1:]))
            lane_all.append(np.mean(lanes[1:]))
            dense_all.append(np.mean(dense_rows[1:]))
            ragged_all.append(np.mean(ragged_rows[1:]))
        results[method] = (float(np.mean(imb_all)), float(np.mean(mig_all)))
        rows.append((f"fig3/imbalance/{method}", results[method][0], "mean over stream"))
        if method != "hash":
            rows.append((f"fig3/migration/{method}", results[method][1], "fraction/update"))
            rows.append((f"fig3/exchange_lane_fraction/{method}",
                         float(np.mean(lane_all)),
                         "a2a lane rows / live state rows (full-state a2a = 1)"))
            dense_mean, ragged_mean = float(np.mean(dense_all)), float(np.mean(ragged_all))
            rows.append((f"fig3/exchange_rows/{method}", dense_mean,
                         "padded migration a2a rows per swap", "dense"))
            rows.append((f"fig3/exchange_rows/{method}", ragged_mean,
                         "shipped migration a2a rows per swap", "ragged"))
            # the count-first transport must track real rows: strictly below
            # the padded provision on these power-law drifting-zipf profiles
            assert ragged_mean < dense_mean, (method, ragged_mean, dense_mean)
    # paper's claims: KIP imbalance beats hash/scan/readj; KIP migrates far
    # less than readj-style rebuilds
    imp_hash = 1 - results["kip"][0] / results["hash"][0]
    imp_scan = 1 - results["kip"][0] / results["scan"][0]
    imp_readj = 1 - results["kip"][0] / results["readj"][0]
    rows.append(("fig3/kip_improvement_vs_hash", imp_hash, "paper: 41%"))
    rows.append(("fig3/kip_improvement_vs_scan", imp_scan, "paper: 29%"))
    rows.append(("fig3/kip_improvement_vs_readj", imp_readj, "paper: 26%"))
    rows.append(("fig3/migration_ratio_readj_over_kip",
                 results["readj"][1] / max(results["kip"][1], 1e-9), "paper: ~4x"))
    return rows
