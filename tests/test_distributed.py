"""Multi-device shuffle/migration correctness on 8 XLA host devices.

Runs in a subprocess because device count must be fixed before jax init
(the main test process keeps the default 1 CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    assert len(jax.devices()) == 8

    from repro.core import Histogram, kip_update, uniform_partitioner
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    job = StreamingJob(
        mesh=mesh, num_partitions=8, state_capacity=4096,
        dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1),
    )
    batches = list(drifting_zipf(5, 8192, num_keys=2000, exponent=1.3,
                                 drift_every=100, seed=0))
    ms = job.run(batches)

    # 1. exact stateful aggregation across a real 8-way all_to_all
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:10]:
        got = job.state_count(int(key))
        want = float((all_keys == key).sum())
        assert got == want, (key, got, want)

    # 2. DR fired and improved balance on the skewed stream
    assert any(m.repartitioned for m in ms), [m.reason for m in ms]
    assert ms[-1].imbalance < ms[0].imbalance

    # 3. each worker shard holds only keys the partitioner maps to it
    sk = np.asarray(job.state_keys)
    part = job.drm.partitioner
    for w in range(8):
        keys_w = sk[w][sk[w] != 2**31 - 1]
        if len(keys_w):
            assert np.all(part.lookup_np(keys_w.astype(np.int32)) % 8 == w)

    print("DISTRIBUTED-OK")
    """
)


@pytest.mark.slow
def test_shuffle_and_dr_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "DISTRIBUTED-OK" in out.stdout, out.stdout + "\n" + out.stderr


RESIZE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.hashing import KEY_SENTINEL
    from repro.core.streaming import StreamingJob
    from repro.data.generators import zipf_keys

    mesh = jax.make_mesh((4,), ("data",))
    job = StreamingJob(mesh=mesh, num_partitions=4, state_capacity=4096,
                       dr=DRConfig(imbalance_trigger=1e9))
    batches = [zipf_keys(8192, num_keys=1000, exponent=1.4, seed=s) for s in range(5)]
    job.process_batch(batches[0]); job.process_batch(batches[1])

    # grow 4->8 across a real 4-way all_to_all: state must physically move
    job.resize(8)
    m = job.process_batch(batches[2])
    assert m.resized and m.reason == "resize 4->8", m.reason
    assert m.overflow == 0, m.overflow
    assert m.relative_migration > 0  # cross-worker shipping actually happened
    assert m.migration_rows <= 4 * max(8, 2 * m.migration_plan_rows)

    job.resize(4)
    m = job.process_batch(batches[3])
    assert m.resized and m.reason == "resize 8->4", m.reason
    assert m.overflow == 0, m.overflow
    job.process_batch(batches[4])

    # exact per-key counts across both resizes
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:10]:
        got, want = job.state_count(int(key)), float((all_keys == key).sum())
        assert got == want, (key, got, want)

    # each worker shard holds only keys the resized partitioner maps to it
    sk = np.asarray(job.state_keys)
    part = job.drm.partitioner
    for w in range(4):
        keys_w = sk[w][sk[w] != KEY_SENTINEL]
        if len(keys_w):
            assert np.all(part.lookup_np(keys_w.astype(np.int32)) % 4 == w)

    print("RESIZE-DISTRIBUTED-OK")
    """
)


@pytest.mark.slow
def test_elastic_resize_on_4_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", RESIZE_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "RESIZE-DISTRIBUTED-OK" in out.stdout, out.stdout + "\n" + out.stderr


BACKEND_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf

    mesh = jax.make_mesh((8,), ("data",))
    batches = list(drifting_zipf(5, 8192, num_keys=2000, exponent=1.5,
                                 drift_every=2, drift_fraction=0.4, seed=3))
    jobs = {}
    for be in ("dense", "ragged"):
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=4096,
            dr=DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0),
            exchange_backend=be,
        )
        jobs[be] = (job, job.run(batches))

    # 1. backend equivalence across a real 8-way all_to_all: bit-identical
    #    keyed state (exact aggregation) and identical overflow accounting
    all_keys = np.concatenate(batches)
    for key in np.unique(all_keys)[:32]:
        got = {be: job.state_count(int(key)) for be, (job, _) in jobs.items()}
        want = float((all_keys == key).sum())
        assert got["dense"] == got["ragged"] == want, (key, got, want)
    ov = {be: [m.overflow for m in ms] for be, (_, ms) in jobs.items()}
    assert ov["dense"] == ov["ragged"], ov

    # 2. both backends repartitioned identically (same decisions, the
    #    transport must not change the control plane's view of the stream)
    acts = {be: [m.action for m in ms] for be, (_, ms) in jobs.items()}
    assert acts["dense"] == acts["ragged"], acts
    assert any(m.repartitioned for m in jobs["dense"][1])

    # 3. the ragged transport moved strictly fewer rows than the dense pad
    shipped = {be: sum(m.shipped_rows for m in ms) for be, (_, ms) in jobs.items()}
    padded = {be: sum(m.padded_rows for m in ms) for be, (_, ms) in jobs.items()}
    assert shipped["dense"] == padded["dense"], (shipped, padded)
    assert shipped["ragged"] < padded["ragged"], (shipped, padded)
    print("BACKEND-EQUIVALENCE-OK", shipped, padded)
    """
)


@pytest.mark.slow
def test_backend_equivalence_on_8_devices():
    """Dense vs ragged on 8 real shards: bit-identical state, fewer rows."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", BACKEND_SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=600,
    )
    assert "BACKEND-EQUIVALENCE-OK" in out.stdout, out.stdout + "\n" + out.stderr
