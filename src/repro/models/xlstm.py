"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential scan) — arXiv:2405.04517.

mLSTM is evaluated chunkwise (gated-linear-attention style): within a chunk
the gate-weighted q/k/v products are dense [chunk, chunk] matrices; across
chunks the matrix memory ``C``, normalizer ``n`` and stabilizer ``m`` are
carried recurrently.  Exponential gating uses the paper's max-stabilizer so
half-precision activations survive 500k-token contexts.

sLSTM has no parallel form (by design — its recurrent gate connections are
the point), so training runs a ``lax.scan`` over time with per-head
block-diagonal recurrence.

Head padding: heads are padded to the model-axis size with dead heads
(zero down-projection rows), same exactness argument as attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import Array, Policy, normal


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d: int, heads: int, heads_p: int, *, proj: int = 2, dtype=jnp.float32) -> dict:
    di = proj * d
    hd = di // heads
    ks = jax.random.split(key, 8)
    p = {
        "up": normal(ks[0], (d, 2, di), d**-0.5, dtype),          # x_m, z
        "conv_w": normal(ks[1], (4, di), 0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": normal(ks[2], (di, heads_p, hd), di**-0.5, dtype),
        "wk": normal(ks[3], (di, heads_p, hd), di**-0.5, dtype),
        "wv": normal(ks[4], (di, heads_p, hd), di**-0.5, dtype),
        "w_if": normal(ks[5], (di, 2, heads_p), di**-0.5, dtype),  # i, f pre-acts
        "b_if": jnp.stack([jnp.zeros((heads_p,)), 3.0 * jnp.ones((heads_p,))]).astype(dtype),
        "down": normal(ks[6], (heads_p, hd, d), di**-0.5, dtype),
    }
    if heads_p > heads:  # dead padded heads contribute exactly zero
        mask = (jnp.arange(heads_p) < heads)[:, None, None]
        p["down"] = p["down"] * mask
    return p


def _mlstm_qkvif(p: dict, x: Array, cd, conv_state=None):
    xz = jnp.einsum("bsd,dgi->bsgi", x, p["up"].astype(cd))
    xm, z = xz[:, :, 0], xz[:, :, 1]
    # causal depthwise conv feeding q/k (as in the paper's block)
    k4 = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k4 - 1, xm.shape[-1]), xm.dtype)
    else:
        pad = conv_state.astype(xm.dtype)
    xp = jnp.concatenate([pad, xm], axis=1)
    xc = sum(xp[:, i : i + xm.shape[1]] * p["conv_w"].astype(cd)[i][None, None] for i in range(k4))
    xc = jax.nn.silu(xc + p["conv_b"].astype(cd)[None, None])
    new_conv_state = xp[:, -(k4 - 1):]
    q = jnp.einsum("bsi,ihk->bshk", xc, p["wq"].astype(cd))
    k = jnp.einsum("bsi,ihk->bshk", xc, p["wk"].astype(cd))
    v = jnp.einsum("bsi,ihk->bshk", xm, p["wv"].astype(cd))
    ifg = jnp.einsum("bsi,igh->bsgh", xm, p["w_if"].astype(cd)) + p["b_if"].astype(cd)[None, None]
    logi = ifg[:, :, 0].astype(jnp.float32)                       # [B, S, H]
    logf = jax.nn.log_sigmoid(ifg[:, :, 1].astype(jnp.float32))   # [B, S, H]
    return q, k, v, logi, logf, z, new_conv_state


def mlstm_forward(p: dict, x: Array, pol: Policy, *, chunk: int = 256, state: dict | None = None):
    """Chunk-parallel mLSTM.  state = {"c": [B,H,dk,dv], "n": [B,H,dk], "m": [B,H]}."""
    b, s, d = x.shape
    cd = pol.compute_dtype
    q, k, v, logi, logf, z, conv_state = _mlstm_qkvif(
        p, x, cd, None if state is None else state["conv"])
    hp, hd = q.shape[2], q.shape[3]
    scale = hd**-0.5

    c = min(chunk, s)
    nchunk = -(-s // c)
    assert s % c == 0

    def chunks(t):
        return t.reshape(b, nchunk, c, *t.shape[2:]).swapaxes(0, 1)

    qs, ks_, vs, lis, lfs = map(chunks, (q, k, v, logi, logf))
    if state is None:
        c0 = jnp.zeros((b, hp, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, hp, hd), jnp.float32)
        m0 = jnp.full((b, hp), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def body(carry, inp):
        cm, nm, mm = carry
        qb, kb, vb, lib, lfb = inp  # [B,c,H,hd] x3, [B,c,H] x2
        f_cum = jnp.cumsum(lfb, axis=1)                     # F_t (within chunk)
        f_tot = f_cum[:, -1]                                # [B,H]
        # stabilizers
        a = lib - f_cum                                     # i_s - F_s
        m_intra = f_cum + jax.lax.cummax(a, axis=1)         # [B,c,H]
        m_inter = mm[:, None] + f_cum                       # old state path
        m_t = jnp.maximum(m_intra, m_inter)
        # intra-chunk: D[t,s] = exp(F_t - F_s + i_s - m_t), s <= t
        dmat = f_cum[:, :, None] - f_cum[:, None, :] + lib[:, None, :] - m_t[:, :, None]
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -1e30)
        w = jnp.exp(dmat)                                   # [B,t,s,H]
        sqk = jnp.einsum("bthk,bshk->btsh", qb.astype(jnp.float32), kb.astype(jnp.float32)) * scale
        pw = w * sqk
        if pol.recurrent_bf16:  # §Perf: halve the [c, c] weight-matrix traffic
            y_intra = jnp.einsum("btsh,bshv->bthv", pw.astype(jnp.bfloat16),
                                 vb.astype(jnp.bfloat16),
                                 preferred_element_type=jnp.float32)
            n_intra = jnp.einsum("btsh,bshk->bthk", w.astype(jnp.bfloat16),
                                 kb.astype(jnp.bfloat16),
                                 preferred_element_type=jnp.float32)
        else:
            y_intra = jnp.einsum("btsh,bshv->bthv", pw, vb.astype(jnp.float32))
            n_intra = jnp.einsum("btsh,bshk->bthk", w, kb.astype(jnp.float32))
        # inter-chunk: old memory contribution
        g = jnp.exp(m_inter - m_t)                          # [B,c,H]
        y_inter = jnp.einsum("bthk,bhkv->bthv", qb.astype(jnp.float32) * scale, cm) * g[..., None]
        n_inter = jnp.einsum("bthk,bhk->bth", qb.astype(jnp.float32) * scale, nm)[..., None] * g[..., None]
        num = y_intra + y_inter
        den = jnp.abs(jnp.einsum("bthk,bthk->bth", qb.astype(jnp.float32) * scale, n_intra)[..., None]
                      + n_inter)
        h = num / jnp.maximum(den, jnp.exp(-m_t)[..., None])
        # carry update to chunk end
        m_end = jnp.maximum(mm + f_tot, f_tot + jnp.max(a, axis=1))
        decay_old = jnp.exp(mm + f_tot - m_end)             # [B,H]
        wk_end = jnp.exp(f_tot[:, None] - f_cum + lib - m_end[:, None])  # [B,c,H]
        c_new = cm * decay_old[..., None, None] + jnp.einsum(
            "bsh,bshk,bshv->bhkv", wk_end, kb.astype(jnp.float32), vb.astype(jnp.float32))
        n_new = nm * decay_old[..., None] + jnp.einsum("bsh,bshk->bhk", wk_end, kb.astype(jnp.float32))
        return (c_new, n_new, m_end), h

    (c_out, n_out, m_out), hs = jax.lax.scan(body, (c0, n0, m0), (qs, ks_, vs, lis, lfs))
    h = hs.swapaxes(0, 1).reshape(b, s, hp, hd).astype(cd)
    # z gate covers the real heads only; padded (dead) heads gate to zero
    real = z.shape[-1] // hd
    zr = jax.nn.silu(z).reshape(b, s, real, hd)
    if hp > real:
        zr = jnp.pad(zr, ((0, 0), (0, 0), (0, hp - real), (0, 0)))
    h = h * zr
    out = jnp.einsum("bshk,hkd->bsd", h, p["down"].astype(cd))
    return out, {"c": c_out, "n": n_out, "m": m_out, "conv": conv_state}


def init_mlstm_state(b: int, heads_p: int, hd: int, di: int, conv: int = 4,
                     dtype=jnp.float32) -> dict:
    return {
        "c": jnp.zeros((b, heads_p, hd, hd), jnp.float32),
        "n": jnp.zeros((b, heads_p, hd), jnp.float32),
        "m": jnp.full((b, heads_p), -1e30, jnp.float32),
        "conv": jnp.zeros((b, conv - 1, di), dtype),
    }


def init_slstm_state(b: int, heads_p: int, hd: int) -> dict:
    z = jnp.zeros((b, heads_p, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z - 1e30}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d: int, heads: int, heads_p: int, dtype=jnp.float32) -> dict:
    hd = d // heads
    ks = jax.random.split(key, 3)
    p = {
        "w": normal(ks[0], (d, 4, heads_p, hd), d**-0.5, dtype),       # z i f o
        "r": normal(ks[1], (4, heads_p, hd, hd), hd**-0.5, dtype),     # recurrent, block-diag
        "b": jnp.zeros((4, heads_p, hd), dtype),
        "down": normal(ks[2], (heads_p, hd, d), d**-0.5, dtype),
    }
    if heads_p > heads:
        mask = (jnp.arange(heads_p) < heads)[:, None, None]
        p["down"] = p["down"] * mask
    b = np.zeros((4, heads_p, hd), np.float32)
    b[2] = 3.0  # forget-gate bias
    p["b"] = jnp.asarray(b, dtype)
    return p


def slstm_forward(p: dict, x: Array, pol: Policy, *, state: dict | None = None,
                  unroll: int | None = None):
    """Sequential sLSTM with chunk-unrolled evaluation.

    The recurrence is inherently sequential, but scanning one *time step*
    per loop iteration makes XLA re-touch the recurrent weights (and, in
    pure-DP training, all-reduce their gradient) once per token.  Unrolling
    ``unroll`` steps inside each scan tick divides that per-iteration
    traffic by ``unroll`` with bit-identical math (§Perf xlstm iteration 3).
    """
    b, s, d = x.shape
    cd = pol.compute_dtype
    wx = jnp.einsum("bsd,dghk->bsghk", x, p["w"].astype(cd)).astype(jnp.float32)  # [B,S,4,H,hd]
    hp, hd = p["w"].shape[2], p["w"].shape[3]
    if state is None:
        zeros = jnp.zeros((b, hp, hd), jnp.float32)
        st = {"c": zeros, "n": zeros, "h": zeros, "m": zeros - 1e30}
    else:
        st = {k: v.astype(jnp.float32) for k, v in state.items()}
    r = p["r"].astype(jnp.float32)
    bias = p["b"].astype(jnp.float32)

    u = unroll if unroll is not None else pol.slstm_unroll
    u = max(1, min(u, s))
    while s % u:
        u -= 1
    nc = s // u

    def step(carry, wx_t):
        c, n, h, m = carry
        pre = wx_t + jnp.einsum("bhk,ghkj->bghj", h, r) + bias[None]
        zt = jnp.tanh(pre[:, 0])
        logi = pre[:, 1]
        logf = jax.nn.log_sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(logf + m, logi)
        i_s = jnp.exp(logi - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * zt
        n = f_s * n + i_s
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    def chunk_body(carry, wx_c):  # wx_c [B, u, 4, H, hd]
        hs = []
        for t in range(u):  # unrolled: weights touched once per chunk
            carry, h = step(carry, wx_c[:, t])
            hs.append(h)
        return carry, jnp.stack(hs, axis=1)  # [B, u, H, hd]

    wx_chunks = wx.reshape(b, nc, u, 4, hp, hd).swapaxes(0, 1)
    (c, n, h, m), hs = jax.lax.scan(
        chunk_body, (st["c"], st["n"], st["h"], st["m"]), wx_chunks)
    hseq = hs.swapaxes(0, 1).reshape(b, s, hp, hd).astype(cd)
    out = jnp.einsum("bshk,hkd->bsd", hseq, p["down"].astype(cd))
    return out, {"c": c, "n": n, "h": h, "m": m}
