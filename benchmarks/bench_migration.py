"""Fig. 3 — imbalance + relative state migration over a drifting 20-batch
stream (LFM-like), 20 partitions, partitioner update forced per batch."""
from __future__ import annotations

import numpy as np

from repro.core import (
    Histogram,
    kip_update,
    load_imbalance,
    make_baseline,
    plan_migration,
    uniform_partitioner,
)
from repro.core.migration import migration_capacity
from repro.data.generators import drifting_zipf

N = 20
BATCHES = 20
BATCH = 100_000
WORKERS = 4  # exchange-plane lane granularity (partition -> worker = p % W)


SMOKE = dict(reps=1)  # CI bench-smoke profile


def run(reps: int = 3):
    rows = []
    results: dict[str, tuple] = {}
    for method in ["hash", "scan", "readj", "kip"]:
        imb_all, mig_all, lane_all = [], [], []
        for rep in range(reps):
            if method == "kip":
                part = uniform_partitioner(N)
                update = lambda prev, hist, n=N: kip_update(prev, hist.top(2 * N))
            else:
                update, part = make_baseline(method, N)
            imb, mig, lanes = [], [], []
            window: list[np.ndarray] = []  # sliding state window of 5 batches
            for batch in drifting_zipf(BATCHES, BATCH, num_keys=10_000, exponent=1.0,
                                       drift_every=4, drift_fraction=0.3, seed=rep):
                hist = Histogram.exact(batch)
                new = update(part, hist.top(2 * N), N)
                window = (window + [batch])[-5:]
                # states linear in the keygroup size over the window
                live, counts = np.unique(np.concatenate(window), return_counts=True)
                plan = plan_migration(part, new, live, counts.astype(np.float64))
                mig.append(plan.relative_migration)
                # exchange-plane lane rows this swap would ship (vs. the
                # full-state all-to-all of W * len(live) rows)
                lanes.append(migration_capacity(plan, num_workers=WORKERS)
                             / max(len(live), 1))
                part = new
                imb.append(load_imbalance(part, batch))
            imb_all.append(np.mean(imb[1:]))
            mig_all.append(np.mean(mig[1:]))
            lane_all.append(np.mean(lanes[1:]))
        results[method] = (float(np.mean(imb_all)), float(np.mean(mig_all)))
        rows.append((f"fig3/imbalance/{method}", results[method][0], "mean over stream"))
        if method != "hash":
            rows.append((f"fig3/migration/{method}", results[method][1], "fraction/update"))
            rows.append((f"fig3/exchange_lane_fraction/{method}",
                         float(np.mean(lane_all)),
                         "a2a lane rows / live state rows (full-state a2a = 1)"))
    # paper's claims: KIP imbalance beats hash/scan/readj; KIP migrates far
    # less than readj-style rebuilds
    imp_hash = 1 - results["kip"][0] / results["hash"][0]
    imp_scan = 1 - results["kip"][0] / results["scan"][0]
    imp_readj = 1 - results["kip"][0] / results["readj"][0]
    rows.append(("fig3/kip_improvement_vs_hash", imp_hash, "paper: 41%"))
    rows.append(("fig3/kip_improvement_vs_scan", imp_scan, "paper: 29%"))
    rows.append(("fig3/kip_improvement_vs_readj", imp_readj, "paper: 26%"))
    rows.append(("fig3/migration_ratio_readj_over_kip",
                 results["readj"][1] / max(results["kip"][1], 1e-9), "paper: ~4x"))
    return rows
