"""Pallas TPU kernel: count-min-sketch accumulation (DRW sampling hot path).

Each grid step consumes a [2, 128] tile of keys and accumulates all ``depth``
sketch rows held in VMEM across the (sequential) TPU grid::

    for d in range(depth):
        col = fmix32(key ^ seed_d) % width
        sketch[d, col] += 1          # as one-hot matvec, no dynamic scatter

The scatter-free formulation is the TPU-native rewrite of the per-record
hash-map increments a JVM worker would do: a [block, width] one-hot reduced
over the block dim lowers to an MXU matmul with a ones vector.

VMEM budget (block = 256, width <= 4096, depth <= 8):
  one-hot 256*4096*4B = 4 MiB; sketch 8*4096*4B = 128 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.partition_apply import KEY_LANES, KEY_ROWS, _fmix32


def _kernel(keys_ref, valid_ref, out_ref, *, depth: int, width: int):
    blk = KEY_ROWS * KEY_LANES
    keys = keys_ref[...].reshape(blk)
    valid = valid_ref[...].reshape(blk).astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    col_iota = jax.lax.broadcasted_iota(jnp.int32, (blk, width), 1)
    acc = out_ref[...]
    for d in range(depth):
        seed_d = (d * 0x9E3779B9) & 0xFFFFFFFF
        mixed = _fmix32(keys.astype(jnp.uint32) ^ jnp.uint32(seed_d))
        col = (mixed % jnp.uint32(width)).astype(jnp.int32)
        onehot = (col[:, None] == col_iota).astype(jnp.float32) * valid[:, None]
        row = jnp.sum(onehot, axis=0)  # [width]
        acc = acc.at[d, :].add(row)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("depth", "width", "interpret"))
def sketch_update(
    keys: jax.Array,  # int32[n], n % 256 == 0
    valid: jax.Array,  # bool[n]
    *,
    depth: int = 4,
    width: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """Returns the float32[depth, width] count-min sketch of the batch."""
    n = keys.shape[0]
    blk = KEY_ROWS * KEY_LANES
    assert n % blk == 0, f"pad keys to a multiple of {blk}"
    keys2d = keys.reshape(n // KEY_LANES, KEY_LANES)
    valid2d = valid.astype(jnp.int32).reshape(n // KEY_LANES, KEY_LANES)

    return pl.pallas_call(
        functools.partial(_kernel, depth=depth, width=width),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((KEY_ROWS, KEY_LANES), lambda i: (i, 0)),
            pl.BlockSpec((KEY_ROWS, KEY_LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((depth, width), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.float32),
        interpret=interpret,
    )(keys2d, valid2d)
