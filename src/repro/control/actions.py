"""Typed actions the policy stack returns to its drivers.

A policy never mutates the runtime: it returns an :class:`Action` and the
driver (``StreamingJob``, ``DRScheduler``, the MoE train loop) executes it
at the safe point — migrate state, add/remove replicas, permute expert
weights.  ``NoOp`` carries the decline reason so declined decisions are as
observable as taken ones.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

from repro.core.partitioner import Partitioner

__all__ = [
    "Action",
    "Evict",
    "NoOp",
    "Quarantine",
    "Recover",
    "Repartition",
    "Resize",
    "Replace",
    "SwitchBackend",
    "Split",
    "Unsplit",
]


@dataclasses.dataclass(frozen=True)
class Action:
    """Base decision record; ``reason`` is always human-readable."""

    reason: str
    kind: ClassVar[str] = "action"
    # whether executing this action migrates state (rows, sessions, expert
    # weights).  Consumers that count "repartitions" — anything dividing
    # migration rows by a taken-action count — gate on this instead of
    # re-listing the exceptions at every call site.
    moves_state: ClassVar[bool] = True

    @property
    def taken(self) -> bool:
        return not isinstance(self, NoOp)


@dataclasses.dataclass(frozen=True)
class NoOp(Action):
    """Decline — keep the current topology/contents.  Carries the decision
    diagnostics so compat wrappers can rebuild a full ``DRDecision``."""

    measured_imbalance: float = 0.0
    planned_imbalance: float = 0.0
    est_migration: float = 0.0
    kind: ClassVar[str] = "noop"


@dataclasses.dataclass(frozen=True)
class Repartition(Action):
    """Swap partition *contents*: install ``partitioner``, migrate state off
    ``prev`` (the paper's §4 trigger outcome)."""

    partitioner: Partitioner = None
    prev: Partitioner = None
    planned_imbalance: float = 0.0
    measured_imbalance: float = 0.0
    est_migration: float = 0.0     # exchange-lane cost estimate (peak lane mass x slack)
    kind: ClassVar[str] = "repartition"


@dataclasses.dataclass(frozen=True)
class Resize(Action):
    """Change the partition/replica *count* to ``target`` (elastic resize,
    serving scale-out/in).  ``requested=True`` marks an explicit driver
    request rather than a policy decision."""

    target: int = 0
    requested: bool = False
    kind: ClassVar[str] = "resize"


@dataclasses.dataclass(frozen=True)
class Replace(Action):
    """Re-place experts onto shards (MoE expert placement — state migration
    is a permutation of the stacked expert arrays).

    When the policy priced candidate placements (expert-weight bytes through
    the exchange backend's sizing rule), the winning placement rides the
    action: ``placement``/``perm`` are the chosen tables, ``choice`` names
    the candidate, and ``est_migration`` is its weight-bytes cost.  A bare
    ``Replace`` (all defaults) asks the host to compute the placement
    itself — the pre-costing behavior."""

    placement: object = None       # ExpertPlacement | None
    perm: object = None            # int32[E_phys] slot permutation | None
    choice: str = ""               # candidate name ("" = host decides)
    planned_imbalance: float = 0.0
    est_migration: float = 0.0     # expert-weight bytes through the exchange
    kind: ClassVar[str] = "replace"


@dataclasses.dataclass(frozen=True)
class Split(Action):
    """Replicate one hot key over ``replicas`` consecutive partitions
    starting at its ``home`` — the Partial-Key-Grouping move for a key whose
    load alone exceeds what one worker sustains (isolation can only *move*
    it; splitting *shrinks* it).

    Install-only: the DRM stamps the replica table
    (``Partitioner.with_splits``) and the route kernels start fanning the
    key out; no state moves.  The scattered partial aggregates stay correct
    because the keyed reduce is a sum and every later migration routes by
    *home*, converging and merging the partials there."""

    key: int = 0
    replicas: int = 2
    home: int = 0
    top_share: float = 0.0         # the key's share of one worker's load
    est_relief: float = 0.0        # load (worker units) the split sheds
    est_migration: float = 0.0     # priced merge-backhaul lane cost
    kind: ClassVar[str] = "split"
    moves_state: ClassVar[bool] = False  # table stamp only; no rows migrate


@dataclasses.dataclass(frozen=True)
class Unsplit(Action):
    """Collapse a cooled-down split key back to its home partition.

    Executing it *is* a state migration off ``prev`` (the partitioner that
    still carried the split): the home route pulls every replica's partial
    rows back to the key's home, where ``merge_into`` sums them — the
    combiner-side merge riding the ordinary backhaul path."""

    key: int = 0
    prev: Partitioner = None
    kind: ClassVar[str] = "unsplit"


@dataclasses.dataclass(frozen=True)
class Quarantine(Action):
    """Circuit-break a sick lane: fold its partitions onto the healthy
    workers (the modulo placement re-folds them once the lane leaves the
    collective) and park the device for a possible :class:`Recover`.

    Executing it *is* a state migration — every row the sick lane held
    re-lands on a surviving worker — priced like any other move
    (``est_migration``, the fold's exchange-lane cost under the active
    transport).  ``lane`` is the *current* lane index; the driver maps it
    to the physical device."""

    lane: int = 0
    straggle_ms: float = 0.0       # the lane's EWMA straggle the decision keyed on
    failures: int = 0              # consecutive failed windows at decision time
    est_migration: float = 0.0     # priced fold (exchange-lane cost units)
    kind: ClassVar[str] = "quarantine"


@dataclasses.dataclass(frozen=True)
class Evict(Action):
    """Remove a lane for good (permanent loss): hard worker loss discovered
    by the recovery protocol, or a lane whose exchanges keep failing past
    the retry budget.  Like :class:`Quarantine` the surviving workers adopt
    the lane's state, but the device is never re-admitted."""

    lane: int = 0
    failures: int = 0
    kind: ClassVar[str] = "evict"


@dataclasses.dataclass(frozen=True)
class Recover(Action):
    """Re-admit the oldest quarantined lane after its probe timer expires
    (the circuit breaker's half-open transition).  Priced: the fold-back
    migration (``est_migration``) must pay for the capacity the extra
    worker regains."""

    lane: int = -1                 # original lane label (diagnostic)
    est_migration: float = 0.0
    kind: ClassVar[str] = "recover"


@dataclasses.dataclass(frozen=True)
class SwitchBackend(Action):
    """Swap the exchange *transport* (dense <-> ragged) at a safe point —
    the transport as one more control-plane actuator.  The driver rebuilds
    its jitted shuffle/migrate steps for the new backend exactly like a
    resize rebuilds them for a new lane count; no state moves.
    ``padding_fraction`` records the occupancy signal the decision keyed on.
    """

    backend: str = ""              # target transport name ("dense" | "ragged")
    padding_fraction: float = 0.0  # occupied / provisioned rows this window
    kind: ClassVar[str] = "switch_backend"
    moves_state: ClassVar[bool] = False  # steps rebuild; no rows migrate
