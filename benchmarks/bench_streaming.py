"""Fig. 6 — relative streaming-throughput increase from DR vs. Zipf
exponent, measured on the real micro-batch runtime (StreamingJob on the
local mesh; stateful count reducer, matching the paper's Flink setup).

Every skewed profile runs under both exchange backends: the dense
capacity-padded transport and the ragged count-first one.  Per backend the
CSV carries rows shipped + wall time (``fig6/exchange_*`` with a backend
column), the ragged rows must be strictly below the dense padded provision
on these power-law profiles, and the two backends must produce *exactly*
the same keyed-state counts — any mismatch raises, failing the bench run
(the CI bench-smoke gate).

The split-phase pipeline gets its own columns: the blocking exchange wall
per batch and the drained end-to-end run wall, overlapped driver vs.
serial, on the skewed profiles (``fig6/exchange_step_wall_ms`` /
``fig6/overlap_run_wall_ms`` with a ``dense/overlap`` vs. ``dense/serial``
column), gated on the run wall: overlap <= serial * 1.25 — hiding the row
ship behind host work must never cost end-to-end time.

Also measures the elastic-resize cost (rows shipped + wall time for a
grow 4->8 and a shrink 8->4, next to the plain migration rows) and the
control plane under *nonstationary* drift: a sudden hotspot flip, and a
sawtooth-skew workload with the resize-cooldown oscillation guard off vs.
on.  Every scenario row carries the decision log's taken/declined counts
(``fig6/decisions_*`` rows are the counts themselves).

The hot-key scenario (``fig6/split_decisions/*``) drives one key past a
worker's entire fair share — the regime where no repartition or resize can
balance (moving the key just moves the straggler).  The split profile must
reach imbalance <= the grow trigger while the no-split control stays above
it, and both must agree bit-for-bit on every key's aggregate (the split
run's scattered partials sum to the unsplit answer).

The topology scenario (``fig6/inter_host_rows/*``) runs the skewed stream
on a two-host profile — 8 lanes, 4 per host, in a subprocess with 8 forced
XLA host devices (device count must be fixed before jax init; the parent
bench process keeps its default) — under flat dense vs. the hierarchical
two-tier transport.  Both must agree bit-for-bit on the keyed state, the
per-class columns land in the CSV, and the hierarchical run must ship
*strictly fewer* inter-host rows than the flat dense pad (the CI gate).
``fig6/topology_decisions/*`` compares the control plane's recorded
decision trajectory locality-aware vs. locality-blind on one imbalanced
window: the 10x inter-host price must flip at least one candidate-plan
choice in the decision log."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.compat import has_ragged_all_to_all
from repro.core.drm import DRConfig
from repro.core.streaming import StreamingJob
from repro.data.generators import drifting_zipf, hotspot_flip, sawtooth_skew, zipf_keys
from repro.exchange import resolve_backend

EXPONENTS = [1.0, 1.3, 1.6, 2.0]


def _worker_time(job_metrics, per_record_us=1.0, per_batch_overhead_us=2000.0):
    """Straggler-bound completion: batches gated by the most loaded worker."""
    t = 0.0
    for m in job_metrics:
        t += m.worker_imbalance * per_record_us + per_batch_overhead_us * 1e-3
    return t


SMOKE = dict(batches=3, batch_size=4_096)  # CI bench-smoke profile


def _assert_backend_equivalence(jobs: dict, stream: list[np.ndarray], exp: float):
    """Exact-count gate: dense and ragged runs must agree bit-for-bit on the
    keyed state (and on overflow totals).  A mismatch raises, which the
    bench harness turns into a FAILED row + nonzero exit."""
    all_keys = np.unique(np.concatenate(stream))
    sample = all_keys[:: max(1, len(all_keys) // 64)]
    for key in sample:
        got = {be: job.state_count(int(key)) for be, (job, _) in jobs.items()}
        if len(set(got.values())) != 1:
            raise AssertionError(
                f"backend count mismatch at exp={exp} key={int(key)}: {got}"
            )
    overflow = {be: sum(m.overflow for m in ms) for be, (_, ms) in jobs.items()}
    if len(set(overflow.values())) != 1:
        raise AssertionError(f"backend overflow mismatch at exp={exp}: {overflow}")


def run(batches: int = 6, batch_size: int = 16_384):
    rows = []
    state_capacity = 16_384
    wall_pairs: list[tuple[float, float]] = []  # (dense, ragged) wall per exp
    for exp in EXPONENTS:
        stream = list(drifting_zipf(batches, batch_size, num_keys=5_000,
                                    exponent=exp, drift_every=100, seed=int(exp * 7)))
        # the DR-on run under both exchange transports (identical results,
        # different traffic); DR-off once for the throughput-gain baseline
        jobs = {}
        for be in ("dense", "ragged"):
            job = StreamingJob(
                num_partitions=8,
                state_capacity=state_capacity,
                dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2),
                exchange_backend=be,
            )
            # pin both runs to one migration-pricing rule: the equivalence
            # gate below asserts bit-identical state, which needs identical
            # control decisions — backend-specific pricing (the feature
            # test_repartition_cost_uses_host_backend covers) could
            # legitimately flip a gain-vs-cost call between the two runs
            job.drm.exchange_backend = resolve_backend("dense")
            ms = job.run(stream)
            jobs[be] = (job, ms)
            shipped = sum(m.shipped_rows for m in ms)
            padded = sum(m.padded_rows for m in ms)
            rows.append((f"fig6/exchange_rows/exp={exp}", shipped,
                         f"rows shipped over {batches} batches (provisioned {padded})",
                         be))
            rows.append((f"fig6/exchange_wall_ms/exp={exp}",
                         float(np.mean([m.wall_time_s for m in ms[1:]])) * 1e3,
                         "mean batch wall", be))
            # the exchange step alone (shuffle dispatch + collective +
            # reduce), batch 0 excluded (it pays the jit): the wall-clock
            # side of the rows-shipped story, per backend
            rows.append((f"fig6/exchange_step_wall_ms/exp={exp}",
                         float(np.mean([m.exchange_wall_s for m in ms[1:]])) * 1e3,
                         "mean exchange-path wall per batch", be))
        _assert_backend_equivalence(jobs, stream, exp)
        dense_padded = sum(m.padded_rows for m in jobs["dense"][1])
        ragged_shipped = sum(m.shipped_rows for m in jobs["ragged"][1])
        # count-first traffic tracks real rows: strictly below the padded
        # provision on every one of these power-law profiles
        assert ragged_shipped < dense_padded, (exp, ragged_shipped, dense_padded)
        wall_pairs.append((
            float(np.sum([m.exchange_wall_s for m in jobs["dense"][1][1:]])),
            float(np.sum([m.exchange_wall_s for m in jobs["ragged"][1][1:]])),
        ))

        job_off = StreamingJob(
            num_partitions=8,
            state_capacity=state_capacity,
            dr_enabled=False,
            dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2),
        )
        ms_off = job_off.run(stream)
        job, ms = jobs["dense"]
        # throughput proxy: records / straggler-bound time
        imb_on = np.mean([m.imbalance for m in ms[1:]])
        imb_off = np.mean([m.imbalance for m in ms_off[1:]])
        mig_rows = sum(m.migration_rows for m in ms)
        reparts = sum(m.repartitioned for m in ms)
        gain = imb_off / imb_on - 1.0
        rows.append((f"fig6/throughput_gain/exp={exp}", gain,
                     "relative increase (paper: biggest at moderate exp)"))
        if reparts:
            # bounded exchange: rows shipped per repartition vs. the
            # full-state all-to-all (W * state_capacity rows per worker)
            full = job.num_workers * state_capacity
            rows.append((f"fig6/migration_rows_fraction/exp={exp}",
                         mig_rows / reparts / full,
                         f"{reparts} repartitions, full-state a2a = 1"))
    if has_ragged_all_to_all():
        # with the native collective the wall-clock must follow the rows:
        # ragged no slower than dense across the skewed profiles (aggregated
        # over all exponents; 25% headroom absorbs shared-CI timer noise)
        dense_wall = sum(d for d, _ in wall_pairs)
        ragged_wall = sum(r for _, r in wall_pairs)
        assert ragged_wall <= dense_wall * 1.25, (ragged_wall, dense_wall)
    rows.extend(_overlap_cost(batches, batch_size, state_capacity))
    rows.extend(_resize_cost(4, 8, batch_size, state_capacity))
    rows.extend(_resize_cost(8, 4, batch_size, state_capacity))
    rows.extend(_nonstationary(batches, batch_size, state_capacity))
    rows.extend(_auto_backend(batches, batch_size, state_capacity))
    rows.extend(_hot_key(batches, batch_size, state_capacity))
    rows.extend(_topology(batches, batch_size))
    rows.extend(_fault_free_identity(batches, batch_size, state_capacity))
    rows.extend(_failure(batches, batch_size))
    return rows


def _overlap_cost(batches: int, batch_size: int, state_capacity: int):
    """Latency hiding from the split-phase pipeline: the same skewed stream
    through the serial driver (blocks on the whole exchange every batch),
    the overlapped one (blocks on the count phase only; the row ship drains
    behind the control plane's host work), and the depth-2 one (additionally
    routes batch N+1 behind batch N's ship, ping-ponging two persistent
    send-buffer sets).

    Emits the blocking exchange wall per batch under all three modes
    (reporting: where each driver pays — the serial one inside the batch
    that acts, the pipelined ones spread over the following count syncs)
    and gates on the *end-to-end* run wall, drained: overlap <= serial *
    1.25 and depth2 <= overlap * 1.10, aggregated over the skewed profiles.
    The first three batches run outside the timed window — they pay the jit
    (batch 0) and the one-time recompiles when the state and the recycled
    send buffers first arrive with committed shardings (batches 1-2: the
    ping-pong pool only fills at the first drain), and the serial and
    split-phase drivers compile different programs, so including them gates
    compiler wall, not pipeline wall.  The scenario sizes its own stream
    (>= 8 batches) so the timed window exists even at the smoke profile.  A small absolute slack keeps the
    ratio gates meaningful when the timed window is milliseconds (the smoke
    profile).  Work is conserved, so per-batch blocking wall just moves
    between modes; the run wall is what latency hiding must actually
    improve (the slack absorbs shared-CI timer noise).  The depth-2 hidden share of the ship
    wall must not regress either: mean ``overlap_fraction`` >= depth-1's
    (small absolute slack for the timer).  All runs must take identical
    control decisions — pipelining is a scheduling change, not a semantic
    one — and the ragged transport must agree too: a depth-2 ragged run is
    held to the serial ragged trajectory and to bit-identical keyed state."""
    import jax

    rows = []
    walls = {"serial": 0.0, "overlap": 0.0, "depth2": 0.0}
    fracs: dict[str, list[float]] = {"overlap": [], "depth2": []}
    n = max(batches, 8)  # warmup eats 3 batches; keep a real timed window
    for exp in (1.3, 1.6):
        stream = list(drifting_zipf(n, batch_size, num_keys=5_000,
                                    exponent=exp, drift_every=100, seed=int(exp * 11)))
        jobs = {}
        for mode, (overlap, depth) in (("serial", (False, 1)),
                                       ("overlap", (True, 1)),
                                       ("depth2", (True, 2))):
            job = StreamingJob(
                num_partitions=8,
                state_capacity=state_capacity,
                dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2,
                            overlap_exchange=overlap, pipeline_depth=depth),
            )
            ms = job.run(stream[:3])  # untimed: pays the jit + recompiles
            jax.block_until_ready(job.state_keys)
            t0 = time.perf_counter()
            ms += job.run(stream[3:])
            jax.block_until_ready(job.state_keys)  # drain the pipeline
            run_wall = time.perf_counter() - t0
            jobs[mode] = (job, ms)
            walls[mode] += run_wall
            if mode in fracs:
                fracs[mode].extend(m.overlap_fraction for m in ms[1:])
            rows.append((f"fig6/exchange_step_wall_ms/exp={exp}",
                         float(np.mean([m.exchange_wall_s for m in ms[1:]])) * 1e3,
                         "blocking exchange wall per batch", f"dense/{mode}"))
            rows.append((f"fig6/overlap_run_wall_ms/exp={exp}", run_wall * 1e3,
                         f"end-to-end drained, {n - 3} timed batches",
                         f"dense/{mode}"))
        if len(stream) > 4:
            # the smoke profile is too short to guarantee a staged batch
            # survives its predecessor's safe point (actions drop the
            # stage); _sync_free gates engagement on the calm profile
            assert any(m.pipelined for m in jobs["depth2"][1]), "depth-2 never staged"
        acts = {mode: [(m.action, m.reason, m.overflow, m.shipped_rows)
                       for m in ms] for mode, (_, ms) in jobs.items()}
        if not (acts["serial"] == acts["overlap"] == acts["depth2"]):
            raise AssertionError(f"pipelining changed the trajectory at exp={exp}: {acts}")
        # bit-identity: the depth-2 run's keyed state vs. the serial answer
        sample = np.unique(np.concatenate(stream))[::64]
        for key in sample:
            got = {mode: job.state_count(int(key)) for mode, (job, _) in jobs.items()}
            if len(set(got.values())) != 1:
                raise AssertionError(f"depth-2 count mismatch at key={int(key)}: {got}")
    rows.append(("fig6/overlap_run_wall_ratio",
                 walls["overlap"] / max(walls["serial"], 1e-12),
                 "overlapped run wall / serial (lower = more hidden)"))
    rows.append(("fig6/depth2_run_wall_ratio",
                 walls["depth2"] / max(walls["overlap"], 1e-12),
                 "depth-2 run wall / depth-1 (gate: <= 1.10)"))
    assert walls["overlap"] <= walls["serial"] * 1.25 + 0.05, walls
    assert walls["depth2"] <= walls["overlap"] * 1.10 + 0.05, walls
    f1 = float(np.mean(fracs["overlap"]))
    f2 = float(np.mean(fracs["depth2"]))
    rows.append(("fig6/overlap_fraction/depth1", f1,
                 "mean hidden/(hidden+ship) wall share, depth-1"))
    rows.append(("fig6/overlap_fraction/depth2", f2,
                 "mean hidden/(hidden+ship) wall share, depth-2 (gate: >= depth-1)"))
    assert f2 >= f1 - 0.05, (f2, f1)  # slack: sub-ms timer on shared CI
    rows.extend(_ragged_depth2(batches, batch_size, state_capacity))
    rows.extend(_sync_free(batches, batch_size, state_capacity))
    return rows


def _ragged_depth2(batches: int, batch_size: int, state_capacity: int):
    """The depth-2 pipeline over the count-first transport: same trajectory
    and bit-identical keyed state as the serial ragged run (the transport
    and the pipeline depth are independent axes; both backends honor the
    persistent buffer seam)."""
    stream = list(drifting_zipf(batches, batch_size, num_keys=5_000,
                                exponent=1.6, drift_every=100, seed=23))
    jobs = {}
    for mode, (overlap, depth) in (("serial", (False, 1)), ("depth2", (True, 2))):
        job = StreamingJob(
            num_partitions=8,
            state_capacity=state_capacity,
            dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.2,
                        overlap_exchange=overlap, pipeline_depth=depth),
            exchange_backend="ragged",
        )
        job.drm.exchange_backend = resolve_backend("dense")  # pin pricing
        jobs[mode] = (job, job.run(stream))
    acts = {mode: [(m.action, m.reason, m.overflow, m.shipped_rows)
                   for m in ms] for mode, (_, ms) in jobs.items()}
    if acts["serial"] != acts["depth2"]:
        raise AssertionError(f"ragged depth-2 changed the trajectory: {acts}")
    sample = np.unique(np.concatenate(stream))[::64]
    for key in sample:
        got = {mode: job.state_count(int(key)) for mode, (job, _) in jobs.items()}
        if len(set(got.values())) != 1:
            raise AssertionError(f"ragged depth-2 count mismatch key={int(key)}: {got}")
    shipped = sum(m.shipped_rows for m in jobs["depth2"][1])
    return [("fig6/depth2_ragged_shipped_rows", shipped,
             f"rows shipped, ragged transport under the depth-2 driver "
             f"({batches} batches)")]


def _sync_free(batches: int, batch_size: int, state_capacity: int):
    """The CI sync-audit gate: a steady-state depth-2 run (triggers parked,
    every safe point a noop) must perform *zero* audited host transfers
    between safe points — every device->host fetch in the driver goes
    through ``compat.host_fetch`` inside a declared ``safe_point`` region,
    so any stray blocking transfer shows up in the counter and fails the
    bench."""
    from repro import compat

    stream = list(drifting_zipf(max(4, batches), batch_size, num_keys=5_000,
                                exponent=1.3, drift_every=100, seed=3))
    job = StreamingJob(
        num_partitions=8,
        state_capacity=state_capacity,
        dr=DRConfig(imbalance_trigger=1e9, pipeline_depth=2),
    )
    job.run(stream[:2])  # warmup: compile + fill the pipeline
    compat.reset_host_sync_count()
    ms = job.run(stream[2:])
    syncs = compat.host_sync_count()
    assert syncs == 0, f"{syncs} host syncs outside safe points"
    assert all(m.action == "noop" for m in ms)
    assert all(m.pipelined for m in ms[1:])
    return [("fig6/host_syncs_per_batch", syncs / max(len(ms), 1),
             f"audited transfers outside safe points over {len(ms)} steady "
             "depth-2 batches (gate: 0)")]


def _decision_rows(tag: str, job: StreamingJob):
    """Decision-log columns: taken/declined counts for one scenario run."""
    taken, declined = job.drm.decisions.counts()
    return [
        (f"fig6/decisions_taken/{tag}", taken, "control-plane actions executed"),
        (f"fig6/decisions_declined/{tag}", declined, "declined safe points (reasons in log)"),
    ]


def _nonstationary(batches: int, batch_size: int, state_capacity: int):
    """Controller under nonstationary drift (not just static power-law).

    * ``hotspot_flip`` — the whole heavy set swaps identity mid-run; DR must
      re-trigger and re-isolate the new set (imbalance recovers toward the
      pre-flip level instead of staying pinned at the UHP ceiling).
    * ``sawtooth`` — imbalance flips across the grow/shrink triggers every
      half-period.  With the cooldown guard off the elastic policy
      ping-pongs the partition count; with it on (cooldown spanning the
      observation window) the same workload produces zero resize reversals
      — the declined resizes show up in the decision columns instead.
    """
    rows = []
    ticks = max(8, 2 * batches)

    # -- sudden hotspot flip under plain DR (no elastic) -------------------
    job = StreamingJob(
        num_partitions=8,
        state_capacity=state_capacity,
        dr=DRConfig(imbalance_trigger=1.15, migration_cost_weight=0.2),
    )
    ms = job.run(hotspot_flip(ticks, batch_size, num_keys=4_000, exponent=1.6, seed=5))
    flip = ticks // 2
    pre = float(np.mean([m.imbalance for m in ms[1:flip]]))
    post = float(np.mean([m.imbalance for m in ms[flip + 1:]]))
    rows.append(("fig6/hotspot_flip/imbalance_ratio", post / max(pre, 1e-9),
                 "mean imb after flip / before (1 = fully re-isolated)"))
    rows.extend(_decision_rows("hotspot_flip", job))

    # -- sawtooth skew: oscillation guard off vs. on -----------------------
    # plain DR stays on (it rebalances contents during the flat phase, so
    # the measured imbalance genuinely flips across the elastic triggers)
    for guard_on in (False, True):
        job = StreamingJob(
            num_partitions=4,
            state_capacity=state_capacity,
            dr=DRConfig(
                elastic=True, min_partitions=4, max_partitions=8,
                grow_trigger=2.0, shrink_trigger=1.45, resize_patience=1,
                resize_cooldown=ticks if guard_on else 0,
                imbalance_trigger=1.3, migration_cost_weight=0.05,
                sketch_decay=0.5,
            ),
        )
        ms = job.run(sawtooth_skew(ticks, batch_size, num_keys=2_000,
                                   exponent=1.8, period=3, seed=7))
        sizes = [m.num_partitions for m in ms if m.resized]
        prev = [4] + sizes[:-1]
        dirs = [s > p for s, p in zip(sizes, prev)]
        reversals = sum(1 for a, b in zip(dirs, dirs[1:]) if a != b)
        tag = "guard=on" if guard_on else "guard=off"
        rows.append((f"fig6/sawtooth_resize_reversals/{tag}", reversals,
                     f"{len(sizes)} resizes over {ticks} safe points"))
        rows.extend(_decision_rows(f"sawtooth_{tag}", job))
        if guard_on:
            # acceptance: the guard kills the ping-pong outright while the
            # initial grow-under-sustained-skew still fires
            assert reversals == 0, sizes
            assert sizes and sizes[0] == 8, sizes
    return rows


def _auto_backend(batches: int, batch_size: int, state_capacity: int):
    """The transport as an actuator: a generously padded job starts dense,
    the ``BackendPolicy`` watches the measured padding fraction stay low and
    flips it to ragged at a safe point.  The decision trajectory lands in
    the CSV (``fig6/backend_switches/*``) next to decisions_taken/declined,
    so the flip is visible output, not something to infer from row counts.
    """
    ticks = max(6, batches)
    job = StreamingJob(
        num_partitions=8,
        state_capacity=state_capacity,
        capacity_factor=4.0,  # generous pad: the lanes run ~25% full
        dr=DRConfig(imbalance_trigger=1e9, auto_backend=True,
                    backend_patience=2, backend_cooldown=4 * ticks),
    )
    ms = job.run(zipf_keys(batch_size, num_keys=4_000, exponent=1.2, seed=31 + t)
                 for t in range(ticks))
    switches = [(m.batch, m.backend) for m in ms if m.action == "switch_backend"]
    # the flip fires once (patience), lands on ragged, and never reverses
    # inside the cooldown — the oscillation guard, one actuator over
    assert len(switches) == 1, [m.action for m in ms]
    assert job.exchange_backend.name == "ragged", job.exchange_backend.name
    sw = switches[0][0]
    trajectory = "->".join(
        f"{m.backend}@{m.batch}" for m in ms if m.batch in (0, sw, sw + 1)
    )
    rows = [
        ("fig6/backend_switches/auto", len(switches), f"trajectory {trajectory}"),
        ("fig6/backend_switches/flip_batch", sw,
         f"padding fraction stayed under {job.drm.config.backend_ragged_below}"),
        ("fig6/backend_switches/post_flip_shipped_fraction",
         float(np.mean([m.shipped_rows / max(m.padded_rows, 1)
                        for m in ms[sw + 1:]])),
         "shipped/provisioned after the flip (dense = 1)"),
    ]
    rows.extend(_decision_rows("auto_backend", job))
    return rows


def _hot_key(batches: int, batch_size: int, state_capacity: int):
    """Hot-key splitting: one key carries ~40% of the stream — ~3.2 fair
    worker budgets on 8 partitions, so per-partition imbalance is pinned
    near ``share * N`` however the keys are binned.  With
    ``split_keys_enabled`` the SplitPolicy replicates the key (d = ceil of
    its budget share), the route kernels fan its records out, and the
    measured imbalance must drop under the elastic grow trigger — the load
    a resize would otherwise chase without ever balancing.  The no-split
    control (same stream, same DR otherwise) must stay above the trigger,
    and both runs must agree exactly on every key's aggregate: the split
    run's scattered partial aggregates sum to the unsplit answer."""
    ticks = max(10, 2 * batches)
    rng = np.random.default_rng(17)
    stream = []
    for _ in range(ticks):
        ks = rng.integers(100, 4100, size=batch_size).astype(np.int64)
        ks[rng.random(batch_size) < 0.40] = 7
        stream.append(ks)
    rows, jobs = [], {}
    tail_window = max(3, ticks // 3)  # post-split regime (split fires early)
    for tag, enabled in (("control", False), ("split", True)):
        job = StreamingJob(
            num_partitions=8,
            state_capacity=state_capacity,
            dr=DRConfig(split_keys_enabled=enabled, split_patience=1,
                        imbalance_trigger=1.15, migration_cost_weight=0.2),
        )
        ms = job.run(stream)
        jobs[tag] = (job, ms)
        tail = float(np.mean([m.imbalance for m in ms[-tail_window:]]))
        splits = sum(1 for m in ms if m.action in ("split", "unsplit"))
        rows.append((f"fig6/split_decisions/{tag}", splits,
                     f"split/unsplit actions taken ({max(m.split_keys for m in ms)}"
                     " keys replicated at peak)"))
        rows.append((f"fig6/split_imbalance/{tag}", tail,
                     f"mean measured imbalance, last {tail_window} batches"))
        rows.extend(_decision_rows(f"hot_key_{tag}", job))
    grow = jobs["split"][0].drm.config.grow_trigger
    tail = {tag: float(np.mean([m.imbalance for m in ms[-tail_window:]]))
            for tag, (_, ms) in jobs.items()}
    # acceptance: splitting balances what nothing else can — the split run
    # settles under the grow trigger, the control stays pinned above it
    assert jobs["split"][1][-1].split_keys >= 1, "the hot key never split"
    assert tail["split"] <= grow, tail
    assert tail["control"] > grow, tail
    # exactness: the scattered partials sum to the unsplit reference on
    # every sampled key (the combiner-side merge is a sum, bit-exact here)
    sample = np.unique(np.concatenate(stream))[::64]
    for key in sample:
        got = {tag: job.state_count(int(key)) for tag, (job, _) in jobs.items()}
        if len(set(got.values())) != 1:
            raise AssertionError(f"split count mismatch at key={int(key)}: {got}")
    return rows


_TOPOLOGY_SCRIPT = textwrap.dedent(
    """
    import json, os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf
    from repro.exchange import ExchangeTopology

    batches, batch_size = int(sys.argv[1]), int(sys.argv[2])
    mesh = jax.make_mesh((8,), ("data",))
    # the two-host profile: 8 lanes, lanes 0-3 on host 0, 4-7 on host 1
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    stream = list(drifting_zipf(batches, batch_size, num_keys=4_000,
                                exponent=1.4, drift_every=2,
                                drift_fraction=0.4, seed=13))
    out = {}
    jobs = {}
    for be in ("dense", "hierarchical"):
        job = StreamingJob(
            mesh=mesh, num_partitions=8, state_capacity=8_192,
            dr=DRConfig(imbalance_trigger=1.1, migration_cost_weight=0.1),
            exchange_backend=be, topology=topo,
        )
        ms = job.run(stream)
        jobs[be] = job
        out[be] = {
            "by_class": [int(x) for x in
                         np.sum([m.shipped_rows_by_class for m in ms], axis=0)],
            "shipped": int(sum(m.shipped_rows for m in ms)),
            "step_wall_ms": float(np.mean([m.exchange_wall_s for m in ms[1:]])) * 1e3,
            "actions": [m.action for m in ms],
            "overflow": int(sum(m.overflow for m in ms)),
            "inter_host_fraction": float(
                np.sum([m.shipped_rows_by_class[2] for m in ms])
                / max(sum(m.shipped_rows for m in ms), 1)),
        }
    # bit-identity gate: both transports, same keyed state, exactly
    sample = np.unique(np.concatenate(stream))[::64]
    for key in sample:
        got = {be: jobs[be].state_count(int(key)) for be in jobs}
        if len(set(got.values())) != 1:
            raise AssertionError(f"topology count mismatch key={int(key)}: {got}")
    print("TOPOLOGY-RESULT " + json.dumps(out))
    """
)


def _topology(batches: int, batch_size: int):
    """Two-host locality profile: flat dense vs. the hierarchical two-tier
    transport on 8 real shards (subprocess: the device count must be fixed
    before jax initializes).  Emits per-class shipped rows + exchange wall
    per backend and gates on strictly fewer inter-host rows under the
    hierarchical transport; the decision-flip comparison runs in-process
    (host-side plan pricing needs no collective)."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _TOPOLOGY_SCRIPT, str(batches), str(batch_size)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    marker = "TOPOLOGY-RESULT "
    line = next((l for l in proc.stdout.splitlines() if l.startswith(marker)), None)
    if proc.returncode != 0 or line is None:
        raise AssertionError(
            f"two-host topology subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    out = json.loads(line[len(marker):])
    # identical control trajectories: the transport must not change the
    # control plane's view of the stream (same contract as dense-vs-ragged)
    if out["dense"]["actions"] != out["hierarchical"]["actions"]:
        raise AssertionError(f"transport changed the trajectory: {out}")
    if out["dense"]["overflow"] != out["hierarchical"]["overflow"]:
        raise AssertionError(f"overflow accounting diverged: {out}")
    rows = []
    for be in ("dense", "hierarchical"):
        r = out[be]
        rows.append((f"fig6/inter_host_rows/{be}", r["by_class"][2],
                     f"rows crossing the host boundary over {batches} batches "
                     f"(fraction {r['inter_host_fraction']:.3f})",
                     be, tuple(r["by_class"])))
        rows.append((f"fig6/topology_exchange_step_wall_ms/{be}",
                     r["step_wall_ms"],
                     "mean exchange-path wall per batch (two-host profile)",
                     be, tuple(r["by_class"])))
    # the CI gate: the two-tier exchange concentrates cross-host traffic
    # into the counted inter hop — strictly fewer inter-host rows than the
    # flat dense pad on this skewed profile
    d, h = out["dense"]["by_class"][2], out["hierarchical"]["by_class"][2]
    assert 0 < h < d, (h, d)
    rows.extend(_topology_decisions())
    return rows


def _topology_decisions():
    """Locality-aware vs. locality-blind control on identical windows: the
    same imbalanced signal sequence through two DRMasters, one carrying the
    two-host topology with the 10x inter-host price, one flat.  Both
    decision logs are recorded; the priced one must flip at least one
    choice (typically declining a repartition whose balance gain does not
    pay for cross-host state movement)."""
    from repro.control import Telemetry
    from repro.core.drm import DRMaster
    from repro.core.partitioner import uniform_partitioner
    from repro.exchange import ExchangeTopology

    rng = np.random.default_rng(29)
    keys = np.repeat(np.arange(64), rng.integers(1, 200, 64)).astype(np.int32)
    # every lane its own host: all cross-worker movement is inter-host,
    # priced 400x — the blind DRM sees the same plans at flat cost
    topo = ExchangeTopology(num_lanes=4, lanes_per_host=1,
                            class_weights=(0.0, 1.0, 400.0))
    logs = {}
    for tag, t in (("blind", None), ("aware", topo)):
        drm = DRMaster(
            uniform_partitioner(4, seed=0),
            DRConfig(imbalance_trigger=1.05, migration_cost_weight=1.0),
            exchange_topology=t,
        )
        for step in range(4):
            drm.observe(keys.reshape(1, -1),
                        np.ones((1, len(keys)), np.int32),
                        total_records=float(len(keys)))
            tel = Telemetry("bench")
            tel.record_batch(float(len(keys)))
            loads = np.bincount(
                drm.partitioner.lookup_np(keys), minlength=4
            ).astype(float)
            sig = tel.snapshot(loads=loads, num_workers=4, at_safe_point=True)
            drm.evaluate(sig)
        logs[tag] = [(r.kind, r.taken) for r in drm.decisions.records]
    flips = sum(1 for a, b in zip(logs["aware"], logs["blind"]) if a != b)
    taken = {tag: sum(1 for _, t in log if t) for tag, log in logs.items()}
    # acceptance: locality pricing flipped at least one recorded choice,
    # in the direction of moving less across hosts
    assert flips >= 1, logs
    assert taken["aware"] < taken["blind"], (taken, logs)
    return [
        ("fig6/topology_decisions/blind", taken["blind"],
         "actions taken with flat plan pricing (4 safe points)"),
        ("fig6/topology_decisions/aware", taken["aware"],
         "actions taken with 400x inter-host pricing (same windows)"),
        ("fig6/topology_decisions/flipped", flips,
         "safe points where locality pricing changed the recorded choice"),
    ]


_FAILURE_SCRIPT = textwrap.dedent(
    """
    import json, os, sys, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.drm import DRConfig
    from repro.core.streaming import StreamingJob
    from repro.data.generators import drifting_zipf
    from repro.exchange import FaultPlan, FaultyBackend, LaneFault

    batches, batch_size = int(sys.argv[1]), int(sys.argv[2])
    stream = list(drifting_zipf(batches, batch_size, num_keys=2_000,
                                exponent=1.3, drift_every=100, seed=0))
    total_records = float(sum(len(b) for b in stream))

    def run(backend=None):
        mesh = jax.make_mesh((8,), ("data",))
        kw = {"exchange_backend": backend} if backend is not None else {}
        job = StreamingJob(mesh=mesh, num_partitions=8, state_capacity=8_192,
                           dr=DRConfig(imbalance_trigger=1e9,
                                       snapshot_interval=3), **kw)
        ms = job.run(stream)
        return job, ms

    ref_job, _ = run()
    # kill lane 5 at exchange tick 4: one gap batch sits in the replay
    # buffer (snapshots refresh every 3 batches), so the recovery must
    # restore, replay the gap, and retry the lost batch on 7 workers
    plan = FaultPlan(faults=(LaneFault(4, 5, "kill"),))
    job, ms = run(FaultyBackend("dense", plan))
    assert len(job.recoveries) == 1, job.recoveries
    rec = job.recoveries[0]
    assert rec.kind == "evict", rec

    got = float(np.asarray(job.state_vals).sum())
    want = float(np.asarray(ref_job.state_vals).sum())
    assert want == total_records, (want, total_records)
    # exact per-key conservation, every key — the zero-loss claim
    all_keys = np.concatenate(stream)
    for key in np.unique(all_keys):
        a = job.state_count(int(key))
        b = float((all_keys == key).sum())
        assert a == b, (int(key), a, b)
    out = {
        "rows_lost": int(round(want - got)),
        "recovery_wall_ms": rec.wall_s * 1e3,
        "replayed": rec.replayed,
        "workers_after": rec.workers,
        "lane": rec.lane,
        "kills": job.exchange_backend.kills,
    }
    print("FAILURE-RESULT " + json.dumps(out))
    """
)


def _failure(batches: int, batch_size: int):
    """Kill-a-worker scenario (Fig 6 failure domain): 8 real shards, hard
    loss of lane 5 mid-stream, zero-loss recovery through the safe-point
    protocol — restore the auto-snapshot, replay the gap, resume on the
    shrunk 7-worker topology.  Subprocess: the device count must be fixed
    before jax initializes.  Emits the recovery wall and the row-loss
    count; the CI smoke gate greps for ``fig6/rows_lost`` being exactly
    zero."""
    n = max(batches, 6)  # the kill tick needs stream to outlive it
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _FAILURE_SCRIPT, str(n), str(batch_size)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    marker = "FAILURE-RESULT "
    line = next((l for l in proc.stdout.splitlines() if l.startswith(marker)),
                None)
    if proc.returncode != 0 or line is None:
        raise AssertionError(
            f"kill-a-worker subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    out = json.loads(line[len(marker):])
    assert out["rows_lost"] == 0, out
    assert out["workers_after"] == 7, out
    assert out["kills"] == 1, out
    return [
        ("fig6/rows_lost", out["rows_lost"],
         f"rows lost across a hard loss of lane {out['lane']} "
         f"(protocol contract: exactly 0)"),
        ("fig6/recovery_wall_ms", out["recovery_wall_ms"],
         f"restore + replay of {out['replayed']} gap batch(es) + retry "
         f"onto {out['workers_after']} surviving workers"),
    ]


def _fault_free_identity(batches: int, batch_size: int, state_capacity: int):
    """An installed, never-firing FaultPlan must be bit-identical to no
    seam at all — serial, depth-1 and depth-2 drivers alike (the seam
    fires at the host boundary; the traced program is untouched).  Runs
    in-process on the single-device mesh; the 8-shard version gates in
    tests/test_distributed.py."""
    from repro.exchange import FaultPlan, FaultyBackend

    stream = [zipf_keys(batch_size, num_keys=2_000, exponent=1.3, seed=s)
              for s in range(max(batches, 4))]
    rows = []
    modes = {
        "serial": dict(dr=dict(pipeline_depth=1), env="1"),
        "depth1": dict(dr=dict(pipeline_depth=1), env=None),
        "depth2": dict(dr=dict(pipeline_depth=2), env=None),
    }
    for mode, spec in modes.items():
        prev = os.environ.get("REPRO_DISABLE_OVERLAP")
        if spec["env"] is not None:
            os.environ["REPRO_DISABLE_OVERLAP"] = spec["env"]
        try:
            acts = {}
            for tag, backend in (("plain", "dense"),
                                 ("seamed", FaultyBackend("dense",
                                                          FaultPlan()))):
                job = StreamingJob(
                    num_partitions=8, state_capacity=state_capacity,
                    dr=DRConfig(imbalance_trigger=1.1,
                                migration_cost_weight=0.2, **spec["dr"]),
                    exchange_backend=backend,
                )
                ms = job.run(stream)
                acts[tag] = ([(m.action, m.reason, m.overflow,
                               m.shipped_rows) for m in ms],
                             float(np.asarray(job.state_vals).sum()))
            assert acts["plain"] == acts["seamed"], (mode, acts)
        finally:
            if spec["env"] is not None:
                if prev is None:
                    os.environ.pop("REPRO_DISABLE_OVERLAP", None)
                else:
                    os.environ["REPRO_DISABLE_OVERLAP"] = prev
        rows.append((f"fig6/fault_free_identity/{mode}", 1,
                     "never-firing FaultPlan bit-identical to no seam "
                     "(trajectory + state mass)"))
    return rows


def _resize_cost(base_n: int, target_n: int, batch_size: int, state_capacity: int):
    """Elastic-resize cost: exchange rows + wall time for one grow/shrink,
    under both exchange backends (the resize migration's sparse lanes are
    where the count-first transport pays off most).

    The resize batch pays the state migration *and* the shuffle-step rebuild
    (jit for the new lane count); a steady-state batch is reported alongside
    so the delta is visible."""
    rows = []
    tag = f"grow_{base_n}to{target_n}" if target_n > base_n else f"shrink_{base_n}to{target_n}"
    for be in ("dense", "ragged"):
        job = StreamingJob(
            num_partitions=base_n,
            state_capacity=state_capacity,
            dr=DRConfig(imbalance_trigger=1e9),  # isolate the resize: no plain DR
            exchange_backend=be,
        )
        warm = [zipf_keys(batch_size, num_keys=2_000, exponent=1.3, seed=s) for s in (20, 21)]
        for b in warm:
            steady = job.process_batch(b)
        job.resize(target_n)
        t0 = time.perf_counter()
        m = job.process_batch(zipf_keys(batch_size, num_keys=2_000, exponent=1.3, seed=22))
        wall_ms = (time.perf_counter() - t0) * 1e3
        assert m.resized, m.reason
        full = job.num_workers * state_capacity
        rows += [
            (f"fig6/resize_rows/{tag}", m.migration_rows,
             f"exchange buffer rows (plan {m.migration_plan_rows}; full-state a2a {full})",
             be),
            (f"fig6/resize_shipped_rows/{tag}", m.shipped_rows,
             "rows the backend measured moving on the resize batch", be),
            (f"fig6/resize_wall_ms/{tag}", wall_ms,
             f"resize batch incl. step rebuild (steady batch {steady.wall_time_s * 1e3:.1f} ms)",
             be),
        ]
    return rows
