"""Gradient compression (error feedback) + elastic resize features."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Histogram, kip_update, load_imbalance, plan_migration, uniform_partitioner
from repro.data.generators import zipf_keys
from repro.train.compression import _quantize, compressed_grad_sync, init_error_feedback


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,))
        q, scale = _quantize(x)
        err = jnp.abs(q.astype(jnp.float32) * scale - x)
        assert float(err.max()) <= float(scale) / 2 + 1e-6

    def test_error_feedback_unbiased_over_steps(self):
        """Sum of synced grads + final error == sum of true grads."""
        mesh = jax.make_mesh((1,), ("data",))
        sync = compressed_grad_sync(mesh, ("data",))
        rng = np.random.default_rng(0)
        g_true = [jnp.asarray(rng.standard_normal(64), jnp.float32) for _ in range(5)]
        err = {"w": jnp.zeros(64)}
        acc = jnp.zeros(64)
        for g in g_true:
            out, err = sync({"w": g}, err)
            acc = acc + out["w"]
        total_true = sum(g_true)
        np.testing.assert_allclose(np.asarray(acc + err["w"]), np.asarray(total_true),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.slow
    def test_multidevice_mean_matches_fp32(self):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train.compression import compressed_grad_sync, init_error_feedback
            mesh = jax.make_mesh((8,), ("data",))
            sync = compressed_grad_sync(mesh, ("data",))
            rng = np.random.default_rng(1)
            # per-replica distinct grads: sharded array [8, n] viewed per shard
            def local(g, e):
                return sync(g, e)
            g = {"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
            e = {"w": jnp.zeros(256)}
            out, e2 = sync(g, e)  # replicated input -> mean == input
            np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                       atol=2e-2)
            print("COMPRESS-OK")
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                             text=True, env=env, timeout=300,
                             cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert "COMPRESS-OK" in out.stdout, out.stdout + out.stderr


class TestElastic:
    """Elastic scaling via KIPUPDATE(N -> N') — node loss and scale-out."""

    def test_scale_out_rebalances(self):
        stream = zipf_keys(200_000, num_keys=20_000, exponent=1.0, seed=0)
        hist = Histogram.exact(stream).top(64)
        k8 = kip_update(uniform_partitioner(8), hist, tight=True)
        k12 = kip_update(k8, hist, num_partitions=12, tight=True)
        assert load_imbalance(k12, stream) < 1.35 * max(1, 12 * hist.freqs[0])
        # growing 8->12 must move >= 1 - 8/12 = 33% of mass; stays below 70%
        plan = plan_migration(k8, k12, np.unique(stream))
        assert 0.3 < plan.relative_migration < 0.7

    def test_node_failure_shrink(self):
        """Losing a worker = resize to N-1; all its keys leave partition N-1."""
        stream = zipf_keys(100_000, num_keys=10_000, exponent=1.1, seed=1)
        hist = Histogram.exact(stream).top(64)
        k8 = kip_update(uniform_partitioner(8), hist, tight=True)
        k7 = kip_update(k8, hist, num_partitions=7, tight=True)
        parts = k7.lookup_np(stream.astype(np.int32))
        assert parts.max() < 7
        assert load_imbalance(k7, stream) < 1.5 * max(1, 7 * hist.freqs[0])
