"""Expert-parallel MoE layer with DR-style dispatch.

The token -> expert exchange *is* the paper's keyed shuffle: keys are expert
ids, partitions are EP shards, and the routing table is the KIP placement
(``inv_place``: logical expert -> physical slot).  The layer runs under
``shard_map`` on the unified exchange plane (``repro.exchange``) — the same
capacity-padded ``route -> bucketize -> all_to_all -> unpack`` primitive as
``repro.core.shuffle`` — and emits per-expert load counts as the DRW
histogram, consumed by ``repro.moe.kip_placement``.

Two evaluation paths:

* ``moe_ref``     — dense oracle (every expert on every token, exact
  combine); used by tests and tiny CPU configs.
* ``moe_apply``   — the distributed dispatch (shard_map over (dp..., tp)):
  hop 1 ships records to the owning EP shard (a cross-shard exchange on the
  transport ``Policy.exchange_backend`` selects — dense or count-first
  ragged), hop 2 buckets received records into per-expert buffers (the
  local no-collective backend), and the combine rides the same lanes back
  (``backhaul`` + ``take_from``) — under the ragged transport the return
  trip reuses the forward hop's counts, so it ships compacted rows with no
  second count phase, and ``MoEOut.shipped_rows`` accounts both
  directions.  With generous capacity its output equals ``moe_ref``
  exactly, whatever the backend.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import MoESpec
from repro.exchange import ExchangeSpec, Payload, make_exchange, take_from
from repro.models.modules import Array, Policy, act_fn, init_ffn, no_shard, normal

__all__ = ["init_moe", "moe_ref", "moe_apply", "MoEOut"]


class MoEOut(NamedTuple):
    y: Array          # [B, S, d]
    counts: Array     # f32[E] global tokens routed per logical expert
    overflow: Array   # f32[] dropped (token, expert) pairs
    aux_loss: Array   # f32[] load-balancing auxiliary loss
    # rows the exchange transport measured moving across *both* dispatch
    # directions (forward ship + combine backhaul), summed over shards;
    # None on paths with no cross-shard exchange (oracle, replicated decode)
    shipped_rows: Array = None  # int32[]
    # rows actually live in the exchanged lanes, both directions — the
    # backend-independent occupancy (what a ragged transport would ship;
    # under dense, shipped is the pad while this tracks the real load).
    # ``exchange_stats()`` packages both for ``Telemetry.record_exchange``.
    occupied_rows: Array = None  # int32[]

    def exchange_stats(self, *, padded_rows: int = 0, wall_s: float = 0.0,
                       backend: str | None = None):
        """Package this step's dispatch traffic as one plane-constructed
        :class:`~repro.exchange.ExchangeStats` — the record
        ``Telemetry.record_exchange`` takes.  ``padded_rows`` is what the
        dispatch specs provisioned (both directions); paths with no
        cross-shard exchange report zero rows."""
        from repro.exchange import ExchangeStats

        rows = 0 if self.shipped_rows is None else int(self.shipped_rows)
        occ = None if self.occupied_rows is None else int(self.occupied_rows)
        return ExchangeStats(rows=rows, wall_s=wall_s, padded_rows=padded_rows,
                             occupied_rows=occ, backend=backend)


def init_moe(key, d: int, spec: MoESpec, ffn_kind: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    e, f = spec.num_experts, spec.d_ff_expert
    gate = 2 if ffn_kind in ("swiglu", "geglu") else 1
    p = {
        "router": normal(ks[0], (d, e), d**-0.5, jnp.float32),
        "wi": normal(ks[1], (e, d, gate, f), d**-0.5, dtype),
        "wo": normal(ks[2], (e, f, d), f**-0.5, dtype),
    }
    if spec.shared_expert:
        p["shared"] = init_ffn(ks[3], d, f, ffn_kind, dtype)
    return p


def _route(router_w, t, spec: MoESpec):
    """[T, d] -> (weights [T, k], logical ids [T, k], probs [T, E])."""
    logits = (t.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(logits, spec.top_k)
    if spec.top_k == 1:
        w = jax.nn.sigmoid(vals)  # llama4-style gate
    else:
        w = jax.nn.softmax(vals, axis=-1)
    return w, ids, probs


def _aux_loss(probs, ids, e: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    f = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    pm = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pm)


def _expert_ffn(wi, wo, x, ffn_kind: str):
    """x [E, C, d] through per-expert gated FFN."""
    a = act_fn(ffn_kind)
    h = jnp.einsum("ecd,edgf->ecgf", x, wi)  # g = gate axis
    h = a(h[:, :, 0]) * h[:, :, 1] if wi.shape[2] == 2 else a(h[:, :, 0])
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# reference (dense) path
# ---------------------------------------------------------------------------


def moe_ref(p: dict, x: Array, spec: MoESpec, ffn_kind: str, pol: Policy,
            inv_place: Array | None = None) -> MoEOut:
    b, s, d = x.shape
    cd = pol.compute_dtype
    t = x.reshape(-1, d)
    w, ids, probs = _route(p["router"], t, spec)
    # every expert over every token (oracle; fine for smoke-scale E)
    all_out = _expert_ffn(p["wi"].astype(cd), p["wo"].astype(cd),
                          jnp.broadcast_to(t[None], (spec.num_experts,) + t.shape), ffn_kind)
    sel = jnp.take_along_axis(
        all_out.transpose(1, 0, 2), ids[:, :, None], axis=1
    )  # [T, k, d]
    y = jnp.sum(sel * w[..., None].astype(cd), axis=1)
    if "shared" in p:
        from repro.models.modules import apply_ffn

        y = y + apply_ffn(p["shared"], x, ffn_kind, pol).reshape(-1, d)
    counts = jnp.sum(jax.nn.one_hot(ids, spec.num_experts, dtype=jnp.float32), axis=(0, 1))
    return MoEOut(y.reshape(b, s, d), counts, jnp.zeros((), jnp.float32),
                  _aux_loss(probs, ids, spec.num_experts))


# ---------------------------------------------------------------------------
# distributed expert-parallel path (the paper's shuffle, keys = experts)
# ---------------------------------------------------------------------------


def moe_apply(p: dict, x: Array, spec: MoESpec, ffn_kind: str, pol: Policy,
              inv_place: Array) -> MoEOut:
    """x [B, S, d] sharded P(dp..., tp, None); experts sharded over tp."""
    mesh = pol.mesh
    dp_axes, tp = pol.dp_axes, pol.tp_axis
    ntp = mesh.shape[tp]
    e = spec.num_experts
    assert e % ntp == 0, f"experts {e} not a multiple of tp {ntp}"
    e_loc = e // ntp
    cf = pol.moe_capacity_factor or spec.capacity_factor
    cd = pol.compute_dtype
    all_axes = tuple(dp_axes) + (tp,)

    def body(router_w, wi, wo, shared, inv_pl, x_loc):
        # x_loc [b_l, s_l, d]; wi/wo local slots [e_loc, ...]
        b_l, s_l, d = x_loc.shape
        t = x_loc.reshape(-1, d)
        tn = t.shape[0]
        w, ids, probs = _route(router_w, t, spec)
        k = spec.top_k
        rec_tok = jnp.repeat(jnp.arange(tn, dtype=jnp.int32), k)
        rec_e = ids.reshape(-1)
        rec_w = w.reshape(-1)
        phys = inv_pl[rec_e]
        dev = phys // e_loc
        eloc = phys % e_loc

        # hop 1: ship records to the owning EP shard (cross-shard exchange);
        # the transport comes from the policy (dense / ragged), the combine
        # backhauls over the same backend
        c1 = max(8, int(np.ceil(cf * tn * k / ntp / 8.0) * 8))
        ship = make_exchange(ExchangeSpec(num_lanes=ntp, capacity=c1, axis=tp),
                             pol.exchange_backend)
        res1 = ship(
            dev,
            jnp.ones_like(dev, bool),
            [Payload(t[rec_tok].astype(cd), 0), Payload(eloc, 0)],
        )
        rvalid, (rxf, ref_) = res1.unpack()

        # hop 2: bucket received records into local per-expert buffers
        # (axis-free spec -> the local no-collective backend)
        c2 = max(8, int(np.ceil(cf * tn * k / e_loc / 8.0) * 8))
        local = make_exchange(ExchangeSpec(num_lanes=e_loc, capacity=c2))
        res2 = local.bucketize(ref_, rvalid, [Payload(rxf, 0)])
        overflow = (res1.send.overflow + res2.send.overflow).astype(jnp.float32)

        eout = _expert_ffn(wi.astype(cd), wo.astype(cd), res2.payloads[0], ffn_kind)

        # return trip: gather each record's result, ship back over the same
        # lanes, combine.  The forward hop's exchanged counts make the
        # backhaul ragged with no second count phase (dense forward: the
        # return trip ships the pad, exactly as before).
        back = take_from(eout, res2.send).reshape(ntp, c1, d)
        ret, back_shipped, back_occupied = ship.backhaul(back, forward=res1)
        val = take_from(ret, res1.send)
        y = jnp.zeros((tn, d), cd).at[rec_tok].add(val * rec_w[:, None].astype(cd))

        if shared is not None:
            from repro.models.modules import apply_ffn

            pol_in = dataclasses.replace(pol, shard=no_shard)  # manual mesh inside
            y = y + apply_ffn(shared, x_loc, ffn_kind, pol_in).reshape(-1, d)

        counts = jnp.zeros((e,), jnp.float32).at[rec_e].add(1.0)
        counts = jax.lax.psum(counts, all_axes)
        overflow = jax.lax.psum(overflow, all_axes)
        aux = jax.lax.pmean(_aux_loss(probs, ids, e), all_axes)
        # both directions of measured traffic: forward ship + combine
        # backhaul; occupied is the backend-independent live-row count
        # (forward: records that landed a slot; return: the backhaul's
        # counted occupancy) — honest even on the dense path
        shipped = jax.lax.psum(res1.shipped_rows + back_shipped, all_axes)
        fwd_occupied = jnp.asarray(tn * k, jnp.int32) - res1.send.overflow
        occupied = jax.lax.psum(fwd_occupied + back_occupied, all_axes)
        return y.reshape(b_l, s_l, d), counts, overflow, aux, shipped, occupied

    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(tp), P(tp), P(), P(), P(dp_spec, tp, None)),
        out_specs=(P(dp_spec, tp, None), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    shared = p.get("shared")
    y, counts, overflow, aux, shipped, occupied = mapped(
        p["router"], p["wi"], p["wo"], shared, inv_place, x
    )
    return MoEOut(y, counts, overflow, aux, shipped, occupied)


def moe_apply_replicated(p: dict, x: Array, spec: MoESpec, ffn_kind: str, pol: Policy,
                         inv_place: Array) -> MoEOut:
    """Decode-path EP with expert tensor parallelism (no weight movement).

    Decode has a handful of tokens: moving weights to tokens (FSDP gathers)
    would ship GBs per decoded token.  Instead tokens are replicated to all
    shards; each (data, model) shard owns (its experts) x (an F-slice):
    experts sharded over ``model``, each expert's FFN hidden dim sharded
    over the data axes.  Every shard computes its partial contribution for
    all tokens and one psum over (data..., model) combines them.  The
    shared expert is F-sharded over ``model`` (scaled to ride the same
    psum).
    """
    mesh = pol.mesh
    dp_axes, tp = pol.dp_axes, pol.tp_axis
    ntp = mesh.shape[tp]
    e = spec.num_experts
    e_loc = e // ntp
    cd = pol.compute_dtype
    dpn = int(np.prod([mesh.shape[a] for a in dp_axes]))
    all_axes = tuple(dp_axes) + (tp,)
    a = act_fn(ffn_kind)

    def body(router_w, wi, wo, shared, inv_pl, x_loc):
        b_l, s_l, d = x_loc.shape  # replicated: b_l = full batch
        t = x_loc.reshape(-1, d)
        tn = t.shape[0]
        w, ids, probs = _route(router_w, t, spec)
        k = spec.top_k
        me = jax.lax.axis_index(tp)
        rec_tok = jnp.repeat(jnp.arange(tn, dtype=jnp.int32), k)
        rec_e = ids.reshape(-1)
        rec_w = w.reshape(-1)
        phys = inv_pl[rec_e]
        mine = (phys // e_loc) == me
        eloc = jnp.where(mine, phys % e_loc, 0)

        # local exchange: only this shard's (token, expert) pairs get slots
        c2 = max(8, int(np.ceil((pol.moe_capacity_factor or spec.capacity_factor)
                                * tn * k / max(e_loc, 1) / 8.0) * 8))
        local = make_exchange(ExchangeSpec(num_lanes=e_loc, capacity=c2))
        res = local.bucketize(eloc, mine, [Payload(t[rec_tok].astype(cd), 0)])
        overflow = res.send.overflow.astype(jnp.float32)
        # F-sliced expert FFN: wi [e_loc, d, g, F/dp], wo [e_loc, F/dp, d]
        h = jnp.einsum("ecd,edgf->ecgf", res.payloads[0], wi.astype(cd))
        h = a(h[:, :, 0]) * h[:, :, 1] if wi.shape[2] == 2 else a(h[:, :, 0])
        eout = jnp.einsum("ecf,efd->ecd", h, wo.astype(cd))  # partial over F
        val = take_from(eout, res.send)
        y = jnp.zeros((tn, d), cd).at[rec_tok].add(val * rec_w[:, None].astype(cd))
        if shared is not None:
            # shared expert F-sliced over model; identical on every data
            # shard, so scale by 1/dpn to survive the (data+model) psum
            swi, swo = shared["wi"].astype(cd), shared["wo"].astype(cd)
            sh = jnp.einsum("td,dgf->tgf", t, swi)
            sh = a(sh[:, 0]) * sh[:, 1] if swi.shape[1] == 2 else a(sh[:, 0])
            y = y + jnp.einsum("tf,fd->td", sh, swo) / dpn
        y = jax.lax.psum(y, all_axes)
        counts = jnp.zeros((e,), jnp.float32).at[rec_e].add(1.0)  # same on all shards
        overflow_g = jax.lax.pmean(overflow, all_axes) * ntp  # per-model-shard drops
        aux = _aux_loss(probs, ids, e)
        return y.reshape(b_l, s_l, d), counts, overflow_g, aux

    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),
            P(tp, None, None, dp_axes),   # wi: experts x model, F x data
            P(tp, dp_axes, None),          # wo
            P(),                           # shared: F x model handled below
            P(),
            P(None, None, None),           # tokens replicated
        ),
        out_specs=(P(None, None, None), P(), P(), P()),
        check_vma=False,
    )
    shared = p.get("shared")
    if shared is not None:
        # present the shared expert F-sliced over the model axis
        shared = {"wi": shared["wi"], "wo": shared["wo"]}
        shared_specs = {"wi": P(None, None, tp), "wo": P(tp, None)}
        mapped = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(), P(tp, None, None, dp_axes), P(tp, dp_axes, None),
                shared_specs, P(), P(None, None, None),
            ),
            out_specs=(P(None, None, None), P(), P(), P()),
            check_vma=False,
        )
    y, counts, overflow, aux = mapped(p["router"], p["wi"], p["wo"], shared, inv_place, x)
    return MoEOut(y, counts, overflow, aux)
