"""Sharding rules: map param/batch/cache pytrees to NamedShardings.

Strategy (DESIGN.md §6): DP over ("pod","data"), TP over "model" (heads /
d_ff / vocab / experts), SP (sequence-sharded residuals) between blocks,
FSDP over "data" for the weight matrices of the large archs, EP for MoE.
Rules are (path-substring, spec) pairs matched against flattened pytree
paths — later rules win.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import dp_axes_of
from repro.models.modules import Policy

TP = "model"


@dataclasses.dataclass(frozen=True)
class ShardingOptions:
    fsdp: bool = False          # shard big weight matrices over "data" too
    sp: bool = True             # sequence-sharded residual stream (train/prefill)
    compute_dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.bfloat16
    moment_dtype: jnp.dtype = jnp.float32
    remat: bool = True
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 2048
    # §Perf hillclimb knobs (defaults = paper-faithful baseline config)
    pure_dp: bool = False       # no TP: FSDP/ZeRO-3 over the whole mesh
    attn_p_bf16: bool = False   # bf16 softmax-weights @ V (halves attn HBM)
    recurrent_bf16: bool = False  # bf16 gate/qkv precompute in ssm/xlstm
    remat_policy: str = "nothing"  # "nothing" | "save_moe" (skip MoE recompute)
    moe_cf: float = 0.0         # capacity-factor override (0 = config value)
    slstm_unroll: int = 1       # sLSTM steps per scan tick


def default_options(cfg: ArchConfig) -> ShardingOptions:
    big = cfg.param_count() > 20e9
    huge = cfg.param_count() > 100e9
    return ShardingOptions(
        fsdp=big,
        moment_dtype=jnp.bfloat16 if huge else jnp.float32,
    )


def make_policy(cfg: ArchConfig, mesh: Mesh | None, shape_kind: str,
                opts: ShardingOptions) -> Policy:
    if mesh is None:
        return Policy()
    tp = 1 if opts.pure_dp else mesh.shape[TP]
    if opts.pure_dp:
        dp = tuple(mesh.axis_names)  # the whole mesh is data-parallel
    else:
        dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    constrain = shape_kind in ("train", "prefill") and opts.sp

    def shard(x, name):
        if not constrain:
            return x
        if opts.pure_dp:
            if name in ("act_btd", "logits") and x.ndim == 3:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(dp_spec, None, None)))
            return x
        spec = {
            "act_btd": P(dp_spec, TP, None),
            "act_q": P(dp_spec, None, TP, None),
            "act_kv": P(dp_spec, None, TP, None),
            "ffn_hidden4": P(dp_spec, None, None, TP),
            "ssm_inner": P(dp_spec, None, TP),
            "logits": P(dp_spec, None, TP),
        }.get(name)
        if spec is None or len(spec) != x.ndim:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return Policy(
        param_dtype=opts.param_dtype,
        compute_dtype=opts.compute_dtype,
        shard=shard,
        tp=tp,
        mesh=mesh,
        dp_axes=dp,
        tp_axis=TP,
        remat=opts.remat,
        attn_q_chunk=opts.attn_q_chunk,
        attn_kv_chunk=opts.attn_kv_chunk,
        attn_p_bf16=opts.attn_p_bf16,
        recurrent_bf16=opts.recurrent_bf16,
        remat_policy=opts.remat_policy,
        moe_capacity_factor=opts.moe_cf,
        slstm_unroll=opts.slstm_unroll,
    )


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def _param_rules(fsdp: bool, decode: bool = False):
    """(path substring regex, rank -> PartitionSpec).  First match wins.

    Decode mode: no FSDP (weight gathers per token are absurd); MoE expert
    FFNs are F-sharded over the data axes instead (expert-TP, zero weight
    movement — see moe_apply_replicated)."""
    fs = "data" if (fsdp and not decode) else None
    if decode:
        moe_rules = [
            (r"moe/router$", lambda r: P(*_pad(r, (None, None)))),
            (r"moe/wi$", lambda r: P(*_pad(r, (TP, None, None, "data")))),
            (r"moe/wo$", lambda r: P(*_pad(r, (TP, "data", None)))),
            (r"moe/shared/wi$", lambda r: P(*_pad(r, (None, None, TP)))),
            (r"moe/shared/wo$", lambda r: P(*_pad(r, (TP, None)))),
        ]
    else:
        moe_rules = [
            (r"moe/router$", lambda r: P(*_pad(r, (None, None)))),
            (r"moe/wi$", lambda r: P(*_pad(r, (TP, fs, None, None)))),
            (r"moe/wo$", lambda r: P(*_pad(r, (TP, None, fs)))),
            (r"moe/shared/wi$", lambda r: P(*_pad(r, (fs, None, TP)))),
            (r"moe/shared/wo$", lambda r: P(*_pad(r, (TP, fs)))),
        ]
    return moe_rules + [
        # embeddings / unembedding: vocab over model (+ d over data FSDP)
        (r"embed/tok$", lambda r: P(TP, fs)),
        (r"lm_head$", lambda r: P(TP, fs)),
        (r"dec_pos$", lambda r: P(None, TP)),
        # attention (leading period axis optional)
        (r"attn/wq$", lambda r: P(*_pad(r, (fs, TP, None)))),
        (r"attn/wk$", lambda r: P(*_pad(r, (fs, None, None)))),
        (r"attn/wv$", lambda r: P(*_pad(r, (fs, None, None)))),
        (r"attn/wo$", lambda r: P(*_pad(r, (TP, None, fs)))),
        # dense ffn
        (r"ffn/wi$", lambda r: P(*_pad(r, (fs, None, TP)))),
        (r"ffn/wo$", lambda r: P(*_pad(r, (TP, fs)))),
        # mamba
        (r"mamba/in_proj$", lambda r: P(*_pad(r, (fs, None, TP)))),
        (r"mamba/conv_w$", lambda r: P(*_pad(r, (None, TP)))),
        (r"mamba/conv_b$", lambda r: P(*_pad(r, (TP,)))),
        (r"mamba/x_proj$", lambda r: P(*_pad(r, (TP, None)))),
        (r"mamba/dt_proj$", lambda r: P(*_pad(r, (None, TP)))),
        (r"mamba/dt_bias$", lambda r: P(*_pad(r, (TP,)))),
        (r"mamba/a_log$", lambda r: P(*_pad(r, (TP, None)))),
        (r"mamba/d_skip$", lambda r: P(*_pad(r, (TP,)))),
        (r"mamba/out_proj$", lambda r: P(*_pad(r, (TP, fs)))),
        # xlstm
        (r"mlstm/up$", lambda r: P(*_pad(r, (fs, None, TP)))),
        (r"mlstm/conv_[wb]$", lambda r: P(*_pad(r, (None, TP) if r >= 2 else (TP,)))),
        (r"mlstm/w[qkv]$", lambda r: P(*_pad(r, (None, TP, None)))),
        (r"mlstm/w_if$", lambda r: P(*_pad(r, (None, None, TP)))),
        (r"mlstm/b_if$", lambda r: P(*_pad(r, (None, TP)))),
        (r"mlstm/down$", lambda r: P(*_pad(r, (TP, None, fs)))),
        (r"slstm/w$", lambda r: P(*_pad(r, (None, None, TP, None)))),
        (r"slstm/r$", lambda r: P(*_pad(r, (None, TP, None, None)))),
        (r"slstm/b$", lambda r: P(*_pad(r, (None, TP, None)))),
        (r"slstm/down$", lambda r: P(*_pad(r, (TP, None, fs)))),
        # norms + everything small: replicated
        (r"", lambda r: P()),
    ]


def _pad(rank: int, spec: tuple) -> tuple:
    """Left-pad a spec with None for the stacked period axis (if present)."""
    if rank == len(spec):
        return spec
    assert rank == len(spec) + 1, f"rank {rank} vs spec {spec}"
    return (None,) + spec


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}{i}/")
    elif tree is not None:
        yield prefix.rstrip("/"), tree


def param_shardings(params_abstract, mesh: Mesh, opts: ShardingOptions,
                    decode: bool = False):
    """NamedSharding pytree matching the abstract params."""
    if opts.pure_dp:
        return _pure_dp_shardings(params_abstract, mesh)
    rules = _param_rules(opts.fsdp, decode)

    def assign(path, leaf):
        for pat, fn in rules:
            if re.search(pat, path):
                spec = fn(leaf.ndim)
                # drop axes that do not divide evenly -> replicate that dim
                fixed = []
                for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
                    if ax is None:
                        fixed.append(None)
                        continue
                    size = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
                    fixed.append(ax if dim % size == 0 else None)
                return NamedSharding(mesh, P(*fixed))
        raise AssertionError(f"no rule for {path}")

    flat = dict(_tree_paths(params_abstract))
    specs = {k: assign(k, v) for k, v in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(*vals) if hasattr(tree, "_fields") else type(tree)(vals)
        if tree is None:
            return None
        return specs[prefix.rstrip("/")]

    return rebuild(params_abstract)


def _pure_dp_shardings(params_abstract, mesh: Mesh):
    """ZeRO-3/FSDP: every tensor sharded over the *whole* mesh along its
    first evenly-divisible dim (GSPMD gathers at use, reduce-scatters
    grads); small tensors replicate.  No TP => no head padding, no SP
    collectives — the right regime for sub-~3B models (§Perf)."""
    axes = tuple(mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes]))

    def assign(leaf):
        for i, dim in enumerate(leaf.shape):
            if dim % n == 0:
                spec = [None] * leaf.ndim
                spec[i] = axes
                return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree.map(assign, params_abstract)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch_abstract, mesh: Mesh, axes: tuple | None = None):
    dp = axes or dp_axes_of(mesh)

    def assign(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        # longest suffix of dp axes whose product divides the batch dim
        use = list(dp)
        while use and leaf.shape[0] % int(np.prod([mesh.shape[a] for a in use])):
            use.pop(0)
        if not use:
            return NamedSharding(mesh, P())
        spec = tuple(use) if len(use) > 1 else use[0]
        return NamedSharding(mesh, P(spec, *(None,) * (leaf.ndim - 1)))

    return jax.tree.map(assign, batch_abstract)


def cache_shardings(cache_abstract, mesh: Mesh, batch: int):
    """KV/SSM cache: batch over dp when divisible; heads/inner over model;
    for batch=1 long-context cells the KV *sequence* is sharded over data."""
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    dpn = int(np.prod([mesh.shape[a] for a in dp]))
    tp = mesh.shape[TP]
    batch_ok = batch % dpn == 0

    def assign(path, leaf):
        shape = leaf.shape
        spec = [None] * leaf.ndim
        # find batch dim: first dim equal to `batch` after optional stack axis
        for i, dim in enumerate(shape):
            if dim == batch and batch_ok and i <= 1:
                spec[i] = dp_spec
                break
        if re.search(r"/(k|v)$", path) and leaf.ndim >= 4:
            # [..., B, L, H, hd]
            h_axis = leaf.ndim - 2
            l_axis = leaf.ndim - 3
            if shape[h_axis] % tp == 0:
                spec[h_axis] = TP
            if not batch_ok and shape[l_axis] % dpn == 0:
                spec[l_axis] = dp_spec  # seq-sharded KV (long_500k)
        elif re.search(r"(ssm|conv)$", path) and leaf.ndim >= 3:
            # mamba states [..., B, *, di] — inner dim over model
            if shape[-1] % tp == 0:
                spec[-1] = TP
        elif re.search(r"/(c|n|m|h)$", path) and leaf.ndim >= 3:
            # xlstm states [..., B, H, ...]: heads over model
            h_axis = 2 if shape[0] != batch else 1
            if h_axis < leaf.ndim and shape[h_axis] % tp == 0:
                spec[h_axis] = TP
        return NamedSharding(mesh, P(*spec))

    flat = dict(_tree_paths(cache_abstract))
    specs = {k: assign(k, v) for k, v in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(*vals) if hasattr(tree, "_fields") else type(tree)(vals)
        if tree is None:
            return None
        return specs[prefix.rstrip("/")]

    return rebuild(cache_abstract)
