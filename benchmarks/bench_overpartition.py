"""Fig. 5 — processing time vs. #partitions (over-partitioning study),
ZIPF exponent 1.5, DR on/off, fixed worker count.

Paper: over-partitioning helps both; DR peaks at 2-3x the compute slots
(more partitions = more scheduling overhead), while hash keeps improving
but never reaches DR."""
from __future__ import annotations

import numpy as np

from benchmarks.common import stage_time
from repro.core import Histogram, kip_update, load_imbalance, uniform_partitioner
from repro.data.generators import zipf_keys

WORKERS = 10
PARTS = [10, 20, 30, 50, 80, 120]


SMOKE = dict(n_records=50_000)  # CI bench-smoke profile


def run(n_records: int = 400_000):
    rows = []
    # exponent chosen so N*f1 spans ~0.4..5 across the partition sweep (the
    # paper's 1.5-over-1M-keys regime; see bench_spark_like regime note)
    keys = zipf_keys(n_records, num_keys=100_000, exponent=0.9, seed=0)
    best = {}
    for n in PARTS:
        uhp = uniform_partitioner(n)
        hist = Histogram.exact(keys[: n_records // 10]).top(2 * n)
        kip = kip_update(uhp, hist, eps=0.003)
        t_hash = stage_time(uhp, keys, workers=WORKERS)
        t_dr = stage_time(kip, keys, workers=WORKERS)
        best[n] = (t_hash, t_dr)
        rows.append((f"fig5/time_hash/parts={n}", t_hash, "us"))
        rows.append((f"fig5/time_dr/parts={n}", t_dr, "us"))
        rows.append((f"fig5/imb_dr/parts={n}", load_imbalance(kip, keys), ""))
    t_dr_best = min(t for _, t in best.values())
    t_hash_best = min(t for t, _ in best.values())
    n_dr_best = min(best, key=lambda n: best[n][1])
    rows.append(("fig5/dr_best_parts_over_workers", n_dr_best / WORKERS,
                 "paper: best at 2-3x slots"))
    rows.append(("fig5/hash_cannot_reach_dr", t_hash_best / t_dr_best,
                 "paper: >1 — over-partitioning alone insufficient"))
    assert t_hash_best / t_dr_best > 1.0
    return rows
