"""Exchange backends: dense / ragged / local equivalence and cost rules.

The backend contract is bit-identity: on the same routed input every
transport must produce identical unpacked rows and identical overflow
accounting — they differ only in *how much* they ship (``shipped_rows``)
and what a candidate plan costs (``cost``).  Property tests cover the
bucketize layer on random inputs; the collective layer is exercised through
``shard_map`` here (single device) and on 8 real shards in
``tests/test_distributed.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.migration import exchange_lane_cost, plan_migration
from repro.core.partitioner import uniform_partitioner
from repro.exchange import (
    DenseBackend,
    ExchangeSpec,
    LocalBackend,
    Payload,
    RaggedBackend,
    backend_name,
    make_exchange,
    resolve_backend,
)

ALL_BACKENDS = ("dense", "ragged", "local")


def _random_input(rng, n, num_lanes, payload_dim=3):
    lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = rng.random(n) < 0.8
    vals = rng.normal(size=(n, payload_dim)).astype(np.float32)
    ints = rng.integers(0, 1000, n).astype(np.int32)
    return jnp.asarray(lane), jnp.asarray(valid), jnp.asarray(vals), jnp.asarray(ints)


# ---------------------------------------------------------------------------
# bucketize: transport-independent, bit-identical across backends
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(
    n=st.integers(min_value=1, max_value=512),
    num_lanes=st.integers(min_value=1, max_value=16),
    capacity=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_bucketize_bit_identical_across_backends(n, num_lanes, capacity, seed):
    rng = np.random.default_rng(seed)
    lane, valid, vals, ints = _random_input(rng, n, num_lanes)
    spec = ExchangeSpec(num_lanes=num_lanes, capacity=capacity)
    results = {
        be: make_exchange(spec, be).bucketize(
            lane, valid, [Payload(vals, 0), Payload(ints, -1)]
        )
        for be in ALL_BACKENDS
    }
    ref = results["dense"]
    for be, res in results.items():
        np.testing.assert_array_equal(np.asarray(res.valid), np.asarray(ref.valid), err_msg=be)
        for got, want in zip(res.payloads, ref.payloads):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want), err_msg=be)
        assert int(res.send.overflow) == int(ref.send.overflow), be
        np.testing.assert_array_equal(
            np.asarray(res.send.lane_overflow), np.asarray(ref.send.lane_overflow),
            err_msg=be,
        )
        # unpacked view identical too (the consumer-facing surface)
        va, flat = res.unpack()
        wa, wflat = ref.unpack()
        np.testing.assert_array_equal(np.asarray(va), np.asarray(wa), err_msg=be)
        for g, w in zip(flat, wflat):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=be)


@settings(max_examples=10)
@given(
    n=st.integers(min_value=8, max_value=512),
    num_lanes=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_lane_overflow_sums_to_scalar_in_range(n, num_lanes, seed):
    """With every lane in range, the per-lane vector is a refinement of the
    scalar: it sums to exactly the total overflow."""
    rng = np.random.default_rng(seed)
    lane, valid, vals, _ = _random_input(rng, n, num_lanes)
    res = make_exchange(ExchangeSpec(num_lanes=num_lanes, capacity=4)).bucketize(
        lane, valid, [Payload(vals, 0)]
    )
    assert int(np.asarray(res.send.lane_overflow).sum()) == int(res.send.overflow)


def test_lane_overflow_localizes_the_hot_lane():
    lane = jnp.asarray([1, 1, 1, 1, 1, 0], jnp.int32)  # lane 1 gets 5 > cap 2
    valid = jnp.ones(6, bool)
    res = make_exchange(ExchangeSpec(num_lanes=3, capacity=2)).bucketize(
        lane, valid, [Payload(jnp.arange(6, dtype=jnp.float32), 0)]
    )
    np.testing.assert_array_equal(np.asarray(res.send.lane_overflow), [0, 3, 0])
    assert int(res.send.overflow) == 3


def test_out_of_range_lane_counts_in_scalar_only():
    """A lane outside [0, L) has no lane to charge: the scalar sees it, the
    vector (by design) does not — the documented asymmetry."""
    lane = jnp.asarray([0, 7, -3], jnp.int32)
    valid = jnp.ones(3, bool)
    res = make_exchange(ExchangeSpec(num_lanes=2, capacity=4)).bucketize(
        lane, valid, [Payload(jnp.zeros(3), 0)]
    )
    assert int(res.send.overflow) == 2
    assert int(np.asarray(res.send.lane_overflow).sum()) == 0


# ---------------------------------------------------------------------------
# the collective: dense vs ragged through a real shard_map
# ---------------------------------------------------------------------------


def _run_collective(backend, lane, valid, vals, num_lanes, capacity):
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=num_lanes, capacity=capacity, axis="data"), backend
    )

    def body(lane, valid, vals):
        res = ex(lane, valid, [Payload(vals, -1.0)])
        va, (v,) = res.unpack()
        return va[None], v[None], res.shipped_rows, res.send.overflow

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(), P()),
        check_vma=False,
    )
    va, v, shipped, overflow = mapped(lane, valid, vals)
    return np.asarray(va), np.asarray(v), int(shipped), int(overflow)


@pytest.mark.parametrize("skew", ["uniform", "hot"])
def test_collective_backends_bit_identical(skew):
    rng = np.random.default_rng(3)
    n, num_lanes, capacity = 256, 4, 96
    if skew == "hot":
        lane = np.zeros(n, np.int32)  # everything to lane 0: max raggedness
    else:
        lane = rng.integers(0, num_lanes, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    vals = rng.normal(size=(n,)).astype(np.float32)
    out = {
        be: _run_collective(be, jnp.asarray(lane), jnp.asarray(valid),
                            jnp.asarray(vals), num_lanes, capacity)
        for be in ("dense", "ragged")
    }
    va_d, v_d, shipped_d, ov_d = out["dense"]
    va_r, v_r, shipped_r, ov_r = out["ragged"]
    np.testing.assert_array_equal(va_d, va_r)
    np.testing.assert_array_equal(v_d, v_r)
    assert ov_d == ov_r
    # dense ships the whole pad; ragged ships measured occupancy + counts
    assert shipped_d == num_lanes * capacity
    assert shipped_r <= shipped_d
    assert shipped_r == int(valid.sum() if skew == "uniform" else min(valid.sum(), capacity)) + num_lanes


def test_local_backend_refuses_mesh_axis():
    spec = ExchangeSpec(num_lanes=2, capacity=4, axis="data")
    ex = make_exchange(spec, "local")
    res = ex.bucketize(jnp.zeros(3, jnp.int32), jnp.ones(3, bool),
                       [Payload(jnp.zeros(3), 0)])
    with pytest.raises(AssertionError):
        ex.all_to_all(res)


# ---------------------------------------------------------------------------
# backend resolution + cost rules
# ---------------------------------------------------------------------------


def test_resolve_backend_auto_and_names():
    assert isinstance(resolve_backend(None, ExchangeSpec(2, 4)), LocalBackend)
    assert isinstance(resolve_backend(None, ExchangeSpec(2, 4, axis="data")), DenseBackend)
    assert isinstance(resolve_backend(None), DenseBackend)
    assert isinstance(resolve_backend("ragged"), RaggedBackend)
    be = RaggedBackend()
    assert resolve_backend(be) is be
    with pytest.raises(ValueError):
        resolve_backend("nccl")
    assert backend_name(None) == "auto"
    assert backend_name("dense") == "dense"
    assert backend_name(be) == "ragged"


@settings(max_examples=10)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_cost_rules_ordering(seed):
    """Ragged cost (mean real rows) never exceeds dense cost (padded peak);
    a local exchange is free."""
    rng = np.random.default_rng(seed)
    transfer = rng.random((6, 6)) * rng.integers(1, 100)
    np.fill_diagonal(transfer, 0.0)
    dense = DenseBackend().cost(None, transfer)
    ragged = RaggedBackend().cost(None, transfer)
    assert 0.0 <= ragged <= dense
    assert LocalBackend().cost(None, transfer) == 0.0
    assert DenseBackend().cost(None, np.zeros((0, 0))) == 0.0


def test_exchange_lane_cost_backend_rules():
    """The policy-facing cost helper: default == dense rule; ragged strictly
    cheaper on a skewed plan; local free."""
    old = uniform_partitioner(4, seed=0)
    new = uniform_partitioner(4, seed=3)
    plan = plan_migration(old, new, np.arange(512, dtype=np.int64))
    base = exchange_lane_cost(plan, num_workers=2)
    dense = exchange_lane_cost(plan, num_workers=2, backend=DenseBackend())
    ragged = exchange_lane_cost(plan, num_workers=2, backend=RaggedBackend())
    local = exchange_lane_cost(plan, num_workers=2, backend=LocalBackend())
    assert base == dense > 0
    assert 0 < ragged < dense  # a 2-worker fold has an empty diagonal to skip
    assert local == 0.0


def test_make_exchange_default_matches_pre_backend_behavior():
    """axis=None auto-selects the local transport; the collective verbs are
    identity, exactly the old ``Exchange`` with no axis."""
    ex = make_exchange(ExchangeSpec(num_lanes=3, capacity=4))
    assert isinstance(ex.backend, LocalBackend)
    res = ex(jnp.asarray([0, 1, 2], jnp.int32), jnp.ones(3, bool),
             [Payload(jnp.arange(3, dtype=jnp.float32), 0)])
    assert int(res.shipped_rows) == 0  # nothing crossed a mesh axis
    buf = np.asarray(res.payloads[0])
    assert buf[0, 0] == 0 and buf[1, 0] == 1 and buf[2, 0] == 2
