"""The decision log: every control-plane decision, taken or declined.

One log per policy host.  ``BatchMetrics`` reads the latest record's reason,
and the benchmarks read the taken/declined counters into their CSV rows, so
a run's decision history (including *why* nothing happened) is first-class
output rather than something to reconstruct from prints.  ``to_arrays`` /
``from_arrays`` round-trip the log through flat (npz-friendly) arrays so
any host's snapshot can carry its history.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.control.actions import Action

__all__ = ["Decision", "DecisionLog"]


@dataclasses.dataclass(frozen=True)
class Decision:
    tick: int              # the host's safe-point counter when decided
    consumer: str          # "stream" | "serve" | "moe"
    kind: str              # "noop" | "repartition" | "resize" | "replace"
    taken: bool
    reason: str
    imbalance: float = 0.0
    detail: dict = dataclasses.field(default_factory=dict)


class DecisionLog:
    """Bounded record list + unbounded counters.

    ``records`` keeps the most recent ``max_records`` decisions (a
    long-running job makes one decision per safe point forever — the log
    must not grow with the stream); the taken/declined counters are
    cumulative so ``counts()`` stays exact after trimming.
    """

    def __init__(self, consumer: str = "", max_records: int = 10_000):
        self.consumer = consumer
        self.max_records = max_records
        self.records: list[Decision] = []
        self._taken = 0
        self._declined = 0

    def record(
        self,
        action: Action,
        *,
        tick: int,
        imbalance: float = 0.0,
        detail: dict | None = None,
    ) -> Decision:
        d = Decision(
            tick=int(tick),
            consumer=self.consumer,
            kind=action.kind,
            taken=action.taken,
            reason=action.reason,
            imbalance=float(imbalance),
            detail=detail or {},
        )
        self.records.append(d)
        if len(self.records) > self.max_records:
            del self.records[: -self.max_records]
        if d.taken:
            self._taken += 1
        else:
            self._declined += 1
        return d

    def counts(self) -> tuple[int, int]:
        """(taken, declined) decision counts over the whole run."""
        return self._taken, self._declined

    def taken(self) -> list[Decision]:
        return [d for d in self.records if d.taken]

    def declined(self) -> list[Decision]:
        return [d for d in self.records if not d.taken]

    def tail(self, n: int = 10) -> list[Decision]:
        return self.records[-n:]

    def __len__(self) -> int:
        return len(self.records)

    # -- persistence (flat arrays, npz-friendly) ---------------------------
    def to_arrays(self, prefix: str = "decisions_") -> dict:
        """Columnar snapshot of the log: records as parallel arrays (details
        JSON-encoded) plus the cumulative counters."""
        taken, declined = self.counts()
        return {
            f"{prefix}consumer": np.str_(self.consumer),
            f"{prefix}tick": np.array([d.tick for d in self.records], np.int64),
            f"{prefix}kind": np.array([d.kind for d in self.records], np.str_),
            f"{prefix}taken": np.array([d.taken for d in self.records], bool),
            f"{prefix}reason": np.array([d.reason for d in self.records], np.str_),
            f"{prefix}imbalance": np.array(
                [d.imbalance for d in self.records], np.float64
            ),
            f"{prefix}detail": np.array(
                [json.dumps(d.detail) for d in self.records], np.str_
            ),
            f"{prefix}counts": np.array([taken, declined], np.int64),
        }

    @classmethod
    def from_arrays(cls, snap: dict, prefix: str = "decisions_") -> "DecisionLog":
        """Rebuild a log from :meth:`to_arrays` output (tolerates snapshots
        that predate persistence — those restore empty)."""
        log = cls(str(snap.get(f"{prefix}consumer", "")))
        if f"{prefix}tick" not in snap:
            return log
        for tick, kind, taken, reason, imb, detail in zip(
            np.asarray(snap[f"{prefix}tick"]),
            np.asarray(snap[f"{prefix}kind"]),
            np.asarray(snap[f"{prefix}taken"]),
            np.asarray(snap[f"{prefix}reason"]),
            np.asarray(snap[f"{prefix}imbalance"]),
            np.asarray(snap[f"{prefix}detail"]),
        ):
            log.records.append(Decision(
                tick=int(tick), consumer=log.consumer, kind=str(kind),
                taken=bool(taken), reason=str(reason),
                imbalance=float(imb), detail=json.loads(str(detail)),
            ))
        log._taken, log._declined = (int(x) for x in np.asarray(snap[f"{prefix}counts"]))
        return log
