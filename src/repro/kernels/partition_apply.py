"""Pallas TPU kernel: per-record partition lookup (the shuffle hot path).

For every key the partitioner computes::

    host = fmix32(key ^ seed) & (H - 1)
    part = heavy_parts[i]            if key == heavy_keys[i] for some i
         = host_to_part[host]        otherwise

TPU adaptation (vs. the JVM per-record hash-map of the paper): the heavy
table (B <= 1024 keys) and the host routing table (H = 4096) are pinned in
VMEM for the whole kernel; lookups are expressed as one-hot matmuls so they
lower to MXU/VPU ops instead of dynamic gathers.

VMEM budget per grid step (block = 256 keys, H = 4096, B = 1024):
  host one-hot  256*4096*4B = 4.0 MiB
  heavy one-hot 256*1024*4B = 1.0 MiB
  tables        (B*2 + H)*4B ~ 24 KiB          => ~5.1 MiB < 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# keys are processed in [KEY_ROWS, 128] tiles (lane dim = 128, TPU-native).
KEY_LANES = 128
KEY_ROWS = 2  # 256 keys per grid step


def _fmix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _kernel(keys_ref, heavy_keys_ref, heavy_parts_ref, host_ref, out_ref, *, seed: int, num_hosts: int):
    keys2d = keys_ref[...]  # [KEY_ROWS, 128] int32
    blk = KEY_ROWS * KEY_LANES
    keys = keys2d.reshape(blk)

    # ---- weighted hash: key -> host -> partition ----
    mixed = _fmix32(keys.astype(jnp.uint32) ^ jnp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF))
    host = (mixed & jnp.uint32(num_hosts - 1)).astype(jnp.int32)
    host_iota = jax.lax.broadcasted_iota(jnp.int32, (blk, num_hosts), 1)
    onehot_host = (host[:, None] == host_iota).astype(jnp.float32)  # [blk, H]
    table = host_ref[...].reshape(num_hosts).astype(jnp.float32)
    part_tail = jax.lax.dot_general(
        onehot_host, table[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    # ---- explicit heavy-key routing ----
    hk = heavy_keys_ref[...].reshape(-1)  # [B] sorted, sentinel padded
    hp = heavy_parts_ref[...].reshape(-1).astype(jnp.float32)
    eq = (keys[:, None] == hk[None, :]).astype(jnp.float32)  # [blk, B]
    hit = jnp.sum(eq, axis=1) > 0.0
    part_heavy = jax.lax.dot_general(
        eq, hp[:, None], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]

    part = jnp.where(hit, part_heavy, part_tail).astype(jnp.int32)
    out_ref[...] = part.reshape(KEY_ROWS, KEY_LANES)


@functools.partial(jax.jit, static_argnames=("seed", "num_hosts", "interpret"))
def partition_apply(
    keys: jax.Array,  # int32[n], n % 256 == 0
    heavy_keys: jax.Array,  # int32[B] sorted, sentinel padded; B % 128 == 0
    heavy_parts: jax.Array,  # int32[B]
    host_to_part: jax.Array,  # int32[H]
    *,
    seed: int = 0,
    num_hosts: int = 4096,
    interpret: bool = True,
) -> jax.Array:
    n = keys.shape[0]
    blk = KEY_ROWS * KEY_LANES
    assert n % blk == 0, f"pad keys to a multiple of {blk}"
    assert num_hosts & (num_hosts - 1) == 0, "H must be a power of two"
    b = heavy_keys.shape[0]
    keys2d = keys.reshape(n // KEY_LANES, KEY_LANES)

    grid = (n // blk,)
    out = pl.pallas_call(
        functools.partial(_kernel, seed=seed, num_hosts=num_hosts),
        grid=grid,
        in_specs=[
            pl.BlockSpec((KEY_ROWS, KEY_LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, host_to_part.shape[0]), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((KEY_ROWS, KEY_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // KEY_LANES, KEY_LANES), jnp.int32),
        interpret=interpret,
    )(keys2d, heavy_keys[None, :], heavy_parts[None, :], host_to_part[None, :])
    return out.reshape(n)
