"""Per-partition keyed operator state (the stateful-reduce substrate).

State is a fixed-capacity sorted table per worker shard::

    keys   int32[S]    sorted ascending, KEY_SENTINEL padded
    values f32[S, D]   one state row per key

``merge_into`` folds a batch of (key, value) aggregates into the table with a
sort + segment-reduce (pure jnp, works inside jit / shard_map).  The reduce
op is configurable (``sum`` for counters, ``max``, ``last``) — ``sum`` is
what the paper's Flink experiment uses ("a reducer that simply stores a
count for each key as task state").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hashing import KEY_SENTINEL

__all__ = ["empty_state", "merge_into", "state_size"]


def empty_state(capacity: int, dim: int, dtype=jnp.float32):
    return (
        jnp.full((capacity,), KEY_SENTINEL, jnp.int32),
        jnp.zeros((capacity, dim), dtype),
    )


def merge_into(state_keys, state_vals, batch_keys, batch_vals, batch_valid, *, reduce: str = "sum"):
    """Fold batch aggregates into the sorted state table.

    Returns ``(keys, vals, overflowed)`` where ``overflowed`` counts distinct
    keys that did not fit in the table (capacity pressure — surfaced, never
    silent).
    """
    cap = state_keys.shape[0]
    bk = jnp.where(batch_valid, batch_keys.astype(jnp.int32), KEY_SENTINEL)
    bv = jnp.where(batch_valid[:, None], batch_vals, 0)

    all_keys = jnp.concatenate([state_keys, bk])
    all_vals = jnp.concatenate([state_vals, bv])
    order = jnp.argsort(all_keys)
    sk = all_keys[order]
    sv = all_vals[order]

    start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    seg = jnp.cumsum(start) - 1  # segment id per row
    m = all_keys.shape[0]
    seg_keys = jnp.full((m,), KEY_SENTINEL, jnp.int32).at[seg].min(sk)
    if reduce == "sum":
        seg_vals = jnp.zeros((m,) + sv.shape[1:], sv.dtype).at[seg].add(sv)
    elif reduce == "max":
        seg_vals = jnp.full((m,) + sv.shape[1:], -jnp.inf, sv.dtype).at[seg].max(sv)
        seg_vals = jnp.where(jnp.isfinite(seg_vals), seg_vals, 0)
    else:
        raise ValueError(f"unknown reduce {reduce!r}")

    # sentinel rows collapse into the final segment(s); valid segments first
    valid_seg = seg_keys != KEY_SENTINEL
    num_valid = jnp.sum(valid_seg)
    overflow = jnp.maximum(0, num_valid - cap)
    new_keys = seg_keys[:cap]
    new_vals = seg_vals[:cap]
    new_keys = jnp.where(new_keys == KEY_SENTINEL, KEY_SENTINEL, new_keys)
    return new_keys, new_vals, overflow


def state_size(state_keys) -> jax.Array:
    return jnp.sum(state_keys != KEY_SENTINEL)
