"""The unified exchange plane: ``route -> bucketize -> all_to_all -> unpack``.

The paper's DR module works because repartitioning "reuses normal DDPS
communication".  This module is that communication, implemented once: a
routed, capacity-padded all-to-all primitive shared by the micro-batch
shuffle (``repro.core.shuffle``), operator-state migration
(``make_migrate_step``) and MoE expert dispatch (``repro.moe.layer``).
Following Partial Key Grouping / AutoFlow, the routing+exchange primitive is
the pluggable unit; the balancing policy (KIP, KIP placement, migration
planning) layers on top and never touches collectives directly.

Vocabulary:

* **lane** — one destination of the exchange: a worker shard for an
  all-to-all, or a local bucket (e.g. an expert) for a pure dispatch.
* **slot** — a record's stable rank within its lane (``dispatch_count``),
  which makes the scatter into the ``[L, capacity]`` send buffer
  collision-free.
* **capacity** — static rows per lane.  XLA collectives need static shapes,
  so lanes are padded to ``capacity`` and anything beyond it is *counted*
  (never silently lost) in ``SendInfo.overflow``.

All functions are pure jnp and run inside ``jit`` / ``shard_map``.  The
routing hot path has a fused Pallas kernel
(``repro.kernels.lookup_dispatch``) with a bit-identical jnp twin; the twin
is the default off-TPU.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.partitioner import PartitionerTables
from repro.kernels import ref as kref

__all__ = [
    "ExchangeSpec",
    "Payload",
    "SendInfo",
    "ExchangeResult",
    "Exchange",
    "make_exchange",
    "route_dispatch",
    "take_from",
]


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """Static shape of one exchange: ``num_lanes`` destinations of
    ``capacity`` rows each, optionally crossed over mesh ``axis``.

    ``axis=None`` is a *local* exchange: records are bucketized into
    ``[num_lanes, capacity]`` buffers with no collective (MoE's second
    dispatch hop — per-expert batching on the receiving shard).
    """

    num_lanes: int
    capacity: int
    axis: str | None = None

    @property
    def rows(self) -> int:
        """Rows one exchange call ships per worker (``num_lanes * capacity``)
        — the static accounting unit the control plane's telemetry records
        per call (``Telemetry.record_exchange``), so policy cost models see
        what the plane actually provisions rather than a heuristic."""
        return self.num_lanes * self.capacity

    def resized(
        self, *, num_lanes: int | None = None, capacity: int | None = None
    ) -> "ExchangeSpec":
        """Re-derive the spec for a resized topology.

        Elastic resize (changing the lane count after a worker grow/shrink)
        and re-capacitating (a migration whose planned peak transfer differs
        from the last one) are both one-spec changes: everything downstream —
        bucketize buffers, the collective, unpack — follows from the spec.
        """
        return dataclasses.replace(
            self,
            num_lanes=self.num_lanes if num_lanes is None else int(num_lanes),
            capacity=self.capacity if capacity is None else int(capacity),
        )


class Payload(NamedTuple):
    """One array travelling through the exchange; ``fill`` pads empty slots."""

    data: jax.Array  # [n, ...] one row per record
    fill: int | float = 0


class SendInfo(NamedTuple):
    """Send-side bookkeeping — enough to reverse the exchange.

    ``take_from(buffers, send)`` gathers each record's row back out of
    lane-major buffers (the MoE combine / any request-response pattern).
    """

    lane: jax.Array      # int32[n] destination lane per record
    slot: jax.Array      # int32[n] rank within lane, -1 for invalid
    ok: jax.Array        # bool[n]  accepted into the send buffer
    overflow: jax.Array  # int32[]  local records dropped for capacity


class ExchangeResult(NamedTuple):
    valid: jax.Array     # bool[L, capacity] occupancy of the (received) buffer
    payloads: tuple      # each [L, capacity, ...], same order as the inputs
    send: SendInfo

    def unpack(self):
        """Flatten lane-major buffers to record-major ``[L*capacity, ...]``."""
        l, c = self.valid.shape
        flat = tuple(p.reshape((l * c,) + p.shape[2:]) for p in self.payloads)
        return self.valid.reshape(-1), flat


def take_from(buffers: jax.Array, send: SendInfo) -> jax.Array:
    """Gather each record's row from ``[L, capacity, ...]`` buffers, zeroing
    records that never made it into a slot (the reverse of ``bucketize``)."""
    rows = buffers[send.lane, jnp.where(send.ok, send.slot, 0)]
    mask = send.ok.reshape(send.ok.shape + (1,) * (rows.ndim - 1))
    return jnp.where(mask, rows, 0)


def route_dispatch(
    tables: PartitionerTables,
    keys: jax.Array,
    valid: jax.Array,
    *,
    num_hosts: int,
    seed: int,
    num_lanes: int,
    use_pallas: bool | None = None,
):
    """Fused key -> partition lookup + lane slot assignment.

    Returns ``(part[n], slot[n])`` where ``slot`` ranks each valid record
    within its ``part % num_lanes`` lane.  On TPU this is one fused Pallas
    kernel (``repro.kernels.lookup_dispatch``); elsewhere the bit-identical
    jnp twin.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from repro.kernels import ops

        part, slot, _ = ops.route_slots(
            keys, valid, tables, num_hosts=num_hosts, seed=seed, num_lanes=num_lanes
        )
    else:
        part, slot, _ = kref.lookup_dispatch_ref(
            keys, valid, tables.heavy_keys, tables.heavy_parts, tables.host_to_part,
            seed=seed, num_hosts=num_hosts, num_lanes=num_lanes,
        )
    return part, slot


class Exchange:
    """The exchange primitive bound to one :class:`ExchangeSpec`.

    Calling it runs the full ``bucketize -> all_to_all -> unpack`` sequence;
    ``bucketize`` alone builds the lane-major send buffers (local dispatch),
    and ``backhaul`` runs the reverse collective for request-response
    patterns (MoE combine).
    """

    def __init__(self, spec: ExchangeSpec):
        self.spec = spec

    # -- step 2: capacity-padded send-buffer builder -----------------------
    def bucketize(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
    ) -> ExchangeResult:
        """Scatter records into ``[L, capacity]`` buffers; count overflow.

        ``slot`` may be precomputed (e.g. by the fused route kernel);
        otherwise it is derived with ``dispatch_count``.
        """
        spec = self.spec
        lane = jnp.where(valid, lane, 0).astype(jnp.int32)
        if slot is None:
            slot, _ = kref.dispatch_count_ref(lane, valid, num_parts=spec.num_lanes)
        # a valid record is lost either to a full lane or to a lane outside
        # [0, num_lanes) — both are counted, never silently dropped
        in_range = (lane >= 0) & (lane < spec.num_lanes)
        ok = valid & in_range & (slot >= 0) & (slot < spec.capacity)
        overflow = jnp.sum(valid & (~in_range | (slot >= spec.capacity))).astype(jnp.int32)
        # rows without a slot land at column `capacity` and are dropped by
        # the out-of-range scatter (mode='drop') — counted above, never lost
        # silently.
        s = jnp.where(ok, slot, spec.capacity)
        shape = (spec.num_lanes, spec.capacity)
        buf_valid = jnp.zeros(shape, bool).at[lane, s].set(ok, mode="drop")
        bufs = tuple(
            jnp.full(shape + p.data.shape[1:], p.fill, p.data.dtype)
            .at[lane, s].set(p.data, mode="drop")
            for p in payloads
        )
        return ExchangeResult(buf_valid, bufs, SendInfo(lane, slot, ok, overflow))

    # -- step 3: the collective -------------------------------------------
    def all_to_all(self, buffers: ExchangeResult) -> ExchangeResult:
        """Exchange lane-major buffers across ``spec.axis`` (row j -> shard j)."""
        if self.spec.axis is None:
            return buffers
        a2a = lambda b: jax.lax.all_to_all(b, self.spec.axis, 0, 0, tiled=True)
        return ExchangeResult(
            a2a(buffers.valid), tuple(a2a(b) for b in buffers.payloads), buffers.send
        )

    def backhaul(self, buffers: jax.Array) -> jax.Array:
        """Reverse collective for already-laned response buffers."""
        if self.spec.axis is None:
            return buffers
        return jax.lax.all_to_all(buffers, self.spec.axis, 0, 0, tiled=True)

    # -- the full primitive ------------------------------------------------
    def __call__(
        self,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
    ) -> ExchangeResult:
        return self.all_to_all(self.bucketize(lane, valid, payloads, slot=slot))


def make_exchange(spec: ExchangeSpec) -> Exchange:
    """Build the exchange primitive for one static spec."""
    return Exchange(spec)
