"""Baseline partitioners (Readj/Redist/Scan/Mixed) sanity + ordering tests."""
import numpy as np
import pytest

from repro.core import (
    Histogram,
    kip_update,
    load_imbalance,
    make_baseline,
    plan_migration,
    uniform_partitioner,
)
from repro.data.generators import drifting_zipf, zipf_keys

NAMES = ["readj", "redist", "scan", "mixed"]


@pytest.mark.parametrize("name", NAMES)
def test_total_function(name):
    update, prev = make_baseline(name, 16)
    stream = zipf_keys(100_000, num_keys=10_000, exponent=1.1, seed=0)
    hist = Histogram.exact(stream).top(32)
    part = update(prev, hist, 16)
    parts = part.lookup_np(stream.astype(np.int32))
    assert parts.min() >= 0 and parts.max() < 16


@pytest.mark.parametrize("name", NAMES)
def test_improves_over_hash(name):
    n = 16
    update, prev = make_baseline(name, n)
    stream = zipf_keys(200_000, num_keys=50_000, exponent=1.2, seed=1)
    hist = Histogram.exact(stream).top(2 * n)
    part = update(prev, hist, n)
    assert load_imbalance(part, stream) <= load_imbalance(prev, stream) + 1e-9


def test_kip_beats_baselines_on_drift():
    """Fig 3 headline: over a drifting stream KIP's average imbalance beats
    Scan and Readj, and its migration is far below Readj-style rebuilds."""
    n = 20
    results = {}
    for name in ["scan", "readj", "kip"]:
        if name == "kip":
            update, part = (lambda prev, hist, n=n: kip_update(prev, hist, n)), uniform_partitioner(n)
        else:
            update, part = make_baseline(name, n)
        imb, mig = [], []
        live = None
        for batch in drifting_zipf(12, 50_000, num_keys=5_000, exponent=1.0, seed=7):
            hist = Histogram.exact(batch).top(2 * n)
            new = update(part, hist, n)
            live = np.unique(batch)
            mig.append(plan_migration(part, new, live).relative_migration)
            part = new
            imb.append(load_imbalance(part, batch))
        results[name] = (float(np.mean(imb[1:])), float(np.mean(mig[1:])))
    assert results["kip"][0] <= results["scan"][0] + 0.05
    assert results["kip"][0] <= results["readj"][0] + 0.05


def test_redist_migrates_more_than_scan():
    """On a gradually drifting stream, sticky Scan moves less state than
    rebuild-from-scratch Redist (Gedik's trade-off, paper Fig. 3)."""
    n = 16
    mig = {}
    for strat in ["redist", "scan"]:
        update, part = make_baseline(strat, n)
        total = []
        for batch in drifting_zipf(8, 50_000, num_keys=5_000, exponent=1.0,
                                   drift_every=3, drift_fraction=0.2, seed=5):
            hist = Histogram.exact(batch).top(2 * n)
            new = update(part, hist, n)
            total.append(plan_migration(part, new, np.unique(batch)).relative_migration)
            part = new
        mig[strat] = float(np.mean(total[1:]))
    assert mig["scan"] <= mig["redist"] + 1e-9, mig
