"""Exchange backends: the *how* of a routed exchange.

An :class:`ExchangeBackend` implements the four verbs of the plane —
``bucketize`` / ``all_to_all`` / ``backhaul`` / ``cost`` — against one
:class:`~repro.exchange.spec.ExchangeSpec`.  Three transports ship:

* :class:`DenseBackend` — the capacity-padded all-to-all: every lane is
  padded to ``spec.capacity`` and the collective moves the whole
  ``[L, capacity]`` buffer.  Simple, one device round, and the worst case
  under skew: every consumer ships ``L * capacity`` rows even when the
  observed key distribution leaves most lanes nearly empty.
* :class:`RaggedBackend` — the count-first two-phase exchange: phase 1
  all-to-alls the per-lane *counts* (one int per lane), phase 2 ships
  row-compacted lanes sized by the measured occupancy, so traffic tracks
  real rows instead of padding (Partial Key Grouping's bounded per-worker
  load, AutoFlow's load-adapted routing).  On this build the row phase
  rides the dense collective (jax < 0.5 has no ``ragged_all_to_all``;
  ``_ship`` is the one seam a ragged/NCCL collective slots into) with the
  receive buffer masked to the exchanged counts, so results are
  bit-identical to dense while ``shipped_rows`` reports what a ragged
  transport would actually move.
* :class:`LocalBackend` — the ``axis=None`` single-host fast path: pure
  bucketize, no collective, zero shipped rows.

``cost(spec, plan_rows)`` is each backend's sizing rule on a candidate
migration plan — what the control plane's
:func:`repro.core.migration.exchange_lane_cost` evaluates so
``RepartitionPolicy`` prices a repartition by what the *active* transport
would move: the dense rule pads every lane to the peak, the ragged rule
averages real rows over the lanes, a local exchange is free.

All device code is pure jnp and runs inside ``jit`` / ``shard_map``.
Backends are stateless; one instance may serve any number of specs.
"""
from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.exchange.spec import ExchangeResult, ExchangeSpec, Payload, SendInfo
from repro.kernels import ref as kref

__all__ = [
    "ExchangeBackend",
    "DenseBackend",
    "RaggedBackend",
    "LocalBackend",
    "resolve_backend",
    "backend_name",
]


@runtime_checkable
class ExchangeBackend(Protocol):
    """The four verbs every exchange transport implements."""

    name: str

    def bucketize(
        self,
        spec: ExchangeSpec,
        lane: jax.Array,
        valid: jax.Array,
        payloads: Sequence[Payload],
        slot: jax.Array | None = None,
    ) -> ExchangeResult: ...

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult: ...

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array) -> jax.Array: ...

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float: ...


def _bucketize(
    spec: ExchangeSpec,
    lane: jax.Array,
    valid: jax.Array,
    payloads: Sequence[Payload],
    slot: jax.Array | None = None,
) -> ExchangeResult:
    """Scatter records into ``[L, capacity]`` buffers; count overflow.

    Shared by every backend — the send-side layout is transport-independent
    (a backend that wanted a different layout would override).  ``slot`` may
    be precomputed (e.g. by the fused route kernel); otherwise it is derived
    with ``dispatch_count``.
    """
    lane = jnp.where(valid, lane, 0).astype(jnp.int32)
    if slot is None:
        slot, _ = kref.dispatch_count_ref(lane, valid, num_parts=spec.num_lanes)
    # a valid record is lost either to a full lane or to a lane outside
    # [0, num_lanes) — both are counted, never silently dropped
    in_range = (lane >= 0) & (lane < spec.num_lanes)
    ok = valid & in_range & (slot >= 0) & (slot < spec.capacity)
    overflow = jnp.sum(valid & (~in_range | (slot >= spec.capacity))).astype(jnp.int32)
    # per-lane view of the capacity drops: which lane filled up (out-of-range
    # records have no lane to charge — they count in the scalar only)
    lane_overflow = (
        jnp.zeros(spec.num_lanes, jnp.int32)
        .at[lane]
        .add((valid & in_range & (slot >= spec.capacity)).astype(jnp.int32), mode="drop")
    )
    # rows without a slot land at column `capacity` and are dropped by
    # the out-of-range scatter (mode='drop') — counted above, never lost
    # silently.
    s = jnp.where(ok, slot, spec.capacity)
    shape = (spec.num_lanes, spec.capacity)
    buf_valid = jnp.zeros(shape, bool).at[lane, s].set(ok, mode="drop")
    bufs = tuple(
        jnp.full(shape + p.data.shape[1:], p.fill, p.data.dtype)
        .at[lane, s].set(p.data, mode="drop")
        for p in payloads
    )
    return ExchangeResult(
        buf_valid, bufs, SendInfo(lane, slot, ok, overflow, lane_overflow),
        shipped_rows=jnp.zeros((), jnp.int32),
    )


def _a2a(x: jax.Array, axis: str) -> jax.Array:
    """Tiled all-to-all over ``axis``: row j of the leading dim -> shard j."""
    return jax.lax.all_to_all(x, axis, 0, 0, tiled=True)


class DenseBackend:
    """The capacity-padded transport (the pre-backend exchange, verbatim)."""

    name = "dense"

    def bucketize(self, spec, lane, valid, payloads, slot=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot)

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        """Exchange lane-major buffers across ``spec.axis`` (row j -> shard j)."""
        if spec.axis is None:
            return buffers
        return ExchangeResult(
            _a2a(buffers.valid, spec.axis),
            tuple(_a2a(b, spec.axis) for b in buffers.payloads),
            buffers.send,
            shipped_rows=jnp.asarray(spec.rows, jnp.int32),  # the whole pad
        )

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array) -> jax.Array:
        """Reverse collective for already-laned response buffers."""
        if spec.axis is None:
            return buffers
        return _a2a(buffers, spec.axis)

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        """Every lane provisions (and ships) the peak planned lane mass."""
        plan_rows = np.asarray(plan_rows, np.float64)
        if plan_rows.size == 0:
            return 0.0
        return float(plan_rows.max()) * slack


class RaggedBackend:
    """Count-first two-phase transport: ship counts, then compacted rows."""

    name = "ragged"

    def bucketize(self, spec, lane, valid, payloads, slot=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot)

    def _ship(self, spec: ExchangeSpec, buffers: ExchangeResult,
              recv_counts: jax.Array) -> ExchangeResult:
        """Phase 2: move the rows.  On this transport the row phase rides the
        dense collective and the receive buffer is masked to the exchanged
        counts — a ``ragged_all_to_all`` / NCCL path replaces exactly this
        method, everything else (count phase, accounting, consumers) holds.
        """
        live = jnp.arange(spec.capacity, dtype=jnp.int32)[None, :] < recv_counts[:, None]
        valid = _a2a(buffers.valid, spec.axis) & live
        return ExchangeResult(
            valid, tuple(_a2a(b, spec.axis) for b in buffers.payloads), buffers.send,
            shipped_rows=buffers.shipped_rows,
        )

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        if spec.axis is None:
            return buffers
        # phase 1: exchange per-lane occupancy (one int32 per lane) so every
        # receiver knows how many rows each peer actually sends
        counts = jnp.sum(buffers.valid, axis=1, dtype=jnp.int32)  # [L] sent per lane
        recv_counts = _a2a(counts, spec.axis)
        # measured traffic: the rows this worker's lanes actually hold plus
        # the count phase itself (one row-equivalent per lane, conservatively)
        shipped = (jnp.sum(counts) + spec.num_lanes).astype(jnp.int32)
        return self._ship(
            spec, buffers._replace(shipped_rows=shipped), recv_counts
        )

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array) -> jax.Array:
        """Response rows ride the request lanes back; their occupancy was
        fixed by the forward hop, so the return trip needs no second count
        phase — it ships dense on this transport."""
        if spec.axis is None:
            return buffers
        return _a2a(buffers, spec.axis)

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        """A ragged transport moves real rows: the per-lane *average* planned
        mass (empty lanes are free), never more than the dense peak."""
        plan_rows = np.asarray(plan_rows, np.float64)
        if plan_rows.size == 0:
            return 0.0
        return float(plan_rows.sum()) / plan_rows.size * slack


class LocalBackend:
    """``axis=None`` fast path: bucketize only, no collective, nothing ships."""

    name = "local"

    def bucketize(self, spec, lane, valid, payloads, slot=None):
        return _bucketize(spec, lane, valid, payloads, slot=slot)

    def all_to_all(self, spec: ExchangeSpec, buffers: ExchangeResult) -> ExchangeResult:
        assert spec.axis is None, (
            f"LocalBackend cannot cross mesh axis {spec.axis!r}; "
            "use the dense or ragged backend"
        )
        return buffers

    def backhaul(self, spec: ExchangeSpec, buffers: jax.Array) -> jax.Array:
        assert spec.axis is None, spec.axis
        return buffers

    def cost(self, spec: ExchangeSpec | None, plan_rows: np.ndarray,
             slack: float = 1.25) -> float:
        return 0.0


_BACKENDS = {
    "dense": DenseBackend,
    "ragged": RaggedBackend,
    "local": LocalBackend,
}


def resolve_backend(
    backend: str | ExchangeBackend | None, spec: ExchangeSpec | None = None
) -> ExchangeBackend:
    """Turn a backend name (or instance, or ``None``) into an instance.

    ``None`` auto-selects: the local fast path when the spec has no mesh
    axis, otherwise dense — the pre-backend behavior, bit-identical.
    """
    if backend is None:
        return LocalBackend() if spec is not None and spec.axis is None else DenseBackend()
    if isinstance(backend, str):
        try:
            return _BACKENDS[backend]()
        except KeyError:
            raise ValueError(
                f"unknown exchange backend {backend!r}; have {sorted(_BACKENDS)}"
            ) from None
    return backend


def backend_name(backend: str | ExchangeBackend | None) -> str:
    """Stable display/cache name for a backend selection (``None`` = auto)."""
    if backend is None:
        return "auto"
    if isinstance(backend, str):
        return backend
    return getattr(backend, "name", type(backend).__name__)
