"""DR-based request routing across serving replicas.

Serving-side instance of the paper's mapping: requests carry a *session
key* (user / document / host — the paper's §6 partitions crawl output by
web host); replicas are partitions; the per-session KV cache is operator
state.  Session keys are heavy-tailed (hot documents / hot tenants), so
UHP routing makes some replicas stragglers.  The scheduler runs the same
DRM loop: counter-sketch over observed session keys, KIPUPDATE at decision
points, and session (cache) migration costed against the expected balance
gain.

Replicas here are modeled objects (queue depths), keeping the scheduler
testable without spinning 16 engines; ``ServeEngine`` is the per-replica
execution unit.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.drm import DRConfig, DRMaster
from repro.core.hashing import DEFAULT_NUM_HOSTS
from repro.core.partitioner import uniform_partitioner

__all__ = ["ReplicaState", "DRScheduler"]


@dataclasses.dataclass
class ReplicaState:
    rid: int
    queued_tokens: float = 0.0      # outstanding work
    sessions: set = dataclasses.field(default_factory=set)


class DRScheduler:
    def __init__(self, num_replicas: int, *, dr: DRConfig | None = None, seed: int = 0,
                 migration_token_cost: float = 64.0):
        self.replicas = [ReplicaState(i) for i in range(num_replicas)]
        cfg = dr or DRConfig(lam=4.0, imbalance_trigger=1.25)
        heavy_cap = int(np.ceil(max(1.0, cfg.lam * num_replicas) / 128.0) * 128)
        init = uniform_partitioner(num_replicas, DEFAULT_NUM_HOSTS, seed,
                                   heavy_capacity=heavy_cap)
        self.drm = DRMaster(init, cfg)
        self.migration_token_cost = migration_token_cost
        self.migrations = 0
        self.routed = 0

    # -- hot path ---------------------------------------------------------
    def route(self, session_key: int, cost_tokens: float) -> int:
        """Assign a request to a replica; account its load."""
        r = int(self.drm.partitioner.lookup_np(np.asarray([session_key], np.int32))[0])
        rep = self.replicas[r]
        rep.queued_tokens += cost_tokens
        rep.sessions.add(session_key)
        self.routed += 1
        return r

    def drain(self, tokens_per_replica: float) -> None:
        """Simulate service: each replica completes up to N tokens."""
        for rep in self.replicas:
            rep.queued_tokens = max(0.0, rep.queued_tokens - tokens_per_replica)

    # -- safe point: observe + maybe repartition --------------------------
    def checkpoint(self, window_keys: np.ndarray) -> dict:
        keys, counts = np.unique(np.asarray(window_keys, np.int64), return_counts=True)
        self.drm.observe(keys.reshape(1, -1), counts.reshape(1, -1))
        loads = np.array([r.queued_tokens for r in self.replicas])
        before = self.drm.partitioner
        decision = self.drm.decide(loads + 1e-9)
        moved_sessions = 0
        if decision.repartition:
            new = self.drm.partitioner
            for rep in self.replicas:
                stay = set()
                for s in rep.sessions:
                    dst = int(new.lookup_np(np.asarray([s], np.int32))[0])
                    if dst != rep.rid:
                        # migrate the session's KV cache
                        self.replicas[dst].sessions.add(s)
                        self.replicas[dst].queued_tokens += self.migration_token_cost
                        moved_sessions += 1
                    else:
                        stay.add(s)
                rep.sessions = stay
            self.migrations += moved_sessions
        return {
            "repartitioned": decision.repartition,
            "imbalance": decision.measured_imbalance,
            "moved_sessions": moved_sessions,
        }

    def imbalance(self) -> float:
        loads = np.array([r.queued_tokens for r in self.replicas])
        return float(loads.max() / max(loads.mean(), 1e-9))
