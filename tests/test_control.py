"""Control plane: signals, policy stack, cooldown guard, decision log.

Covers the tentpole wiring (all three consumers route through
``repro.control``) plus the oscillation-guard and throughput-shrink
policy rules, at both the unit (synthetic ``Signals``) and end-to-end
(``StreamingJob`` on a sawtooth workload) level.
"""
import numpy as np
import pytest

from repro.exchange import ExchangeStats
from repro.control import (
    NoOp,
    Repartition,
    Replace,
    Resize,
    Signals,
    SwitchBackend,
    Telemetry,
)
from repro.core.drm import DRConfig, DRMaster
from repro.core.migration import (
    exchange_lane_cost,
    fold_to_workers,
    migration_capacity,
    plan_migration,
)
from repro.core.partitioner import uniform_partitioner
from repro.core.streaming import StreamingJob
from repro.exchange import resolve_backend
from repro.data.generators import sawtooth_skew
from repro.moe.kip_placement import PlacementController
from repro.serve.scheduler import DRScheduler

HOT = np.array([10.0, 1.0, 1.0, 1.0])
FLAT = np.array([1.0, 1.0, 1.0, 1.0])


def _warm_drm(cfg=None, n=4) -> DRMaster:
    """DRM with a skewed sketch so the repartition policy has a histogram.

    ``total_records`` is double the summary mass, so half the traffic is
    untracked tail riding the host tables — the cost model must account it
    when hosts are re-binned."""
    drm = DRMaster(uniform_partitioner(n, heavy_capacity=128), cfg or DRConfig())
    keys = np.arange(8, dtype=np.int64)
    counts = np.array([400.0, 100, 50, 25, 12, 6, 3, 1])
    drm.observe(keys[None], counts[None], total_records=2.0 * float(counts.sum()))
    return drm


# ---------------------------------------------------------------------------
# signals + telemetry
# ---------------------------------------------------------------------------


def test_signals_derived_metrics():
    s = Signals(loads=np.array([4.0, 2, 1, 1]), num_workers=2,
                records=600.0, window_wall_s=2.0)
    assert s.imbalance == pytest.approx(2.0)
    np.testing.assert_allclose(s.worker_loads, [5.0, 3.0])  # p % 2 folding
    assert s.worker_imbalance == pytest.approx(1.25)
    assert s.throughput == pytest.approx(300.0)
    assert s.per_worker_throughput == pytest.approx(150.0)
    empty = Signals(loads=np.zeros(4))
    assert empty.imbalance == 1.0 and empty.throughput == 0.0


def test_telemetry_window_accumulates_until_safe_point():
    t = Telemetry("stream")
    t.record_batch(100)
    t.record_exchange(ExchangeStats(rows=64, wall_s=0.5))
    peek = t.snapshot(loads=FLAT, at_safe_point=False)  # peek: no reset
    t.record_batch(100)
    t.record_overflow(shuffle=3, migration=2)
    s = t.snapshot(loads=FLAT, num_workers=2, state_rows=7)
    assert peek.records == 100 and s.records == 200  # window spanned both
    assert s.exchange_rows == 64 and s.exchange_wall_s == pytest.approx(0.5)
    assert s.shuffle_overflow == 3 and s.migration_overflow == 2
    assert s.state_rows == 7 and s.consumer == "stream"
    fresh = t.snapshot(loads=FLAT)  # the safe point reset the window
    assert fresh.records == 0 and fresh.exchange_rows == 0


def test_fold_to_workers_vector_and_matrix():
    loads = np.array([5.0, 1, 2, 3, 4, 6])
    np.testing.assert_allclose(fold_to_workers(loads, 2), [11.0, 10.0])
    m = np.zeros((4, 4))
    m[0, 3] = 5.0  # worker 0 -> worker 1
    m[2, 0] = 2.0  # worker 0 -> worker 0 (same worker after folding)
    folded = fold_to_workers(m, 2)
    assert folded[0, 1] == 5.0 and folded[0, 0] == 2.0


def test_exchange_lane_cost_matches_capacity_rule():
    """The policy's cost estimate is migration_capacity's sizing rule minus
    the row quantization — same fold, same slack, same peak."""
    old = uniform_partitioner(4, seed=0)
    new = uniform_partitioner(4, seed=3)
    live = np.arange(512, dtype=np.int64)
    plan = plan_migration(old, new, live)
    cost = exchange_lane_cost(plan, num_workers=2)
    cap = migration_capacity(plan, num_workers=2)
    assert cost > 0
    assert cap == max(8, int(np.ceil(cost / 8.0) * 8))
    # unfolded (unknown workers): partition-level lanes are the unit — the
    # peak of finer lanes can only be <= the worker-folded aggregate's
    assert 0 < exchange_lane_cost(plan) <= cost


# ---------------------------------------------------------------------------
# the policy stack through DRMaster.evaluate
# ---------------------------------------------------------------------------


def test_evaluate_not_safe_point_declines_without_logging():
    drm = _warm_drm()
    a = drm.evaluate(Signals(loads=HOT, at_safe_point=False))
    assert isinstance(a, NoOp) and a.reason == "not-checkpoint-tick"
    # a peek is not a decision: the log counts safe points only
    assert len(drm.decisions) == 0 and drm.decisions.counts() == (0, 0)


def test_decision_log_bounded_with_exact_counts():
    drm = _warm_drm(DRConfig())
    drm.decisions.max_records = 16
    for _ in range(40):
        drm.evaluate(Signals(loads=FLAT), policies_enabled=False)
    assert len(drm.decisions.records) == 16  # trimmed ...
    assert drm.decisions.counts() == (0, 40)  # ... counters stay cumulative


def test_evaluate_requested_resize_wins():
    drm = _warm_drm(DRConfig(elastic=True))
    a = drm.evaluate(Signals(loads=HOT), requested_resize=8)
    assert isinstance(a, Resize) and a.target == 8 and a.requested
    assert a.reason == "resize 4->8"
    # a request equal to the current topology falls through to the policies
    a2 = drm.evaluate(Signals(loads=FLAT), requested_resize=4)
    assert isinstance(a2, NoOp)


def test_evaluate_disabled_policies_noop():
    drm = _warm_drm()
    a = drm.evaluate(Signals(loads=HOT), policies_enabled=False)
    assert isinstance(a, NoOp) and a.reason == "dr-disabled"
    assert drm.batches_seen == 0  # nothing advanced: no policy ran


def test_evaluate_repartition_installs_and_logs():
    drm = _warm_drm(DRConfig(imbalance_trigger=1.05, migration_cost_weight=0.0))
    before = drm.partitioner
    a = drm.evaluate(Signals(loads=np.array([500.0, 30, 30, 37])))
    assert isinstance(a, Repartition)
    assert drm.partitioner is a.partitioner and a.prev is before
    assert a.est_migration > 0  # exchange-lane accounting, not zero
    taken, declined = drm.decisions.counts()
    assert (taken, declined) == (1, 0)


def test_cost_model_blocks_expensive_migration():
    drm = _warm_drm(DRConfig(imbalance_trigger=1.05, migration_cost_weight=1e9))
    a = drm.evaluate(Signals(loads=np.array([500.0, 30, 30, 37])))
    assert isinstance(a, NoOp) and a.reason.startswith("gain ")
    assert a.est_migration > 0  # the declined cost is recorded too


# ---------------------------------------------------------------------------
# oscillation guard (cooldown) + throughput shrink
# ---------------------------------------------------------------------------


def _sawtooth_cfg(cooldown: int) -> DRConfig:
    return DRConfig(elastic=True, min_partitions=4, max_partitions=8,
                    grow_trigger=1.5, shrink_trigger=1.05, resize_patience=1,
                    resize_cooldown=cooldown, imbalance_trigger=1e9)


def _drive_sawtooth(drm: DRMaster, ticks: int = 12) -> list[int]:
    """Alternate hot/flat loads through the full stack; execute resizes the
    way a driver would (replan at the safe point).  Returns topology sizes."""
    sizes = []
    for t in range(ticks):
        loads = HOT if (t // 2) % 2 == 0 else FLAT
        loads = np.resize(loads, drm.partitioner.num_partitions)
        a = drm.evaluate(Signals(loads=loads))
        if isinstance(a, Resize):
            drm.replan_resize(a.target)
            sizes.append(a.target)
    return sizes


def test_cooldown_guard_stops_pingpong():
    # without the guard the sawtooth ping-pongs the partition count
    sizes = _drive_sawtooth(DRMaster(uniform_partitioner(4), _sawtooth_cfg(0)))
    dirs = [s > p for s, p in zip(sizes, [4] + sizes[:-1])]
    assert sum(1 for a, b in zip(dirs, dirs[1:]) if a != b) >= 2, sizes
    # with it on: the initial grow fires, everything after is declined
    drm = DRMaster(uniform_partitioner(4), _sawtooth_cfg(100))
    sizes = _drive_sawtooth(drm)
    assert sizes == [8]
    declined = [d for d in drm.decisions.records
                if d.detail.get("resize_declined") == "resize-cooldown"]
    assert declined, "cooldown declines must be observable in the log"


def test_cooldown_expiry_allows_followup_resize():
    drm = DRMaster(uniform_partitioner(4), _sawtooth_cfg(3))
    assert _drive_sawtooth(drm, ticks=2) == [8]   # grow at tick 0
    # flat ticks inside the cooldown: declined; after expiry: shrink fires
    sizes = _drive_sawtooth(drm, ticks=2)  # ticks are hot again: at-max
    for _ in range(6):
        a = drm.evaluate(Signals(loads=np.resize(FLAT, 8)))
        if isinstance(a, Resize):
            drm.replan_resize(a.target)
            assert a.target == 4
            return
    raise AssertionError("shrink never fired after cooldown expiry")


def test_throughput_below_target_shrinks_when_balanced():
    """An idle stream in the trigger dead zone (imbalance can't shrink it)
    still shrinks on the capacity-target signal."""
    cfg = DRConfig(elastic=True, min_partitions=2, max_partitions=16,
                   grow_trigger=1.5, shrink_trigger=0.9,  # imb >= 1 always:
                   resize_patience=2, target_throughput=1000.0)  # unreachable
    drm = DRMaster(uniform_partitioner(4), cfg)
    idle = Signals(loads=np.array([1.2, 1.0, 1.0, 1.0]),  # dead zone
                   records=100.0, window_wall_s=1.0)      # 100 rec/s << 1000
    assert isinstance(drm.resize_policy.evaluate(drm, idle), NoOp)  # patience 1/2
    a = drm.resize_policy.evaluate(drm, idle)
    assert isinstance(a, Resize) and a.target == 2
    # same loads at a healthy throughput: dead zone holds, no shrink
    drm2 = DRMaster(uniform_partitioner(4), cfg)
    busy = Signals(loads=np.array([1.2, 1.0, 1.0, 1.0]),
                   records=10_000.0, window_wall_s=1.0)
    assert isinstance(drm2.resize_policy.evaluate(drm2, busy), NoOp)
    a2 = drm2.resize_policy.evaluate(drm2, busy)
    assert isinstance(a2, NoOp) and a2.reason == "dead-zone"


def test_low_throughput_never_shrinks_a_hotspot():
    """Idle + hot-spotted at max_partitions must not shrink: fewer bins
    would concentrate the hotspot further.  The throughput shrink covers
    the trigger dead zone only."""
    cfg = DRConfig(elastic=True, min_partitions=2, max_partitions=4,
                   grow_trigger=1.5, shrink_trigger=1.05,
                   resize_patience=1, target_throughput=1000.0)
    drm = DRMaster(uniform_partitioner(4), cfg)  # n == max_partitions
    hot_idle = Signals(loads=np.array([100.0, 1.0, 1.0, 1.0]),
                       records=10.0, window_wall_s=1.0)  # 10 rec/s << 1000
    for _ in range(4):
        a = drm.resize_policy.evaluate(drm, hot_idle)
        assert isinstance(a, NoOp) and a.reason == "at-max", a


def test_scheduler_policy_scale_in_on_idle_replicas():
    """Sustained balanced (idle) queues shrink the replica set through the
    checkpoint policy path — scale-in must not be floored at the current
    replica count."""
    sched = DRScheduler(4, dr=DRConfig(lam=4.0, elastic=True, min_partitions=2,
                                       max_partitions=8, grow_trigger=1.5,
                                       shrink_trigger=1.2, resize_patience=2,
                                       imbalance_trigger=1e9))
    rng = np.random.default_rng(2)
    results = []
    for _ in range(3):
        window = rng.integers(0, 10_000, 512)  # uniform sessions: balanced
        for s in window:
            sched.route(int(s), 1.0)
        results.append(sched.checkpoint(window))
        sched.drain(1e9)  # fully idle between windows
    assert any(r["resized"] for r in results), results
    assert len(sched.replicas) == 2


def test_replan_resize_rewarns_sketch_before_growing():
    """A grow widens the heavy-key budget (lam * n); stale floor-dominated
    sketch entries must not surface in the resized heavy table."""
    drm = DRMaster(uniform_partitioner(4, heavy_capacity=128),
                   DRConfig(lam=2.0, sketch_capacity=8, sketch_decay=1.0))
    heavy = np.arange(4, dtype=np.int64)
    drm.observe(heavy[None], np.full((1, 4), 500.0))
    for k in range(100, 140):  # one-off parade: evictions raise the floor
        drm.observe(np.array([[k]], dtype=np.int64), np.array([[1.0]]))
    assert drm.sketch._floor > 0
    stale = set(drm.sketch.histogram().keys.tolist()) - set(heavy.tolist())
    assert stale  # the un-rescaled window would read into these
    new = drm.replan_resize(8)  # top_b jumps 8 -> 16
    isolated = set(new.heavy_keys[new.heavy_keys >= 0].tolist())
    assert isolated & set(heavy.tolist())
    assert not (isolated & stale), isolated & stale


def test_trigger_gap_dead_zone_enforced():
    # validated unconditionally now: an inverted dead zone is wrong even
    # while the feature flag is off
    with pytest.raises(ValueError):
        DRConfig(elastic=True, grow_trigger=1.2, shrink_trigger=1.3)
    with pytest.raises(ValueError):
        DRConfig(grow_trigger=1.2, shrink_trigger=1.3)


# ---------------------------------------------------------------------------
# end-to-end: StreamingJob sawtooth through the full runtime
# ---------------------------------------------------------------------------


def _reversals(sizes: list[int], start: int = 4) -> int:
    dirs = [s > p for s, p in zip(sizes, [start] + sizes[:-1])]
    return sum(1 for a, b in zip(dirs, dirs[1:]) if a != b)


def test_streaming_sawtooth_no_pingpong_with_guard():
    """End-to-end oscillation guard: plain DR rebalances contents during the
    flat phase (so the measured imbalance genuinely flips across the
    triggers), and the elastic policy ping-pongs the partition count unless
    the cooldown guard is on."""
    def run(cooldown):
        job = StreamingJob(
            num_partitions=4, state_capacity=8192,
            dr=DRConfig(elastic=True, min_partitions=4, max_partitions=8,
                        grow_trigger=2.0, shrink_trigger=1.45,
                        resize_patience=1, resize_cooldown=cooldown,
                        imbalance_trigger=1.3, migration_cost_weight=0.05,
                        sketch_decay=0.5),
        )
        ms = job.run(sawtooth_skew(12, 4096, num_keys=2_000, exponent=1.8,
                                   period=3, seed=7))
        return job, [m.num_partitions for m in ms if m.resized]

    job_off, sizes_off = run(cooldown=0)
    assert len(sizes_off) >= 2 and _reversals(sizes_off) >= 2, sizes_off
    job, sizes = run(cooldown=100)
    assert sizes == [8], sizes  # grow-under-skew fires once, never reverses
    assert _reversals(sizes) == 0
    declined = [d for d in job.drm.decisions.records
                if d.detail.get("resize_declined") == "resize-cooldown"]
    assert declined, "cooldown declines must be observable in the log"
    # BatchMetrics reads the decision log's action/reason
    first = [m for m in job.metrics if m.resized][0]
    assert first.action == "resize" and first.reason == "resize 4->8"


# ---------------------------------------------------------------------------
# decision-log persistence: snapshot/restore carries the history
# ---------------------------------------------------------------------------


def test_decision_log_snapshot_restore_roundtrip():
    """A restored DRM keeps its decision history — records, reasons,
    details, and the cumulative taken/declined counters — and keeps
    logging into the same history."""
    drm = _warm_drm(DRConfig(elastic=True, imbalance_trigger=1.05,
                             migration_cost_weight=0.0, resize_cooldown=100))
    drm.exchange_backend = resolve_backend("ragged")
    drm.evaluate(Signals(loads=np.array([500.0, 30, 30, 37])))  # repartition
    drm.evaluate(Signals(loads=FLAT))                           # declined
    snap = drm.snapshot()
    restored = DRMaster.restore(snap, drm.config)
    # the restored master prices plans with the same transport it ran on
    assert restored.exchange_backend.name == "ragged"
    assert restored.decisions.counts() == drm.decisions.counts() == (1, 1)
    assert len(restored.decisions) == len(drm.decisions)
    for a, b in zip(restored.decisions.records, drm.decisions.records):
        assert a == b, (a, b)
    assert restored.decisions.consumer == drm.decisions.consumer
    # the restored log keeps accumulating on the shared counters
    restored.evaluate(Signals(loads=FLAT), policies_enabled=False)
    assert restored.decisions.counts() == (1, 2)


def test_decision_log_restore_tolerates_old_snapshots():
    drm = _warm_drm()
    snap = drm.snapshot()
    for k in list(snap):
        if k.startswith("decisions_"):
            snap.pop(k)
    restored = DRMaster.restore(snap, drm.config)
    assert len(restored.decisions) == 0 and restored.decisions.counts() == (0, 0)


def test_streaming_snapshot_carries_decision_log():
    """End-to-end: a StreamingJob restore resumes with its decision history
    (ROADMAP open item: the log used to live in memory per run)."""
    job = StreamingJob(num_partitions=4, state_capacity=2048)
    rng = np.random.default_rng(0)
    for _ in range(3):
        job.process_batch(rng.integers(0, 200, 1024))
    snap = job.snapshot()
    fresh = StreamingJob(num_partitions=4, state_capacity=2048)
    fresh.restore(snap)
    assert fresh.drm.decisions.counts() == job.drm.decisions.counts()
    assert [d.reason for d in fresh.drm.decisions.records] == \
        [d.reason for d in job.drm.decisions.records]


# ---------------------------------------------------------------------------
# backend-priced migration cost + exchange padded-vs-shipped signals
# ---------------------------------------------------------------------------


def test_repartition_cost_uses_host_backend():
    """The same skewed stream priced under dense vs ragged transports: the
    ragged rule (mean real rows) is cheaper than the dense rule (padded
    peak), so a gain that cannot pay for the dense pad can still pay for
    the ragged traffic — the transport changes the decision."""
    from repro.exchange import DenseBackend, RaggedBackend

    loads = np.array([500.0, 30, 30, 37])

    def decide(backend, weight):
        drm = _warm_drm(DRConfig(imbalance_trigger=1.05,
                                 migration_cost_weight=weight))
        drm.exchange_backend = backend
        return drm.evaluate(Signals(loads=loads, num_workers=4))

    dense_free = decide(DenseBackend(), 0.0)
    assert isinstance(dense_free, Repartition)
    est_dense = dense_free.est_migration
    ragged_free = decide(RaggedBackend(), 0.0)
    assert isinstance(ragged_free, Repartition)
    est_ragged = ragged_free.est_migration
    assert 0 < est_ragged < est_dense
    # a weight between the two gains: dense declines, ragged proceeds
    gain = dense_free.measured_imbalance - dense_free.planned_imbalance
    weight = gain / ((est_dense + est_ragged) / 2.0)
    dense_gated = decide(DenseBackend(), weight)
    ragged_gated = decide(RaggedBackend(), weight)
    assert isinstance(dense_gated, NoOp) and dense_gated.reason.startswith("gain ")
    assert isinstance(ragged_gated, Repartition)


def test_telemetry_padded_vs_shipped_and_hot_lane():
    t = Telemetry("stream")
    t.record_exchange(ExchangeStats(rows=100, wall_s=0.1, padded_rows=400,
                                    lane_overflow=np.array([0, 7, 0])))
    t.record_exchange(ExchangeStats(rows=50))  # dense-style: shipped == padded
    t.record_exchange(ExchangeStats(rows=0, lane_overflow=np.array([0, 2, 1])))
    s = t.snapshot(loads=FLAT)
    assert s.exchange_rows == 150 and s.exchange_padded_rows == 450
    assert s.exchange_padding_fraction == pytest.approx(150 / 450)
    np.testing.assert_array_equal(s.lane_overflow, [0, 9, 1])
    assert s.hot_lane == 1
    empty = t.snapshot(loads=FLAT)
    assert empty.hot_lane == -1 and empty.exchange_padding_fraction == 0.0


def test_telemetry_lane_overflow_survives_lane_count_change():
    """An elastic resize changes the lane count mid-window; both vectors
    fold onto the wider one, no drop lost."""
    t = Telemetry("stream")
    t.record_exchange(ExchangeStats(rows=8, lane_overflow=np.array([1, 2])))
    t.record_exchange(ExchangeStats(rows=8, lane_overflow=np.array([0, 1, 5, 0])))
    s = t.snapshot(loads=FLAT)
    np.testing.assert_array_equal(s.lane_overflow, [1, 3, 5, 0])
    assert s.hot_lane == 2


# ---------------------------------------------------------------------------
# the transport as an actuator: BackendPolicy + SwitchBackend
# ---------------------------------------------------------------------------


def _exchange_signals(fraction: float, padded: int = 1000) -> Signals:
    """Safe-point signals whose measured lane occupancy is ``fraction``."""
    return Signals(loads=FLAT, exchange_padded_rows=padded,
                   exchange_occupied_rows=int(fraction * padded),
                   exchange_rows=padded)


def test_telemetry_explicit_zero_occupancy_is_a_measurement():
    """Occupancy 0 with a nonzero provision means all-empty lanes (maximal
    padding waste) — the fraction must read 0.0, not fall back to the
    shipped rows as if occupancy had never been recorded."""
    t = Telemetry("stream")
    t.record_exchange(ExchangeStats(rows=100, padded_rows=100, occupied_rows=0))
    s = t.snapshot(loads=FLAT)
    assert s.exchange_padding_fraction == 0.0
    # unrecorded occupancy still falls back to shipped rows
    t.record_exchange(ExchangeStats(rows=50, padded_rows=100))
    s2 = t.snapshot(loads=FLAT)
    assert s2.exchange_occupied_rows == 50
    assert s2.exchange_padding_fraction == pytest.approx(0.5)


def test_backend_policy_flips_dense_to_ragged_with_patience():
    """Sustained low lane occupancy flips a dense job to the ragged
    transport after the patience streak; the decline and the switch both
    land in the decision log, and the DRM's plan pricing follows."""
    cfg = DRConfig(auto_backend=True, backend_patience=2, imbalance_trigger=1e9)
    drm = _warm_drm(cfg)
    a1 = drm.evaluate(_exchange_signals(0.2))
    assert isinstance(a1, NoOp)
    assert drm.decisions.records[-1].detail["backend_declined"].startswith(
        "backend-patience")
    a2 = drm.evaluate(_exchange_signals(0.2))
    assert isinstance(a2, SwitchBackend) and a2.backend == "ragged"
    assert a2.padding_fraction == pytest.approx(0.2)
    assert drm.exchange_backend.name == "ragged"
    d = drm.decisions.records[-1]
    assert d.kind == "switch_backend" and d.taken
    # a window with no exchange keeps the streak untouched, and occupancy
    # inside the dead zone resets it
    drm2 = _warm_drm(cfg)
    drm2.evaluate(_exchange_signals(0.2))
    a = drm2.evaluate(Signals(loads=FLAT))
    assert isinstance(a, NoOp)
    assert drm2.backend_streak == 1
    drm2.evaluate(_exchange_signals(0.7))  # dead zone: neither threshold
    assert drm2.backend_streak == 0


def test_backend_switch_oscillation_guard():
    """A sawtooth occupancy straddling both thresholds ping-pongs the
    transport with the guard off; with the cooldown spanning the window the
    same workload produces exactly one switch and zero reversals (the
    resize ping-pong test, one actuator over)."""
    def run(cooldown):
        cfg = DRConfig(auto_backend=True, backend_patience=1,
                       backend_cooldown=cooldown, imbalance_trigger=1e9)
        drm = _warm_drm(cfg)
        switches = []
        for t in range(12):
            frac = 0.2 if t % 2 == 0 else 1.0
            a = drm.evaluate(_exchange_signals(frac))
            if isinstance(a, SwitchBackend):
                switches.append(a.backend)
        return switches

    off = run(0)
    dirs = [s == "ragged" for s in off]
    reversals_off = sum(1 for a, b in zip(dirs, dirs[1:]) if a != b)
    assert reversals_off > 0, off
    on = run(100)
    assert on == ["ragged"], on  # one flip, no reversal inside the cooldown


def test_backend_switch_survives_snapshot_restore():
    cfg = DRConfig(auto_backend=True, backend_patience=1,
                   backend_cooldown=50, imbalance_trigger=1e9)
    drm = _warm_drm(cfg)
    a = drm.evaluate(_exchange_signals(0.1))
    assert isinstance(a, SwitchBackend)
    restored = DRMaster.restore(drm.snapshot(), cfg)
    assert restored.exchange_backend.name == "ragged"
    assert restored.last_backend_switch == drm.last_backend_switch
    # still inside the cooldown: the restored master cannot reverse
    b = restored.evaluate(_exchange_signals(1.0))
    assert isinstance(b, NoOp)
    assert restored.decisions.records[-1].detail["backend_declined"] == \
        "backend-cooldown"


def test_scheduler_backend_policy_parks_without_lane_telemetry():
    """The serving scheduler records no exchange-lane occupancy (its KV
    migrations are modeled, not bufferized), so the actuator declines with
    the no-exchange-window reason instead of flipping on a signal it never
    measured — the documented contract until session moves ship through
    real lanes."""
    sched = DRScheduler(4, dr=DRConfig(auto_backend=True, backend_patience=1,
                                       imbalance_trigger=1e9))
    rng = np.random.default_rng(3)
    for _ in range(3):
        window = rng.integers(0, 100, 50)
        for s in window:
            sched.route(int(s), 8.0)
        r = sched.checkpoint(window)
        assert r["backend"] == "dense" and not r["repartitioned"]
    declines = [d.detail.get("backend_declined")
                for d in sched.drm.decisions.records]
    assert all(reason == "backend-no-exchange-window" for reason in declines)


def test_streaming_auto_backend_switch_end_to_end():
    """A generously padded dense job flips to ragged at a safe point, the
    switch is visible in BatchMetrics and the decision log, never reverses
    inside the cooldown, changes no results, and survives restore."""
    dr = DRConfig(auto_backend=True, backend_patience=2, backend_cooldown=50,
                  imbalance_trigger=1e9)
    job = StreamingJob(num_partitions=4, state_capacity=2048,
                       capacity_factor=4.0, dr=dr)
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, 500, 2048) for _ in range(6)]
    ms = job.run(batches)
    switches = [m for m in ms if m.action == "switch_backend"]
    assert len(switches) == 1, [m.action for m in ms]
    assert job.exchange_backend.name == "ragged"
    # a switch is taken but moves no state: it must not read as a repartition
    assert not switches[0].repartitioned and not switches[0].resized
    sw = switches[0].batch
    assert all(m.backend == "dense" for m in ms[:sw + 1])
    assert all(m.backend == "ragged" for m in ms[sw + 1:])
    # ragged batches ship fewer rows than their padded provision
    assert all(m.shipped_rows < m.padded_rows for m in ms[sw + 1:])
    assert any(d.kind == "switch_backend" and d.taken
               for d in job.drm.decisions.records)
    # bit-identical state vs. a dense-pinned job on the same stream: the
    # actuator changes traffic, never results
    pinned = StreamingJob(num_partitions=4, state_capacity=2048,
                          capacity_factor=4.0,
                          dr=DRConfig(imbalance_trigger=1e9))
    pinned.run(batches)
    for key in rng.integers(0, 500, 16):
        assert job.state_count(int(key)) == pinned.state_count(int(key))
    # restore resumes on the switched transport
    snap = job.snapshot()
    fresh = StreamingJob(num_partitions=4, state_capacity=2048,
                         capacity_factor=4.0, dr=dr)
    assert fresh.exchange_backend.name == "dense"
    fresh.restore(snap)
    assert fresh.exchange_backend.name == "ragged"
    m = fresh.process_batch(batches[0])
    assert m.backend == "ragged"


# ---------------------------------------------------------------------------
# the other consumers: serving scheduler + MoE placement
# ---------------------------------------------------------------------------


def test_scheduler_checkpoint_uniform_schema():
    """Resize, repartition, and decline branches all return the same keys."""
    rng = np.random.default_rng(0)
    sched = DRScheduler(4, dr=DRConfig(lam=4.0, elastic=True, min_partitions=2,
                                       max_partitions=8, grow_trigger=1.5,
                                       shrink_trigger=1.02, resize_patience=1,
                                       imbalance_trigger=1e9))
    keys = ["repartitioned", "resized", "num_replicas", "imbalance",
            "moved_sessions", "reason", "backend", "overlapped"]
    results = []
    for _ in range(2):
        window = []
        for _ in range(200):
            s = 7 if rng.random() < 0.7 else int(rng.integers(100, 5000))
            sched.route(s, 32.0)
            window.append(s)
        results.append(sched.checkpoint(np.array(window)))
        sched.drain(2000.0)
    assert any(r["resized"] for r in results)
    for r in results:
        assert sorted(r.keys()) == sorted(keys), r
        assert isinstance(r["reason"], str) and r["reason"]
    assert len(sched.drm.decisions) == len(results)


def test_placement_controller_logs_decisions():
    ctl = PlacementController(16, 4, trigger=1.05)
    ctl.observe(np.ones(16))
    changed, _, _ = ctl.maybe_update()
    assert not changed
    assert ctl.decisions.records[-1].reason == "balanced"
    loads = np.ones(16)
    loads[0], loads[1] = 20.0, 15.0
    for _ in range(3):
        ctl.observe(loads)
    changed, _, _ = ctl.maybe_update()
    assert changed
    d = ctl.decisions.records[-1]
    assert d.taken and d.kind == "replace" and d.consumer == "moe"
    taken, declined = ctl.decisions.counts()
    assert (taken, declined) == (1, 1)


def test_placement_weight_costing_gates_which_placement_wins():
    """With expert-weight bytes folded through exchange_lane_cost, the
    policy prices every candidate (including "stay"): a prohibitive cost
    weight declines the re-placement outright, a free one re-places — the
    §4 gain-vs-migration-cost rule applied to expert weights."""
    loads = np.ones(16)
    loads[0], loads[1] = 20.0, 15.0

    def drive(cost_weight):
        ctl = PlacementController(16, 4, trigger=1.05,
                                  expert_weight_bytes=4096.0,
                                  cost_weight=cost_weight)
        for _ in range(3):
            ctl.observe(loads)
        return ctl, ctl.maybe_update()

    ctl, (changed, _, perm) = drive(cost_weight=0.0)
    assert changed and (perm != np.arange(16)).any()
    assert ctl.history[-1]["migration_bytes"] > 0
    assert ctl.history[-1]["choice"] in ("pack", "waterfill")
    assert ctl.decisions.records[-1].detail["choice"] == ctl.history[-1]["choice"]

    ctl, (changed, _, perm) = drive(cost_weight=1e9)
    assert not changed and (perm == np.arange(16)).all()
    d = ctl.decisions.records[-1]
    assert not d.taken and d.reason.startswith("placement gain <= migration cost")


def test_placement_costing_off_keeps_legacy_behavior():
    """expert_weight_bytes=0 (default): the policy only decides whether, the
    host computes the KIP placement — the pre-costing path."""
    ctl = PlacementController(16, 4, trigger=1.05)
    loads = np.ones(16)
    loads[0] = 20.0
    for _ in range(3):
        ctl.observe(loads)
    changed, _, _ = ctl.maybe_update()
    assert changed
    assert ctl.decisions.records[-1].reason.startswith("imbalance ")
    assert ctl.history[-1]["migration_bytes"] == 0.0


def test_batchmetrics_carries_action_kind():
    job = StreamingJob(num_partitions=4, state_capacity=2048, dr_enabled=False)
    rng = np.random.default_rng(1)
    m = job.process_batch(rng.integers(0, 500, 1024))
    assert m.action == "noop" and m.reason == "dr-disabled"
    job.resize(8)
    m2 = job.process_batch(rng.integers(0, 500, 1024))
    assert m2.action == "resize" and m2.resized


def test_scheduler_env_kill_switch_beats_overlap_config(monkeypatch):
    """REPRO_DISABLE_OVERLAP wins over DRConfig.overlap_exchange in the
    serving scheduler too: the checkpoint schema reports the *effective*
    overlap so operators can confirm the kill switch reached every
    consumer, not just the streaming driver."""
    monkeypatch.delenv("REPRO_DISABLE_OVERLAP", raising=False)
    sched = DRScheduler(4, dr=DRConfig(lam=4.0, imbalance_trigger=1.25,
                                       overlap_exchange=True,
                                       pipeline_depth=2))
    assert sched.overlap_active()
    rng = np.random.default_rng(0)
    r = sched.checkpoint(rng.integers(0, 100, 64))
    assert r["overlapped"] is True
    monkeypatch.setenv("REPRO_DISABLE_OVERLAP", "1")
    assert not sched.overlap_active()  # env wins, no reconstruction needed
    r = sched.checkpoint(rng.integers(0, 100, 64))
    assert r["overlapped"] is False


def test_scheduler_rejects_invalid_pipeline_depth():
    with pytest.raises(ValueError, match="pipeline_depth"):
        DRScheduler(4, dr=DRConfig(pipeline_depth=4))
