"""Unit tests for the loop-aware HLO roofline analyzer."""
import textwrap

from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_parse import analyze

HLO = textwrap.dedent("""
    HloModule jit_step

    %body.1 (arg.1: f32[8,128]) -> f32[8,128] {
      %p0 = f32[8,128]{1,0} parameter(0)
      %w = f32[128,128]{1,0} parameter(1)
      %dot.1 = f32[8,128]{1,0} dot(%p0, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,128]{1,0} all-reduce(%dot.1), replica_groups=[1,4]<=[4], to_apply=%add.0
      ROOT %out = f32[8,128]{1,0} add(%ar, %p0)
    }

    %cond.1 (arg.2: s32[]) -> pred[] {
      %i = s32[] parameter(0)
      ROOT %lt = pred[] compare(%i), direction=LT
    }

    ENTRY %main.1 (a: f32[8,128]) -> f32[8,128] {
      %a = f32[8,128]{1,0} parameter(0)
      %while.1 = f32[8,128]{1,0} while(%a), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %r = f32[8,128]{1,0} copy(%while.1)
    }
""")


def test_trip_count_multiplies_flops():
    r = analyze(HLO)
    # dot: 2 * 8*128 out * 128 contracted = 262144 flops, x10 trips
    assert r["flops"] == 10 * 2 * 8 * 128 * 128


def test_collectives_counted_with_trips():
    r = analyze(HLO)
    assert r["collective_bytes"]["all-reduce"] == 10 * 8 * 128 * 4


def test_bytes_accounted():
    r = analyze(HLO)
    assert r["hbm_bytes_fused"] > 0
    assert r["hbm_bytes"] >= r["hbm_bytes_fused"] / 2


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops_dev=1e15, hbm_dev=1e9, hbm_dev_fused=1e9, coll_dev=1e9)
    assert t["bottleneck"] == "compute"
    assert t["roofline_fraction"] == 1.0
    t = roofline_terms(flops_dev=1e9, hbm_dev=1e13, hbm_dev_fused=1e13, coll_dev=1e9)
    assert t["bottleneck"] == "memory"
    assert t["roofline_fraction"] < 0.1
