"""Pallas TPU kernel: fused partition lookup + slot assignment + bucketize.

``lookup_dispatch`` fused the route (key -> partition) with the slot rank
(destination -> stable send slot) but still returned per-record vectors
that a jnp scatter re-read from HBM to build the ``[L, capacity]`` send
buffers.  This kernel extends the chain through the scatter: the
key -> partition -> lane -> slot -> send-buffer path never leaves VMEM, so
the records make one trip instead of a materialize + re-read of the whole
batch between the route kernel and ``_bucketize``.

The scatter itself is a matmul (MXU, no serial stores): for a block of
``blk`` records with one-hot lane matrix ``O_lane [blk, L]`` (valid-masked)
and one-hot slot matrix ``O_slot [blk, cap]``, each scalar channel ``w``
lands as::

    buffer[l, c] += sum_r  O_lane[r, l] * w[r] * O_slot[r, c]
                 =  ((O_lane * w[:, None]).T @ O_slot)[l, c]

Slot ranks are globally unique within a lane (``dispatch_count``'s
invariant), so every ``(l, c)`` entry receives at most one nonzero term
across the whole grid — the f32 accumulation is exact, and rows whose slot
falls outside ``[0, cap)`` (capacity overflow, invalid records) match no
one-hot column and drop out, exactly like the jnp scatter's
``mode="drop"``.

int32 channels (keys, partition ids) cannot ride f32 matmuls directly
(f32 is exact only to 2**24), so they are split into 16-bit halves
(``x >> 16`` / ``x & 0xFFFF``, each < 65536, exact in f32) and recombined
outside the kernel.  Payload values are f32 and ride as-is: the product
``w * 1.0`` and the single-term sum are exact.

VMEM budget per grid step (block = 256, H = 4096, B <= 1024, L <= 16,
capP <= 2048): route stages ~6.3 MiB (as ``lookup_dispatch``); slot one-hot
256*2048*4B = 2.0 MiB; per-channel accumulators 5 * 16*2048*4B = 0.6 MiB
=> ~9 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lookup_dispatch import BLK, LANES, ROWS, _fmix32


def _kernel(
    keys_ref, valid_ref, vals_ref, heavy_keys_ref, heavy_parts_ref, host_ref,
    *rest, seed: int, num_hosts: int, num_lanes: int, capacity: int,
    num_partitions: int = 0,
):
    # with splitting active (num_partitions > 0) the heavy-replica table
    # rides along as a seventh input, ahead of the output refs
    if num_partitions > 0:
        heavy_repl_ref, *rest = rest
    (part_ref, slot_ref, counts_ref,
     bvalid_ref, bkhi_ref, bklo_ref, bphi_ref, bplo_ref, bvals_ref) = rest
    keys = keys_ref[...].reshape(BLK)
    valid = valid_ref[...].reshape(BLK).astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        bvalid_ref[...] = jnp.zeros_like(bvalid_ref)
        bkhi_ref[...] = jnp.zeros_like(bkhi_ref)
        bklo_ref[...] = jnp.zeros_like(bklo_ref)
        bphi_ref[...] = jnp.zeros_like(bphi_ref)
        bplo_ref[...] = jnp.zeros_like(bplo_ref)
        bvals_ref[...] = jnp.zeros_like(bvals_ref)

    # ---- stage 1: key -> partition (one-hot matmul lookup) ----
    mixed = _fmix32(keys.astype(jnp.uint32) ^ jnp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF))
    host = (mixed & jnp.uint32(num_hosts - 1)).astype(jnp.int32)
    host_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, num_hosts), 1)
    onehot_host = (host[:, None] == host_iota).astype(jnp.float32)
    table = host_ref[...].reshape(num_hosts).astype(jnp.float32)
    part_tail = jax.lax.dot_general(
        onehot_host, table[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    hk = heavy_keys_ref[...].reshape(-1)
    hp = heavy_parts_ref[...].reshape(-1).astype(jnp.float32)
    eq = (keys[:, None] == hk[None, :]).astype(jnp.float32)
    hit = jnp.sum(eq, axis=1) > 0.0
    part_heavy = jax.lax.dot_general(
        eq, hp[:, None], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]
    if num_partitions > 0:
        # ---- split-key replica pick (same formula as lookup_dispatch) ----
        hr = heavy_repl_ref[...].reshape(-1).astype(jnp.float32)
        d = jax.lax.dot_general(
            eq, hr[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, 0]
        d = jnp.maximum(d.astype(jnp.int32), 1)
        gi = pl.program_id(0) * BLK + (
            jax.lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 0) * LANES
            + jax.lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 1)
        ).reshape(BLK)
        h = _fmix32(gi.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) ^ mixed)
        offset = jax.lax.rem((h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32), d)
        split_part = jax.lax.rem(
            part_heavy.astype(jnp.int32) + offset, jnp.int32(num_partitions)
        )
        part = jnp.where(hit, split_part, part_tail.astype(jnp.int32)).astype(jnp.int32)
    else:
        part = jnp.where(hit, part_heavy, part_tail).astype(jnp.int32)
    part_ref[...] = part.reshape(ROWS, LANES)

    # ---- stage 2: lane rank (triangular prefix matmul, fused in VMEM) ----
    lane = jax.lax.rem(part, jnp.int32(num_lanes))
    lane_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, num_lanes), 1)
    onehot = (lane[:, None] == lane_iota).astype(jnp.float32) * valid[:, None]

    r = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 1)
    tri = (c < r).astype(jnp.float32)  # strictly lower triangular
    prefix = jax.lax.dot_general(
        tri, onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    running = counts_ref[...]  # [1, L] counts from earlier blocks
    base = jnp.sum(onehot * running, axis=1)
    rank = jnp.sum(onehot * prefix, axis=1)
    slot = (base + rank).astype(jnp.int32)
    slot = jnp.where(valid > 0, slot, -1)
    slot_ref[...] = slot.reshape(ROWS, LANES)
    counts_ref[...] = running + jnp.sum(onehot, axis=0, keepdims=True)

    # ---- stage 3: scatter into the send buffers (matmul, still in VMEM) --
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, capacity), 1)
    onehot_slot = (slot[:, None] == slot_iota).astype(jnp.float32)

    def scat(w):  # [blk] channel -> [L, cap] contribution of this block
        return jax.lax.dot_general(
            onehot * w[:, None], onehot_slot, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    keys_u = keys.astype(jnp.uint32)
    part_u = part.astype(jnp.uint32)
    bvalid_ref[...] += scat(jnp.ones(BLK, jnp.float32))
    bkhi_ref[...] += scat((keys_u >> jnp.uint32(16)).astype(jnp.float32))
    bklo_ref[...] += scat((keys_u & jnp.uint32(0xFFFF)).astype(jnp.float32))
    bphi_ref[...] += scat((part_u >> jnp.uint32(16)).astype(jnp.float32))
    bplo_ref[...] += scat((part_u & jnp.uint32(0xFFFF)).astype(jnp.float32))
    for d in range(vals_ref.shape[1]):
        bvals_ref[d] += scat(vals_ref[:, d])


@functools.partial(jax.jit, static_argnames=(
    "seed", "num_hosts", "num_lanes", "capacity", "num_partitions", "interpret"))
def route_bucketize(
    keys: jax.Array,  # int32[n], n % 256 == 0
    valid: jax.Array,  # bool[n]
    vals: jax.Array,  # f32[n, D]
    heavy_keys: jax.Array,  # int32[B] sorted, sentinel padded
    heavy_parts: jax.Array,  # int32[B]
    host_to_part: jax.Array,  # int32[H], H a power of two
    heavy_repl: jax.Array | None = None,  # int32[B] replicas (pad rows: 0)
    *,
    seed: int = 0,
    num_hosts: int = 4096,
    num_lanes: int,
    capacity: int,
    num_partitions: int = 0,
    interpret: bool = True,
):
    """Returns ``(part[n], slot[n], counts[L], bvalid[L, cap],
    bkhi/bklo/bphi/bplo [L, cap], bvals[D, L, cap])`` — raw f32 channel
    buffers; ``repro.kernels.ops.route_bucketize`` recombines the 16-bit
    halves and applies fills.  ``num_partitions > 0`` enables the split-key
    replica pick (see ``lookup_dispatch``); 0 traces the pre-split program."""
    n = keys.shape[0]
    assert n % BLK == 0, f"pad records to a multiple of {BLK}"
    assert num_hosts & (num_hosts - 1) == 0, "H must be a power of two"
    b = heavy_keys.shape[0]
    d = vals.shape[1]
    keys2d = keys.reshape(n // LANES, LANES)
    valid2d = valid.astype(jnp.int32).reshape(n // LANES, LANES)

    in_specs = [
        pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        pl.BlockSpec((BLK, d), lambda i: (i, 0)),
        pl.BlockSpec((1, b), lambda i: (0, 0)),
        pl.BlockSpec((1, b), lambda i: (0, 0)),
        pl.BlockSpec((1, host_to_part.shape[0]), lambda i: (0, 0)),
    ]
    inputs = [keys2d, valid2d, vals, heavy_keys[None, :], heavy_parts[None, :],
              host_to_part[None, :]]
    if num_partitions > 0:
        assert heavy_repl is not None, "splitting needs the replica table"
        in_specs.append(pl.BlockSpec((1, b), lambda i: (0, 0)))
        inputs.append(heavy_repl[None, :])

    out = pl.pallas_call(
        functools.partial(_kernel, seed=seed, num_hosts=num_hosts,
                          num_lanes=num_lanes, capacity=capacity,
                          num_partitions=num_partitions),
        grid=(n // BLK,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, num_lanes), lambda i: (0, 0)),
            pl.BlockSpec((num_lanes, capacity), lambda i: (0, 0)),
            pl.BlockSpec((num_lanes, capacity), lambda i: (0, 0)),
            pl.BlockSpec((num_lanes, capacity), lambda i: (0, 0)),
            pl.BlockSpec((num_lanes, capacity), lambda i: (0, 0)),
            pl.BlockSpec((num_lanes, capacity), lambda i: (0, 0)),
            pl.BlockSpec((d, num_lanes, capacity), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((1, num_lanes), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, capacity), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, capacity), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, capacity), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, capacity), jnp.float32),
            jax.ShapeDtypeStruct((num_lanes, capacity), jnp.float32),
            jax.ShapeDtypeStruct((d, num_lanes, capacity), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    part, slot, counts, bvalid, bkhi, bklo, bphi, bplo, bvals = out
    return (part.reshape(n), slot.reshape(n), counts[0].astype(jnp.int32),
            bvalid, bkhi, bklo, bphi, bplo, bvals)
