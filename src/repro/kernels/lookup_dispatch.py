"""Pallas TPU kernel: fused partition lookup + send-slot assignment.

The exchange plane's hot path runs two kernels back to back on the same
records: ``partition_apply`` (key -> partition) and ``dispatch_count``
(destination -> stable send slot).  Fusing them keeps the one-hot
destination matrix in VMEM between the two stages — the [blk, L] one-hot
built for the slot ranking is derived directly from the partition ids the
lookup just produced, so the records make one trip through VMEM instead of
two round trips to HBM.

Per record ``i`` with key ``k``::

    part[i] = heavy_parts[j]        if k == heavy_keys[j] for some j
            = host_to_part[fmix32(k ^ seed) & (H - 1)]   otherwise
    lane[i] = part[i] % num_lanes
    slot[i] = #{ j < i : lane[j] == lane[i], valid[j] }  (stable rank)
    counts[l] = total valid records on lane l

The rank uses the strictly-lower-triangular matmul trick (MXU) with the
running per-lane counts carried across the sequential grid in a VMEM
accumulator, exactly as in ``dispatch_count``.

VMEM budget per grid step (block = 256, H = 4096, B <= 1024, L <= 1024):
  host one-hot 256*4096*4B = 4.0 MiB; heavy one-hot 256*1024*4B = 1.0 MiB;
  tri 256^2*4B = 0.25 MiB; lane one-hot 256*1024*4B = 1.0 MiB  => ~6.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
ROWS = 2  # 256 records per grid step
BLK = LANES * ROWS


def _fmix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _kernel(
    keys_ref, valid_ref, heavy_keys_ref, heavy_parts_ref, host_ref,
    *rest, seed: int, num_hosts: int, num_lanes: int, num_partitions: int = 0,
):
    # with splitting active (num_partitions > 0) the heavy-replica table
    # rides along as a sixth input, ahead of the output refs
    if num_partitions > 0:
        heavy_repl_ref, part_ref, slot_ref, counts_ref = rest
    else:
        part_ref, slot_ref, counts_ref = rest
    keys = keys_ref[...].reshape(BLK)
    valid = valid_ref[...].reshape(BLK).astype(jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    # ---- stage 1: key -> partition (one-hot matmul lookup) ----
    mixed = _fmix32(keys.astype(jnp.uint32) ^ jnp.uint32((seed * 0x9E3779B9) & 0xFFFFFFFF))
    host = (mixed & jnp.uint32(num_hosts - 1)).astype(jnp.int32)
    host_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, num_hosts), 1)
    onehot_host = (host[:, None] == host_iota).astype(jnp.float32)
    table = host_ref[...].reshape(num_hosts).astype(jnp.float32)
    part_tail = jax.lax.dot_general(
        onehot_host, table[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    hk = heavy_keys_ref[...].reshape(-1)
    hp = heavy_parts_ref[...].reshape(-1).astype(jnp.float32)
    eq = (keys[:, None] == hk[None, :]).astype(jnp.float32)
    hit = jnp.sum(eq, axis=1) > 0.0
    part_heavy = jax.lax.dot_general(
        eq, hp[:, None], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )[:, 0]
    if num_partitions > 0:
        # ---- split-key replica pick (fused next to the heavy lookup) ----
        # replicas per record via the same eq matmul (exactly one live match
        # per key; sentinel records sum pad rows' 0 -> clamp to 1 -> offset 0)
        hr = heavy_repl_ref[...].reshape(-1).astype(jnp.float32)
        d = jax.lax.dot_general(
            eq, hr[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[:, 0]
        d = jnp.maximum(d.astype(jnp.int32), 1)
        # the record's shard-local index, from two 2-D iotas (row-major over
        # the [ROWS, LANES] block layout, matching the keys reshape)
        gi = pl.program_id(0) * BLK + (
            jax.lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 0) * LANES
            + jax.lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 1)
        ).reshape(BLK)
        h = _fmix32(gi.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) ^ mixed)
        offset = jax.lax.rem((h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32), d)
        split_part = jax.lax.rem(
            part_heavy.astype(jnp.int32) + offset, jnp.int32(num_partitions)
        )
        part = jnp.where(hit, split_part, part_tail.astype(jnp.int32)).astype(jnp.int32)
    else:
        part = jnp.where(hit, part_heavy, part_tail).astype(jnp.int32)
    part_ref[...] = part.reshape(ROWS, LANES)

    # ---- stage 2: lane rank (triangular prefix matmul, fused in VMEM) ----
    lane = jax.lax.rem(part, jnp.int32(num_lanes))
    lane_iota = jax.lax.broadcasted_iota(jnp.int32, (BLK, num_lanes), 1)
    onehot = (lane[:, None] == lane_iota).astype(jnp.float32) * valid[:, None]

    r = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (BLK, BLK), 1)
    tri = (c < r).astype(jnp.float32)  # strictly lower triangular
    prefix = jax.lax.dot_general(
        tri, onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    running = counts_ref[...]  # [1, L] counts from earlier blocks
    base = jnp.sum(onehot * running, axis=1)
    rank = jnp.sum(onehot * prefix, axis=1)
    slot = (base + rank).astype(jnp.int32)
    slot = jnp.where(valid > 0, slot, -1)
    slot_ref[...] = slot.reshape(ROWS, LANES)
    counts_ref[...] = running + jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("seed", "num_hosts", "num_lanes", "num_partitions", "interpret"),
)
def lookup_dispatch(
    keys: jax.Array,  # int32[n], n % 256 == 0
    valid: jax.Array,  # bool[n]
    heavy_keys: jax.Array,  # int32[B] sorted, sentinel padded
    heavy_parts: jax.Array,  # int32[B]
    host_to_part: jax.Array,  # int32[H], H a power of two
    heavy_repl: jax.Array | None = None,  # int32[B] replicas (pad rows: 0)
    *,
    seed: int = 0,
    num_hosts: int = 4096,
    num_lanes: int,
    num_partitions: int = 0,
    interpret: bool = True,
):
    """Returns (part int32[n], slot int32[n] — rank within ``part % num_lanes``,
    -1 for invalid; counts int32[num_lanes]).

    ``num_partitions > 0`` switches on hot-key splitting: a heavy key with
    ``heavy_repl[b] = d > 1`` fans its records over the d consecutive
    partitions starting at ``heavy_parts[b]`` by a per-record hash.  With
    ``num_partitions == 0`` (the default) the traced program is exactly the
    pre-split one."""
    n = keys.shape[0]
    assert n % BLK == 0, f"pad records to a multiple of {BLK}"
    assert num_hosts & (num_hosts - 1) == 0, "H must be a power of two"
    b = heavy_keys.shape[0]
    keys2d = keys.reshape(n // LANES, LANES)
    valid2d = valid.astype(jnp.int32).reshape(n // LANES, LANES)

    in_specs = [
        pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        pl.BlockSpec((1, b), lambda i: (0, 0)),
        pl.BlockSpec((1, b), lambda i: (0, 0)),
        pl.BlockSpec((1, host_to_part.shape[0]), lambda i: (0, 0)),
    ]
    inputs = [keys2d, valid2d, heavy_keys[None, :], heavy_parts[None, :],
              host_to_part[None, :]]
    if num_partitions > 0:
        assert heavy_repl is not None, "splitting needs the replica table"
        in_specs.append(pl.BlockSpec((1, b), lambda i: (0, 0)))
        inputs.append(heavy_repl[None, :])

    part, slot, counts = pl.pallas_call(
        functools.partial(_kernel, seed=seed, num_hosts=num_hosts,
                          num_lanes=num_lanes, num_partitions=num_partitions),
        grid=(n // BLK,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, num_lanes), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((n // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((1, num_lanes), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return part.reshape(n), slot.reshape(n), counts[0].astype(jnp.int32)
