"""Beyond-paper — KIP expert placement for MoE (the in-model DR).

Simulates skewed routing (Zipf expert popularity, drifting) and measures
EP-shard load imbalance + expert migrations for: static placement, greedy
rebuild (Redist-analog), and KIP placement."""
from __future__ import annotations

import numpy as np

from repro.moe.kip_placement import PlacementController

E, SHARDS, STEPS = 128, 16, 40


def _loads(rng, step):
    ranks = rng.zipf(1.4, size=20_000)
    ranks = ranks[ranks <= E] - 1
    # drift: rotate expert popularity every 10 steps
    shift = (step // 10) * 17
    return np.bincount((ranks + shift) % E, minlength=E).astype(float)


def run():
    rows = []
    rng = np.random.default_rng(0)
    series = [_loads(rng, s) for s in range(STEPS)]

    # static identity placement
    ctl = PlacementController(E, SHARDS, trigger=10**9)  # never updates
    static_imb = [
        (lambda sl: sl.max() / sl.mean())(ctl.shard_loads(l / l.sum())) for l in series
    ]

    # KIP placement
    ctl = PlacementController(E, SHARDS, trigger=1.1)
    kip_imb, moved = [], 0
    for l in series:
        ctl.observe(l)
        changed, _, perm = ctl.maybe_update()
        moved += int((perm != np.arange(E)).sum())
        sl = ctl.shard_loads(l / l.sum())
        kip_imb.append(sl.max() / sl.mean())

    rows.append(("moe/imbalance_static", float(np.mean(static_imb)), "128e/16shards"))
    rows.append(("moe/imbalance_kip", float(np.mean(kip_imb)), ""))
    rows.append(("moe/imbalance_reduction", float(1 - np.mean(kip_imb) / np.mean(static_imb)),
                 "capacity-factor/ICI saving at fixed drop rate"))
    rows.append(("moe/experts_moved_total", float(moved),
                 f"over {STEPS} steps (migration = expert-weight all-to-all)"))
    assert np.mean(kip_imb) < np.mean(static_imb)

    # beyond paper^2: heavy-expert replication (16 extra physical slots)
    from repro.moe.kip_placement import replicated_assignment

    rep_imb = []
    for l in series:
        owner, shard_of = replicated_assignment(l, SHARDS, replicas=16)
        rel = l / max(l.sum(), 1e-12)
        counts = np.bincount(owner, minlength=E)
        eff = (rel / counts)[owner]
        sl = np.zeros(SHARDS)
        np.add.at(sl, shard_of, eff)
        rep_imb.append(sl.max() / sl.mean())
    rows.append(("moe/imbalance_kip_replicated", float(np.mean(rep_imb)),
                 "+16 replica slots: beats the single-expert floor"))
    assert np.mean(rep_imb) < np.mean(kip_imb)
    return rows
