"""Decoder-only LM assembled from the config's block pattern.

Depth is evaluated as ``jax.lax.scan`` over *periods* (one period = the
config's repeating block pattern, weights stacked ``[periods, ...]``), so
compile time is flat in depth — essential for 62-layer dry-runs.  The
optional non-repeating ``tail`` blocks run unscanned.

One code path serves train / prefill / decode; caches (KV, ring-KV, SSM,
xLSTM) are pytrees stacked along the period axis and threaded through the
scan as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, Block
from repro.models.attention import (
    HeadLayout,
    attention_block,
    head_layout,
    init_attention,
    init_kv_cache,
)
from repro.models.modules import (
    Array,
    Policy,
    apply_ffn,
    apply_norm,
    chunked_softmax_xent,
    embed,
    init_embed,
    init_ffn,
    init_norm,
    normal,
    pad_vocab,
    unembed_logits,
)
from repro.models.ssm import init_mamba, init_mamba_state, mamba_forward
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mlstm_forward,
    slstm_forward,
)
from repro.moe.layer import init_moe, moe_apply, moe_apply_replicated, moe_ref


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, blk: Block, lay: HeadLayout, pol: Policy) -> dict:
    ks = jax.random.split(key, 4)
    dt = pol.param_dtype
    p: dict[str, Any] = {"ln1": init_norm(cfg.norm_kind, cfg.d_model, dt)}
    if blk.mixer in ("attn", "local_attn"):
        p["attn"] = init_attention(
            ks[0], cfg.d_model, lay, cfg.head_dim,
            qk_norm=cfg.qk_norm, norm_kind=cfg.norm_kind, dtype=dt,
        )
    elif blk.mixer == "mamba":
        p["mamba"] = init_mamba(
            ks[0], cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_conv, dtype=dt,
        )
    elif blk.mixer == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg.d_model, cfg.num_heads,
                                _heads_p(cfg, pol), dtype=dt)
    elif blk.mixer == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg.d_model, cfg.num_heads,
                                _heads_p(cfg, pol), dtype=dt)
    if blk.ffn == "dense":
        p["ln2"] = init_norm(cfg.norm_kind, cfg.d_model, dt)
        p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind, dt)
    elif blk.ffn == "moe":
        p["ln2"] = init_norm(cfg.norm_kind, cfg.d_model, dt)
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe, cfg.ffn_kind, dt)
    return p


def _heads_p(cfg: ArchConfig, pol: Policy) -> int:
    h = cfg.num_heads
    return h if h % pol.tp == 0 else int(np.ceil(h / pol.tp) * pol.tp)


def init_params(cfg: ArchConfig, key, pol: Policy) -> dict:
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, pol.tp)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, pol.param_dtype),
        "final_norm": init_norm(cfg.norm_kind, cfg.d_model, pol.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal(
            keys[1], (pad_vocab(cfg.vocab_size), cfg.d_model),
            cfg.d_model**-0.5, pol.param_dtype,
        )
    # stacked period blocks: vmap init over the period axis
    per = cfg.num_periods
    blocks = {}
    for j, blk in enumerate(cfg.pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[2], j), per)
        blocks[f"b{j}"] = jax.vmap(lambda k: _init_block(k, cfg, blk, lay, pol))(bkeys)
    params["blocks"] = blocks
    for j, blk in enumerate(cfg.tail):
        params[f"tail{j}"] = _init_block(jax.random.fold_in(keys[3], j), cfg, blk, lay, pol)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, pol: Policy) -> dict:
    """Decode caches stacked [periods, ...] per pattern position."""
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, pol.tp)
    per = cfg.num_periods
    hp = _heads_p(cfg, pol)

    def one(blk: Block) -> dict:
        if blk.mixer == "attn":
            return init_kv_cache(batch, max_len, lay, cfg.head_dim, window=0,
                                 dtype=pol.compute_dtype)
        if blk.mixer == "local_attn":
            return init_kv_cache(batch, max_len, lay, cfg.head_dim, window=cfg.window,
                                 dtype=pol.compute_dtype)
        if blk.mixer == "mamba":
            return init_mamba_state(batch, cfg.d_model, expand=cfg.mamba_expand,
                                    d_state=cfg.mamba_d_state, d_conv=cfg.mamba_conv,
                                    dtype=pol.compute_dtype)
        if blk.mixer == "mlstm":
            di = 2 * cfg.d_model
            return init_mlstm_state(batch, hp, di // cfg.num_heads, di,
                                    dtype=pol.compute_dtype)
        if blk.mixer == "slstm":
            return init_slstm_state(batch, hp, cfg.d_model // cfg.num_heads)
        raise ValueError(blk.mixer)

    cache: dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    cache["blocks"] = {
        f"b{j}": _stack(one(blk), per) for j, blk in enumerate(cfg.pattern)
    }
    for j, blk in enumerate(cfg.tail):
        cache[f"tail{j}"] = one(blk)
    return cache


def _stack(tree, n: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_block(
    blk: Block, p: dict, x: Array, cfg: ArchConfig, lay: HeadLayout, pol: Policy,
    *, pos, cache=None, inv_place=None,
):
    """Pre-norm residual block.  Returns (x, new_cache, moe_stats)."""
    moe_stats = None
    h = apply_norm(p["ln1"], x, cfg.norm_kind)
    if blk.mixer in ("attn", "local_attn"):
        window = cfg.window if blk.mixer == "local_attn" else 0
        theta = cfg.rope_local_theta if (blk.mixer == "local_attn" and cfg.rope_local_theta) else cfg.rope_theta
        sections = _mrope_sections(cfg) if cfg.rope_kind == "mrope" else None
        y, new_cache = attention_block(
            p["attn"], h, lay, pol, pos=pos, causal=True, window=window,
            theta=theta, rope_pct=cfg.rope_pct, rope_kind=cfg.rope_kind,
            mrope_sections=sections, norm_kind=cfg.norm_kind, cache=cache,
        )
    elif blk.mixer == "mamba":
        y, new_cache = mamba_forward(p["mamba"], h, pol, d_state=cfg.mamba_d_state,
                                     chunk=min(256, h.shape[1]), state=cache)
    elif blk.mixer == "mlstm":
        y, new_cache = mlstm_forward(p["mlstm"], h, pol, chunk=min(256, h.shape[1]),
                                     state=cache)
    elif blk.mixer == "slstm":
        y, new_cache = slstm_forward(p["slstm"], h, pol, state=cache)
    else:
        raise ValueError(blk.mixer)
    x = x + y
    x = pol.shard(x, "act_btd")

    if blk.ffn != "none":
        h = apply_norm(p["ln2"], x, cfg.norm_kind)
        if blk.ffn == "dense":
            y = apply_ffn(p["ffn"], h, cfg.ffn_kind, pol)
        else:
            if pol.mesh is None:
                fn = moe_ref
            elif h.shape[1] % pol.tp == 0 and h.shape[1] > 1:
                fn = moe_apply          # train/prefill: seq shards over model
            else:
                fn = moe_apply_replicated  # decode: tokens replicated over EP
            out = fn(p["moe"], h, cfg.moe, cfg.ffn_kind, pol, inv_place)
            y = checkpoint_name(out.y, "moe_out")
            moe_stats = (out.counts, out.overflow, out.aux_loss)
        x = x + y
        x = pol.shard(x, "act_btd")
    return x, new_cache, moe_stats


def _mrope_sections(cfg: ArchConfig) -> tuple:
    half = int(cfg.head_dim * cfg.rope_pct) // 2
    t = half // 4
    rest = half - t
    return (t, rest // 2, rest - rest // 2)


def _positions(cfg: ArchConfig, b: int, s: int, offset) -> Array:
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + (
        offset[:, None] if isinstance(offset, jax.Array) else offset
    )
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos[None], (3, b, s))  # t=h=w for text stub
    return pos


def backbone(
    params: dict, x: Array, cfg: ArchConfig, pol: Policy,
    *, pos, cache: dict | None = None, inv_place: Array | None = None,
):
    """Embedded input [B, S, d] -> final hidden [B, S, d].

    Returns (x, new_cache, moe_counts [E] or None, moe_aux, overflow)."""
    lay = head_layout(cfg.num_heads, cfg.num_kv_heads, pol.tp)
    if inv_place is None and cfg.moe is not None:
        inv_place = jnp.arange(cfg.moe.num_experts, dtype=jnp.int32)

    def period_fn(x, per_params, per_cache):
        stats = []
        new_caches = {}
        for j, blk in enumerate(cfg.pattern):
            c = per_cache.get(f"b{j}") if per_cache else None
            x, nc, ms = _apply_block(blk, per_params[f"b{j}"], x, cfg, lay, pol,
                                     pos=pos, cache=c, inv_place=inv_place)
            if nc is not None:
                new_caches[f"b{j}"] = nc
            if ms is not None:
                stats.append(ms)
        return x, new_caches, stats

    if pol.remat:
        if pol.remat_policy == "save_moe":
            # §Perf: never re-run the expert all-to-all in the backward pass
            policy = jax.checkpoint_policies.save_only_these_names("moe_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        period_fn = jax.checkpoint(period_fn, policy=policy)

    def scan_body(carry, xs):
        x = carry
        per_params, per_cache = xs
        x, new_caches, stats = period_fn(x, per_params, per_cache)
        counts = (
            sum(s[0] for s in stats) if stats else jnp.zeros((0,), jnp.float32)
        )
        over = sum((s[1] for s in stats), jnp.zeros((), jnp.float32))
        aux = sum((s[2] for s in stats), jnp.zeros((), jnp.float32))
        return x, (new_caches, counts, over, aux)

    per_cache = cache["blocks"] if cache is not None else None
    xs = (params["blocks"], per_cache)
    x, (new_caches, counts, over, aux) = jax.lax.scan(scan_body, x, xs)

    moe_counts = jnp.sum(counts, axis=0) if cfg.moe is not None else None
    overflow = jnp.sum(over)
    aux_loss = jnp.mean(aux) if cfg.moe is not None else jnp.zeros(())

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["blocks"] = new_caches

    for j, blk in enumerate(cfg.tail):
        c = cache.get(f"tail{j}") if cache is not None else None
        x, nc, ms = _apply_block(blk, params[f"tail{j}"], x, cfg, lay, pol,
                                 pos=pos, cache=c, inv_place=inv_place)
        if new_cache is not None and nc is not None:
            new_cache[f"tail{j}"] = nc

    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    return x, new_cache, moe_counts, overflow, aux_loss


# ---------------------------------------------------------------------------
# top-level entry points (train / prefill / decode)
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: dict, cfg: ArchConfig, pol: Policy) -> Array:
    x = embed(params["embed"], batch["tokens"], scale=cfg.embed_scale,
              d=cfg.d_model, pol=pol)
    if cfg.vision_tokens and "vision_embeds" in batch:
        v = batch["vision_embeds"].astype(pol.compute_dtype)
        x = jnp.concatenate([v, x[:, v.shape[1] :]], axis=1)  # stub: patches replace prefix
    x = pol.shard(x, "act_btd")
    return x


def _unembed_w(params, cfg: ArchConfig):
    return params["lm_head"] if not cfg.tie_embeddings else params["embed"]["tok"]


def loss_fn(params, batch: dict, cfg: ArchConfig, pol: Policy,
            inv_place: Array | None = None):
    """Training loss.  batch: tokens, labels, mask int/bool [B, S]."""
    x = _embed_inputs(params, batch, cfg, pol)
    pos = _positions(cfg, *batch["tokens"].shape, 0)
    x, _, counts, overflow, aux = backbone(params, x, cfg, pol, pos=pos,
                                           inv_place=inv_place)
    loss = chunked_softmax_xent(
        x, _unembed_w(params, cfg), batch["labels"], batch["mask"], pol,
        cfg.vocab_size, softcap=cfg.logit_softcap,
    )
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    metrics = {"overflow": overflow}
    if counts is not None:
        metrics["expert_counts"] = counts
    return loss, metrics


def prefill(params, batch: dict, cfg: ArchConfig, pol: Policy, max_len: int,
            inv_place: Array | None = None):
    """Fill caches for the prompt; return last-token logits + cache."""
    b, s = batch["tokens"].shape
    cache = init_cache(cfg, b, max_len, pol)
    x = _embed_inputs(params, batch, cfg, pol)
    pos = _positions(cfg, b, s, 0)
    x, cache, counts, overflow, _ = backbone(params, x, cfg, pol, pos=pos,
                                             cache=cache, inv_place=inv_place)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    logits = unembed_logits(x[:, -1:], _unembed_w(params, cfg), pol)
    return logits, cache


def decode_step(params, cache: dict, tokens: Array, cfg: ArchConfig, pol: Policy,
                inv_place: Array | None = None):
    """One token step.  tokens [B, 1].  Returns (logits [B, 1, V], cache)."""
    b = tokens.shape[0]
    x = embed(params["embed"], tokens, scale=cfg.embed_scale, d=cfg.d_model, pol=pol)
    pos = _positions(cfg, b, 1, cache["pos"])
    x, cache, counts, overflow, _ = backbone(params, x, cfg, pol, pos=pos,
                                             cache=cache, inv_place=inv_place)
    cache["pos"] = cache["pos"] + 1
    logits = unembed_logits(x, _unembed_w(params, cfg), pol)
    return logits, cache
