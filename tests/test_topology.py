"""Two-tier exchange topology: distance classes, locality pricing, and the
hierarchical backend's accounting.

The real two-hop collective runs on 8 shards in ``tests/test_distributed.py``
(``test_hierarchical_backend_on_8_devices``); here the single-device suite
covers everything host-side — the :class:`ExchangeTopology` tables, spec
resize survival, the per-class accounting stamped by every backend, the
locality-priced plan cost (and the decision it flips), telemetry folding,
and snapshot round-trips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.control import Telemetry
from repro.core.drm import DRConfig, DRMaster
from repro.core.migration import MigrationPlan, exchange_lane_cost
from repro.core.partitioner import uniform_partitioner
from repro.core.streaming import StreamingJob
from repro.exchange import (
    ExchangeSpec,
    ExchangeStats,
    ExchangeTopology,
    HierarchicalBackend,
    Payload,
    make_exchange,
    resolve_backend,
)
from repro.exchange.spec import DISTANCE_CLASSES, _class_tables
from repro.launch.mesh import exchange_topology_of


# ---------------------------------------------------------------------------
# ExchangeTopology: distance-class tables
# ---------------------------------------------------------------------------


def test_topology_class_tables():
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    assert topo.num_hosts == 2
    cm = topo.class_matrix
    assert cm.shape == (8, 8)
    # diagonal = self, same host block = intra, rest = inter
    np.testing.assert_array_equal(np.diag(cm), np.zeros(8))
    assert cm[0, 3] == 1 and cm[4, 7] == 1       # same host
    assert cm[0, 4] == 2 and cm[7, 0] == 2       # across hosts
    # per-lane class histogram: 1 self + 3 intra + 4 inter, rows sum to L
    counts = topo.class_lane_counts
    np.testing.assert_array_equal(counts, np.tile([1, 3, 4], (8, 1)))
    np.testing.assert_array_equal(counts.sum(axis=1), np.full(8, 8))
    # the onehot refines the histogram
    np.testing.assert_array_equal(topo.class_onehot.sum(axis=2), counts)


def test_topology_weight_matrix_and_resize():
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4,
                            class_weights=(0.0, 1.0, 10.0))
    wm = topo.weight_matrix()
    assert wm[0, 0] == 0.0 and wm[0, 1] == 1.0 and wm[0, 4] == 10.0
    # resize keeps the host width: 8/4 -> 4 lanes is one host (all intra)
    small = topo.resized(4)
    assert small.num_hosts == 1
    assert small.weight_matrix().max() == 1.0
    # and a cross-size weight matrix can be asked for directly (the plan
    # pricing folds to worker granularity, which may differ from num_lanes)
    assert topo.weight_matrix(4).shape == (4, 4)


def test_topology_tables_are_cached_and_frozen():
    """The hoisted class tables are computed once per (L, G) and shared —
    jitted steps close over them instead of rebuilding per trace — and are
    write-protected so nothing can corrupt the shared constant."""
    a = _class_tables(8, 4)
    assert a is _class_tables(8, 4)
    with pytest.raises(ValueError):
        a[0][0, 0] = 7


def test_spec_resized_rederives_topology():
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    spec = ExchangeSpec(num_lanes=8, capacity=32, axis="data", topology=topo)
    grown = spec.resized(num_lanes=16)
    assert grown.topology.num_lanes == 16
    assert grown.topology.lanes_per_host == 4
    assert grown.topology.num_hosts == 4
    shrunk = spec.resized(num_lanes=4)
    assert shrunk.topology.num_hosts == 1
    # re-capacitating does not disturb the topology
    assert spec.resized(capacity=64).topology == topo
    # a flat spec stays flat
    assert ExchangeSpec(8, 32, axis="data").resized(num_lanes=4).topology is None


def test_spec_snaps_mismatched_topology():
    """Constructing a spec with a stale lane count on the topology snaps it
    to the spec's — the resize path hands the old topology straight in."""
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    spec = ExchangeSpec(num_lanes=16, capacity=8, axis="data", topology=topo)
    assert spec.topology.num_lanes == 16
    assert spec.topology.lanes_per_host == 4


def test_exchange_topology_of_mesh():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    # single-process mesh: no process boundary to read -> one host
    topo = exchange_topology_of(mesh)
    assert topo.num_lanes == mesh.shape["data"]
    assert topo.lanes_per_host == topo.num_lanes and topo.num_hosts == 1
    # modeled boundary + custom pricing thread through
    topo = exchange_topology_of(mesh, lanes_per_host=1,
                                class_weights=(0.0, 2.0, 5.0))
    assert topo.num_hosts == mesh.shape["data"]
    assert topo.class_weights == (0.0, 2.0, 5.0)


# ---------------------------------------------------------------------------
# locality-priced plan cost
# ---------------------------------------------------------------------------


def _plan_moving(src: int, dst: int, rows: float, n: int = 4) -> MigrationPlan:
    transfer = np.zeros((n, n))
    transfer[src, dst] = rows
    return MigrationPlan(
        keys=np.zeros(1, np.int64), src=np.array([src], np.int32),
        dst=np.array([dst], np.int32), weights=np.array([rows]),
        transfer=transfer, relative_migration=0.1, num_src=n, num_dst=n,
    )


def test_exchange_lane_cost_topology_flips_plan_choice():
    """Two candidate plans, flat pricing preferring the wrong one: B moves
    slightly less mass but across the host boundary.  The locality price
    (10x inter-host) flips the ordering — the decision the policies gate on.
    """
    topo = ExchangeTopology(num_lanes=4, lanes_per_host=2)
    plan_a = _plan_moving(0, 1, rows=100.0)   # intra-host
    plan_b = _plan_moving(0, 2, rows=90.0)    # inter-host
    flat = {p: exchange_lane_cost(pl, num_workers=4)
            for p, pl in (("a", plan_a), ("b", plan_b))}
    priced = {p: exchange_lane_cost(pl, num_workers=4, topology=topo)
              for p, pl in (("a", plan_a), ("b", plan_b))}
    assert flat["b"] < flat["a"]        # flat: fewer rows wins
    assert priced["a"] < priced["b"]    # priced: intra-host wins
    # self-traffic is free under the topology too
    assert exchange_lane_cost(_plan_moving(1, 1, 50.0), topology=topo) == 0.0


def test_repartition_policy_sees_host_topology():
    """The policy stack prices with the DRM's installed topology: the same
    imbalanced window costs more to fix when every move crosses hosts, so
    the all-inter topology declines a repartition the intra one takes."""
    rng = np.random.default_rng(0)
    keys = np.repeat(np.arange(64), rng.integers(1, 200, 64))
    loads = np.bincount(uniform_partitioner(4, seed=0).lookup_np(
        keys.astype(np.int32)), minlength=4).astype(float)
    decisions = {}
    for name, weights in (("cheap", (0.0, 1.0, 1.0)), ("dear", (0.0, 1e6, 1e6))):
        topo = ExchangeTopology(num_lanes=4, lanes_per_host=1,
                                class_weights=weights)
        drm = DRMaster(
            uniform_partitioner(4, seed=0),
            DRConfig(imbalance_trigger=1.05, migration_cost_weight=1.0),
            exchange_topology=topo,
        )
        drm.observe(keys.reshape(1, -1).astype(np.int32),
                    np.ones((1, len(keys)), np.int32))
        t = Telemetry("t")
        t.record_batch(float(len(keys)))
        sig = t.snapshot(loads=loads, num_workers=4, at_safe_point=True)
        decisions[name] = drm.evaluate(sig)
    assert decisions["cheap"].taken, decisions["cheap"].reason
    assert not decisions["dear"].taken, decisions["dear"].reason


# ---------------------------------------------------------------------------
# per-class accounting on the backends (single device: 1-lane collectives
# and the bucketize layer; the 8-shard split is in test_distributed.py)
# ---------------------------------------------------------------------------


def _run_with_topology(backend, topo, lane, valid, vals, capacity):
    mesh = jax.make_mesh((1,), ("data",))
    ex = make_exchange(
        ExchangeSpec(num_lanes=topo.num_lanes, capacity=capacity, axis="data",
                     topology=topo),
        backend,
    )

    def body(lane, valid, vals):
        res = ex(lane, valid, [Payload(vals, -1.0)])
        va, (v,) = res.unpack()
        return va[None], v[None], res.shipped_rows, res.shipped_rows_by_class

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(), P()),
        check_vma=False,
    )
    va, v, shipped, by = mapped(lane, valid, vals)
    return np.asarray(va), np.asarray(v), int(shipped), np.asarray(by)


@pytest.mark.parametrize("backend", ["dense", "ragged", "hierarchical"])
def test_by_class_sums_to_scalar_and_rows_bit_identical(backend):
    """Every backend's per-class split refines its own scalar shipped_rows
    (identical sum), while the unpacked rows stay bit-identical to dense —
    the PR 4 contract extended by the class axis."""
    rng = np.random.default_rng(7)
    n, capacity = 128, 64
    topo = ExchangeTopology(num_lanes=4, lanes_per_host=2)
    lane = rng.integers(0, 4, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    vals = rng.normal(size=(n,)).astype(np.float32)
    args = (jnp.asarray(lane), jnp.asarray(valid), jnp.asarray(vals), capacity)
    va, v, shipped, by = _run_with_topology(backend, topo, *args)
    ref_va, ref_v, _, _ = _run_with_topology("dense", topo, *args)
    np.testing.assert_array_equal(va, ref_va)
    np.testing.assert_array_equal(v, ref_v)
    assert by.shape == (DISTANCE_CLASSES,)
    assert int(by.sum()) == shipped, (by, shipped)


def test_flat_spec_stamps_no_classes():
    """Without a topology the result carries no per-class split — stats()
    then leaves ``rows_by_class`` None and nothing downstream changes."""
    ex = make_exchange(ExchangeSpec(num_lanes=3, capacity=4))
    res = ex(jnp.asarray([0, 1, 2], jnp.int32), jnp.ones(3, bool),
             [Payload(jnp.arange(3, dtype=jnp.float32), 0)])
    assert res.shipped_rows_by_class is None
    assert res.stats().rows_by_class is None


def test_resolve_backend_knows_hierarchical():
    assert isinstance(resolve_backend("hierarchical"), HierarchicalBackend)
    assert resolve_backend("hierarchical").name == "hierarchical"


def test_hierarchical_plan_fallback_conditions():
    be = HierarchicalBackend()
    topo = ExchangeTopology(num_lanes=8, lanes_per_host=4)
    assert be._plan(ExchangeSpec(8, 4, axis="data", topology=topo)) is None  # 1 device
    assert be._plan(ExchangeSpec(8, 4, axis="data")) is None                # no topo
    one_host = ExchangeTopology(num_lanes=8, lanes_per_host=8)
    assert be._plan(ExchangeSpec(8, 4, axis="data", topology=one_host)) is None


# ---------------------------------------------------------------------------
# telemetry + snapshots
# ---------------------------------------------------------------------------


def test_telemetry_folds_rows_by_class_into_signals():
    t = Telemetry("test")
    t.record_exchange(ExchangeStats(rows=30, rows_by_class=np.array([10, 10, 10])))
    t.record_exchange(ExchangeStats(rows=6, rows_by_class=np.array([2, 2, 2])))
    t.record_exchange(ExchangeStats(rows=0))  # class-less record folds fine
    s = t.snapshot(loads=np.ones(3))
    np.testing.assert_array_equal(s.exchange_rows_by_class, [12, 12, 12])
    assert s.inter_host_fraction == pytest.approx(12 / 36)
    # a flat window has no class split and a well-defined zero fraction
    s2 = Telemetry("flat").snapshot(loads=np.ones(3))
    assert s2.exchange_rows_by_class is None
    assert s2.inter_host_fraction == 0.0


def test_drm_snapshot_roundtrips_topology():
    topo = ExchangeTopology(num_lanes=4, lanes_per_host=2,
                            class_weights=(0.0, 2.0, 7.0))
    drm = DRMaster(uniform_partitioner(4, seed=0), DRConfig(),
                   exchange_topology=topo)
    snap = drm.snapshot()
    restored = DRMaster.restore(snap, DRConfig())
    assert restored.exchange_topology == topo
    # flat DRMs write no topology keys (legacy snapshot byte-stability)
    flat_snap = DRMaster(uniform_partitioner(4, seed=0), DRConfig()).snapshot()
    assert not any(k.startswith("topology_") for k in flat_snap)
    assert DRMaster.restore(flat_snap, DRConfig()).exchange_topology is None


def test_streaming_snapshot_carries_topology():
    topo = ExchangeTopology(num_lanes=1, lanes_per_host=1)
    job = StreamingJob(state_capacity=512, topology=topo)
    job.process_batch(np.arange(64, dtype=np.int64))
    snap = job.snapshot()
    fresh = StreamingJob(state_capacity=512)  # built flat
    fresh.restore(snap)
    assert fresh.exchange_topology == topo
    assert fresh.drm.exchange_topology == topo
    m = fresh.process_batch(np.arange(64, dtype=np.int64))
    assert sum(m.shipped_rows_by_class) == m.shipped_rows
